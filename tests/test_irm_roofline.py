"""IRM / roofline unit + property tests: ceiling geometry, bottleneck
classification, term arithmetic."""
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import paper_data
from repro.core.hardware import MI100, TPU_V5E
from repro.core.hlo_counters import Census
from repro.core.irm import Ceiling, IRMPoint, gpu_irm, tpu_irm
from repro.core.roofline import roofline_terms
from repro.core.tpu_model import profile_from_census


def _census(flops=1e12, hbm=1e11, wire=1e9):
    c = Census()
    c.flops = flops
    c.mxu_flops = flops
    c.hbm_bytes = hbm
    c.mxu_issues = flops / (2 * 128 ** 3)
    c.vpu_issues = 1e6
    c.collectives["all-reduce"] = type(
        "S", (), {"count": 1, "operand_bytes": wire, "wire_bytes": wire})()
    return c


def test_roofline_dominant_selection():
    hw = TPU_V5E
    # compute-heavy
    t = roofline_terms("c", _census(flops=1e15, hbm=1e9, wire=1e6), hw, 1)
    assert t.dominant == "compute"
    # memory-heavy
    t = roofline_terms("m", _census(flops=1e9, hbm=1e12, wire=1e6), hw, 1)
    assert t.dominant == "memory"
    # collective-heavy
    t = roofline_terms("x", _census(flops=1e9, hbm=1e6, wire=1e12), hw, 1)
    assert t.dominant == "collective"


def test_roofline_terms_match_hand_math():
    hw = TPU_V5E
    t = roofline_terms("h", _census(flops=197e12, hbm=819e9, wire=200e9),
                       hw, 1)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)          # 4 links x 50 GB/s


def test_mfu_uses_model_flops():
    hw = TPU_V5E
    t = roofline_terms("u", _census(flops=197e12, hbm=1.0, wire=0.0), hw,
                       n_devices=4, model_flops_total=4 * 98.5e12)
    # modeled time 1s; useful flops per dev = 98.5e12 -> MFU 0.5
    assert t.mfu_vs_peak == pytest.approx(0.5)
    assert t.useful_flops_ratio == pytest.approx(0.5)


def test_gpu_irm_geometry():
    model = gpu_irm(MI100, [paper_data.LWFA_MI100])
    knee = model.knee()
    assert knee == pytest.approx(MI100.peak_gips()
                                 / MI100.memory_ceiling_gbs())
    # left of knee -> memory classified
    p = model.points[0]
    assert model.classify(p) == ("memory" if p.intensity < knee
                                 else "compute")
    # achieved point must sit under the binding roof
    assert model.headroom(p) >= 1.0


def test_tpu_irm_two_unit_ceilings():
    c = _census()
    prof = profile_from_census("k", c, TPU_V5E, runtime_s=1.0)
    model = tpu_irm([prof])
    labels = [ceil.label for ceil in model.ceilings]
    assert any("MXU" in l for l in labels)
    assert any("VPU" in l for l in labels)
    assert len(model.points) == 2                       # MXU + VPU points


@settings(max_examples=30, deadline=None)
@given(flops=st.floats(1e6, 1e16), hbm=st.floats(1e3, 1e13),
       wire=st.floats(0, 1e12))
def test_roofline_properties(flops, hbm, wire):
    """Invariants: modeled time == max term; fractions <= 1; achieved rates
    never exceed peaks."""
    hw = TPU_V5E
    t = roofline_terms("p", _census(flops, hbm, wire), hw, 1)
    assert t.modeled_time_s == pytest.approx(
        max(t.compute_s, t.memory_s, t.collective_s))
    assert max(t.compute_fraction, t.memory_fraction,
               t.collective_fraction) == pytest.approx(1.0)
    assert t.achieved_tflops * 1e12 <= hw.peak_flops_bf16 * 1.0001
    assert t.achieved_gbs * 1e9 <= hw.memory_ceiling_gbs() * 1e9 * 1.0001


@settings(max_examples=20, deadline=None)
@given(intensity=st.floats(1e-6, 1e3))
def test_irm_roof_is_min_of_ceilings(intensity):
    model = gpu_irm(MI100, [paper_data.LWFA_MI100])
    roof = model.roof_at(intensity)
    for c in model.ceilings:
        assert roof <= c.y_at(intensity) + 1e-9
