"""Config invariants the production mesh relies on (divisibilities, family
wiring, shape applicability, parameter-count sanity)."""
import pytest

from repro.configs import SHAPES, get, registry, shape_applicable
from repro.configs.all_archs import ALL_ARCHS

TP = 16
DP = 16


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_flat_projection_dims_divide_tp(arch):
    cfg = get(arch)
    if cfg.is_attention_free:
        pytest.skip("no attention projections")
    assert (cfg.n_heads * cfg.head_dim) % TP == 0
    assert (cfg.n_kv_heads * cfg.head_dim) % TP == 0
    assert cfg.d_model % (2 * DP) == 0          # fsdp over pod+data


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_shapes_divide_mesh(arch):
    cfg = get(arch)
    for shape in SHAPES.values():
        if shape_applicable(cfg, shape):
            continue
        assert shape.seq_len % (DP * TP) == 0   # cache_seq over data x model
        if shape.kind == "train":
            assert shape.global_batch % (2 * DP) == 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_moe_block_layout(arch):
    cfg = get(arch)
    if not cfg.n_experts:
        return
    G = cfg.ep_shards
    assert (cfg.n_experts * cfg.d_ff) % G == 0
    assert G % cfg.n_experts == 0 or cfg.n_experts % G == 0
    # 2D serving EP layout must also divide
    assert (cfg.n_experts * cfg.d_ff) % (DP * TP) == 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_ssm_dims(arch):
    cfg = get(arch)
    if not cfg.mamba_version:
        return
    d_in = cfg.d_model * cfg.ssm_expand
    assert d_in % TP == 0
    if cfg.mamba_version == 2:
        assert d_in % cfg.ssm_head_dim == 0
        assert (d_in // cfg.ssm_head_dim) % TP == 0   # heads over model


def test_long_500k_only_subquadratic():
    runs = [a for a in ALL_ARCHS
            if not shape_applicable(get(a), SHAPES["long_500k"])]
    assert sorted(runs) == ["falcon-mamba-7b", "zamba2-7b"]


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_is_small(arch):
    r = get(arch).reduced()
    assert r.n_params() < 5e6
    assert r.family == get(arch).family


def test_known_param_counts():
    """Sanity-anchor the analytic counts.  Anchors follow the ASSIGNED
    configs (e.g. grok's gelu 2-matrix experts give ~213B rather than the
    314B marketing figure, which assumes 3-matrix GLU experts); what the
    schema declares must match what n_params() predicts — asserted
    leaf-by-leaf in test_models_smoke.test_param_count_sane."""
    assert 90e9 < get("llama4-scout-17b-a16e").n_params() < 115e9
    assert 9e9 < get("llama4-scout-17b-a16e").active_params() < 18e9
    assert 190e9 < get("grok-1-314b").n_params() < 340e9
    assert 40e9 < get("grok-1-314b").active_params() < 90e9
    assert 6e9 < get("granite-8b").n_params() < 9e9
    assert 6e9 < get("falcon-mamba-7b").n_params() < 9e9
    assert 60e9 < get("qwen2-vl-72b").n_params() < 80e9
    assert 0.4e9 < get("qwen2-0.5b").n_params() < 0.6e9
