"""Per-architecture smoke tests: REDUCED configs (same family/wiring, tiny
dims) run one forward + one train-grad step + a prefill/decode consistency
check on CPU, asserting shapes and finiteness.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get, registry
from repro.configs.all_archs import ALL_ARCHS
from repro.models import get_model

BATCH, SEQ = 2, 64


def _batch_for(model, B=BATCH, S=SEQ, key=0):
    cfg = model.cfg
    rng = np.random.RandomState(key)
    batch = {"labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(rng.randn(B, S, cfg.d_model),
                                      cfg.param_dtype)
        batch["tokens"] = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                      jnp.int32)
    elif cfg.embed_inputs:
        batch["tokens"] = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                                      jnp.int32)
    else:
        batch["embeds"] = jnp.asarray(rng.randn(B, S, cfg.d_model),
                                      cfg.param_dtype)
        if cfg.mrope_sections:
            pos = np.broadcast_to(np.arange(S)[None, None], (3, B, S))
            batch["positions"] = jnp.asarray(pos, jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_grad(arch):
    cfg = get(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch_for(model)

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    gnorm = jax.tree.reduce(
        lambda a, x: a + float(jnp.sum(jnp.square(x.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grad norm {gnorm}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    """Decoding token t with the prefill(0..t-1) cache must match the
    training forward's logits at position t-1 (teacher forcing)."""
    cfg = get(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 32
    batch = _batch_for(model, B, S, key=1)
    prefill_batch = dict(batch)
    prefill_batch.pop("labels", None)

    last_logits, cache_parts = jax.jit(model.prefill)(params, prefill_batch)
    assert last_logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(last_logits, np.float32)))

    # full-forward logits (teacher forcing) for comparison
    from repro.models import transformer as T
    if cfg.is_encoder_decoder:
        logits_full, _, _ = jax.jit(
            lambda p, f, t: T.whisper_forward(p, cfg, f, t, mode="train")
        )(params, batch["frames"], batch["tokens"])
    else:
        inputs = batch["tokens"] if cfg.embed_inputs else batch["embeds"]
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        logits_full, _, _ = jax.jit(
            lambda p, i, po: T.lm_forward(p, cfg, i, po, mode="train")
        )(params, inputs, positions)
    np.testing.assert_allclose(
        np.asarray(last_logits, np.float32),
        np.asarray(logits_full[:, -1], np.float32), rtol=2e-2, atol=2e-2)

    # now extend the prefill cache into a padded decode cache and take a step
    max_seq = S + 8
    cache = model.init_cache(B, max_seq)
    for k in cache_parts or {}:
        src = cache_parts[k]
        dst = cache[k]
        # cache parts are (L, B, S, ...) — pad the seq dim
        pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        cache[k] = jnp.pad(src.astype(dst.dtype), pad)
    cache["pos"] = jnp.asarray(S, jnp.int32)

    next_tok = jnp.zeros((B, 1), jnp.int32)
    logits_step, cache2 = jax.jit(model.decode_step)(params, next_tok, cache)
    assert logits_step.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits_step, np.float32)))
    assert int(cache2["pos"]) == S + 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_input_specs_complete(arch):
    """input_specs must cover every live shape cell without allocation."""
    from repro.configs import shape_applicable
    cfg = get(arch)
    model = get_model(cfg)
    for sname, shape in SHAPES.items():
        if shape_applicable(cfg, shape):
            continue
        specs = model.input_specs(shape)
        leaves = jax.tree.leaves(specs)
        assert leaves, (arch, sname)
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_registry_complete():
    assert len(registry()) == 10
    assert set(ALL_ARCHS) == set(registry())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_sane(arch):
    """Schema-declared parameter volume should be within 25% of the
    analytic n_params() estimate (catches missing/extra tensors)."""
    cfg = get(arch)
    model = get_model(cfg)
    total = 0
    for leaf in jax.tree.leaves(model.abstract_params()):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    est = cfg.n_params()
    assert 0.75 < total / est < 1.33, (arch, total, est)
