"""Shared test config.

Three pieces:

  * a ``slow`` marker (+ ``--runslow`` flag): the paged-cache property
    harness runs a short fuzz profile under tier-1 and a long profile
    (thousands of randomized schedule steps) only when asked —
    ``pytest --runslow -m slow`` runs just the long profiles.
  * the container image does not ship ``hypothesis``; rather than losing
    every test in the property-test modules at collection time, install a
    minimal shim that SKIPS @given tests and leaves the plain parametrized
    tests running.  When hypothesis is available the shim is inert.
  * a per-test WATCHDOG: ``pytest-timeout`` is not installed either, so an
    autouse fixture arms ``faulthandler.dump_traceback_later`` around every
    test — a wedged serving engine (the exact failure mode the overload
    harness exists to prevent) dumps every thread's stack and kills the
    process instead of hanging tier-1 forever.  Budget via
    ``REPRO_TEST_TIMEOUT`` seconds (0 disables; default 900).
"""
import faulthandler
import os
import sys
import types

import pytest


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run tests marked slow (long fuzz profiles)")


@pytest.fixture(autouse=True)
def _watchdog():
    """Per-test hang watchdog (stand-in for pytest-timeout, which the image
    does not ship).  The timer RESETS each test, so the budget is per-test;
    on expiry every thread's traceback is dumped and the process exits —
    CI gets a stack instead of a silent hang."""
    budget = float(os.environ.get("REPRO_TEST_TIMEOUT", "900"))
    if budget <= 0:
        yield
        return
    faulthandler.dump_traceback_later(budget, exit=True)
    try:
        yield
    finally:
        faulthandler.cancel_dump_traceback_later()


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-profile fuzz/bench tests (run with --runslow)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow profile (use --runslow)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (container image)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def _strategy(*_args, **_kwargs):
        return None

    st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "just", "one_of", "composite", "data", "text"):
        setattr(st, _name, _strategy)

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
