"""Shared test config.

The container image does not ship ``hypothesis``; rather than losing every
test in the property-test modules at collection time, install a minimal shim
that SKIPS @given tests and leaves the plain parametrized tests running.
When hypothesis is available the shim is inert.
"""
import sys
import types

import pytest

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (container image)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def _strategy(*_args, **_kwargs):
        return None

    st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "sampled_from", "lists",
                  "tuples", "just", "one_of", "composite", "data", "text"):
        setattr(st, _name, _strategy)

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
