"""Multi-device sharding tests — run in a SUBPROCESS with 8 host devices so
the main test process keeps the single real CPU device (per dry-run policy,
XLA_FLAGS must never be set globally)."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get
from repro.dist.sharding import MeshRules, use_mesh
from repro.models import get_model
from repro.core.hlo_counters import census_from_compiled

out = {}

try:
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
except (AttributeError, TypeError):            # jax < 0.5: no AxisType
    mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = MeshRules(batch_axes=("data",), fsdp_axes=("data",),
                  model_axis="model")

# 1) sharded MoE forward == unsharded reference (the shard_map island)
cfg = get("llama4-scout-17b-a16e").reduced()   # E=4, ep_shards=4
model = get_model(cfg)
params = model.init(jax.random.key(0))
B, S = 4, 32
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S))),
         "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)))}
loss_ref = float(jax.jit(model.loss)(params, batch))

from repro.train.elastic import reshard
with use_mesh(mesh, rules):
    p_sh = reshard(params, model.param_pspecs(rules), mesh)
    batch_sh = jax.tree.map(
        lambda x: jax.device_put(x, NamedSharding(mesh, P("data"))), batch)
    loss_sharded = float(jax.jit(model.loss)(p_sh, batch_sh))
out["moe_loss_ref"] = loss_ref
out["moe_loss_sharded"] = loss_sharded

# 2) collective census on a real SPMD program
def f(x, w):
    return jnp.tanh(x @ w).sum()

xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
ws = jax.ShapeDtypeStruct((128, 64), jnp.float32)
with mesh:
    compiled = jax.jit(
        f, in_shardings=(NamedSharding(mesh, P("data", "model")),
                         NamedSharding(mesh, P("model", None)))
    ).lower(xs, ws).compile()
census = census_from_compiled(compiled)
out["n_partitions_collectives"] = {
    k: v.count for k, v in census.collectives.items()}
out["collective_wire_bytes"] = census.collective_wire_bytes
print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def subprocess_result():
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_moe_sharded_matches_reference(subprocess_result):
    r = subprocess_result
    assert abs(r["moe_loss_sharded"] - r["moe_loss_ref"]) \
        / abs(r["moe_loss_ref"]) < 2e-2, r


def test_collective_census_nonzero(subprocess_result):
    r = subprocess_result
    assert r["collective_wire_bytes"] > 0
    assert any(k in r["n_partitions_collectives"]
               for k in ("all-reduce", "reduce-scatter", "all-gather"))
