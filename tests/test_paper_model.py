"""Paper-faithfulness tests: Eqs 1-4 must reproduce Tables 1 and 2 of
Leinhauser et al. 2021 from the published raw counter values.

The paper states its table values are rounded to three decimals and that
"manually calculating Achieved GIPS and Instruction Intensity may vary
slightly" (runtimes are rounded too), so the assertions allow 2% slack —
tight enough to catch any formula error (wrong lane width, missing x4 SIMD
factor, etc. are all >>2%).
"""
import pytest

from repro.core import hardware, paper_data
from repro.core.paper_model import (
    AMD_WAVEFRONT, NVIDIA_WARP, amd_instructions, achieved_gips,
    instruction_intensity_performance, peak_gips)

TABLES = [
    ("table1", paper_data.TABLE1, paper_data.LWFA_PUBLISHED),
    ("table2", paper_data.TABLE2, paper_data.TWEAC_PUBLISHED),
]


@pytest.mark.parametrize("tname,table,published", TABLES)
@pytest.mark.parametrize("gpu", ["v100", "mi60", "mi100"])
def test_peak_gips_eq3(tname, table, published, gpu):
    m = table[gpu]
    assert m.peak_gips() == pytest.approx(published[gpu]["peak_gips"],
                                          rel=1e-6)


@pytest.mark.parametrize("tname,table,published", TABLES)
@pytest.mark.parametrize("gpu", ["v100", "mi60", "mi100"])
def test_achieved_gips_eq4(tname, table, published, gpu):
    m = table[gpu]
    assert m.achieved_gips() == pytest.approx(
        published[gpu]["achieved_gips"], rel=0.02)


@pytest.mark.parametrize("tname,table,published", TABLES)
@pytest.mark.parametrize("gpu", ["v100", "mi60", "mi100"])
def test_intensity_performance_eq2(tname, table, published, gpu):
    """The tables' intensity column is Eq. 2 *including* the runtime factor
    (verified: MI60 TWEAC 90,319,028,127/64 / (12,236,110,000 x 0.394) =
    0.293)."""
    m = table[gpu]
    assert m.intensity_performance() == pytest.approx(
        published[gpu]["intensity"], rel=0.02)


def test_eq1_instruction_scaling():
    # 4 SIMD vector units per CU, 1 scalar unit.
    assert amd_instructions(100, 7) == 407
    assert amd_instructions(0, 5) == 5


def test_wavefront_vs_warp_normalization():
    """Paper section 7.3: identical instruction counts yield 2x higher GIPS
    on NVIDIA purely from warp(32) vs wavefront(64) scaling."""
    g_amd = achieved_gips(1e9, 1.0, AMD_WAVEFRONT)
    g_nv = achieved_gips(1e9, 1.0, NVIDIA_WARP)
    assert g_nv == pytest.approx(2 * g_amd)


def test_peak_gips_scheduler_scaling():
    """Paper section 7.3: if the V100 had 1 scheduler/SM its peak would be
    122.4 GIPS (a quarter of 489.6)."""
    import dataclasses
    v100_one = dataclasses.replace(hardware.V100, schedulers_per_cu=1)
    assert v100_one.peak_gips() == pytest.approx(122.4)


def test_bound_classification():
    # The LWFA MI100 point sits near the memory roof; its memory-bound GIPS
    # must cap it well under the 180.24 compute ceiling.
    m = paper_data.LWFA_MI100
    assert m.bound() == "memory"
    assert m.memory_bound_gips() < m.peak_gips()


def test_babelstream_ceilings():
    """Paper section 7.3: MI60 achieves 81% and MI100 78% of theoretical
    bandwidth under BabelStream."""
    assert hardware.MI60.memory_ceiling_gbs() / 1000.0 == pytest.approx(
        0.81, abs=0.01)
    assert hardware.MI100.memory_ceiling_gbs() / 1200.0 == pytest.approx(
        0.78, abs=0.01)


def test_eq2_rejects_nonpositive():
    with pytest.raises(ValueError):
        instruction_intensity_performance(1.0, 0.0, 0.0, 1.0, 64)
    with pytest.raises(ValueError):
        achieved_gips(1.0, 0.0, 64)


def test_tpu_v5e_issue_model_consistency():
    """The MXU issue model must reproduce the chip's 197 TFLOP/s bf16 peak."""
    hw = hardware.TPU_V5E
    assert hw.mxu_flops_consistency() == pytest.approx(197e12, rel=0.001)
