"""Fused decode subsystem tests: decode_many vs the legacy per-token loop
(greedy AND seeded temperature must be token-identical), Pallas
decode-attention vs the jnp reference in interpret mode, per-slot stop
conditions, slot release/join in the continuous-batching engine, and the
census-ability of the fused decode program."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get
from repro.models import get_model
from repro.serve.engine import (
    ContinuousBatchingEngine, ServeConfig, ServingEngine)


@pytest.fixture(scope="module")
def small_model():
    cfg = get("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def _prompts(model, n=2, seed=3):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, model.cfg.vocab_size, size=ln).astype(np.int32)
            for ln in rng.randint(5, 12, size=n)]


# ---------------------------------------------------------------------------
# fused loop vs legacy loop
# ---------------------------------------------------------------------------

def test_fused_matches_legacy_greedy(small_model):
    model, params = small_model
    eng = ServingEngine(model, params,
                        ServeConfig(max_batch=2, max_seq=48,
                                    max_new_tokens=6, temperature=0.0))
    prompts = _prompts(model)
    assert eng.generate_batch(prompts, fused=True) == \
        eng.generate_batch(prompts, fused=False)


def test_fused_matches_legacy_temperature(small_model):
    """Same seed => identical key-split discipline => identical tokens."""
    model, params = small_model
    prompts = _prompts(model)
    cfg = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=6,
                      temperature=0.7, seed=11)
    a = ServingEngine(model, params, cfg).generate_batch(prompts, fused=True)
    b = ServingEngine(model, params, cfg).generate_batch(prompts, fused=False)
    assert a == b


def test_decode_many_eos_freezes_slot(small_model):
    """Once a slot samples eos its output is frozen to pad_id while the
    other slots keep decoding."""
    model, params = small_model
    B, S, steps = 2, 8, 5
    cache = model.init_cache(B, S + steps + 1)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    key = jax.random.key(0)
    tok = jnp.zeros((B, 1), jnp.int32)
    ref, *_ = model.decode_many(params, tok, cache, key, num_steps=steps)
    eos = int(ref[0, 0])                    # force slot 0's first sample
    cache = model.init_cache(B, S + steps + 1)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    toks, _, _, done = model.decode_many(
        params, tok, cache, key, num_steps=steps, eos_id=eos, pad_id=255)
    toks = np.asarray(toks)
    assert int(toks[0, 0]) == eos
    assert all(int(t) == 255 for t in toks[1:, 0])       # frozen after eos
    assert bool(np.asarray(done)[0])
    if eos not in toks[:, 1]:
        assert not bool(np.asarray(done)[1])


def test_decode_many_advances_cache_pos(small_model):
    model, params = small_model
    cache = model.init_cache(2, 32)
    cache["pos"] = jnp.asarray(4, jnp.int32)
    toks, cache, _, _ = model.decode_many(
        params, jnp.zeros((2, 1), jnp.int32), cache, jax.random.key(0),
        num_steps=6)
    assert toks.shape == (6, 2)
    assert int(cache["pos"]) == 10


# ---------------------------------------------------------------------------
# Pallas decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,KV,D", [
    (2, 32, 4, 2, 16),        # GQA
    (3, 48, 4, 1, 16),        # MQA
    (1, 128, 8, 8, 64),       # MHA, aligned
    (2, 24, 6, 2, 32),        # odd T
])
def test_decode_attention_matches_ref(B, T, H, KV, D):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, KV, D), jnp.float32)
    rng = np.random.RandomState(1)
    kv_len = jnp.int32(rng.randint(1, T + 1))
    starts = jnp.asarray(rng.randint(0, int(kv_len), size=B), jnp.int32)
    got = decode_attention(q, k, v, kv_len, starts, interpret=True)
    want = decode_attention_ref(q, k, v, kv_len, starts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_no_start_mask():
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 40, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 40, 2, 16), jnp.float32)
    got = decode_attention(q, k, v, jnp.int32(17), None, interpret=True)
    want = decode_attention_ref(q, k, v, jnp.int32(17), None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_pallas_decode_path_token_identical(small_model):
    """Whole serving path with cfg.attention_impl='pallas' (kernel inside
    the layer scan inside decode_many) vs the jnp reference path."""
    model, params = small_model
    model_pl = get_model(dataclasses.replace(model.cfg,
                                             attention_impl="pallas"))
    sc = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=5)
    prompts = _prompts(model)
    a = ServingEngine(model, params, sc).generate_batch(prompts)
    b = ServingEngine(model_pl, params, sc).generate_batch(prompts)
    assert a == b


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

def test_continuous_first_request_matches_generate(small_model):
    """A request admitted at pos=0 decodes exactly like generate_batch
    (prefill-by-decode == prefill: same causal math, same positions)."""
    model, params = small_model
    prompt = _prompts(model, n=1, seed=9)[0]
    cbe = ContinuousBatchingEngine(
        model, params, ServeConfig(max_batch=2, max_seq=64,
                                   max_new_tokens=6))
    rid = cbe.submit(prompt)
    res = cbe.run()
    single = ServingEngine(
        model, params, ServeConfig(max_batch=1, max_seq=48,
                                   max_new_tokens=6)
    ).generate_batch([prompt])[0]
    assert res[rid] == single


def test_continuous_slot_release_and_join(small_model):
    """More requests than slots: finished sequences release their slot and
    queued requests join mid-flight (no recompilation, per-slot windows)."""
    model, params = small_model
    cfg = ServeConfig(max_batch=2, max_seq=128, max_new_tokens=4)
    cbe = ContinuousBatchingEngine(model, params, cfg)
    prompts = _prompts(model, n=5, seed=4)
    rids = [cbe.submit(p) for p in prompts]
    res = cbe.run()
    assert set(res) == set(rids)
    assert all(len(res[r]) == 4 for r in rids)
    assert cbe.joins == 5                       # every request got a slot
    assert all(not s.active for s in cbe.slots)
    V = model.cfg.vocab_size
    assert all(0 <= t < V for r in rids for t in res[r])
    # late joiners genuinely joined mid-flight: more joins than slots
    assert cbe.joins > cfg.max_batch


def test_continuous_rejects_empty_prompt(small_model):
    model, params = small_model
    cbe = ContinuousBatchingEngine(
        model, params, ServeConfig(max_batch=2, max_seq=32))
    with pytest.raises(ValueError):
        cbe.submit(np.array([], np.int32))


def test_continuous_rejects_ssm():
    cfg = get("falcon-mamba-7b").reduced()
    model = get_model(cfg)
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(model, None,
                                 ServeConfig(max_batch=2, max_seq=32))


# ---------------------------------------------------------------------------
# the fused decode cell is censusable (the PR's motivation)
# ---------------------------------------------------------------------------

def test_fused_decode_program_census(small_model):
    from repro.core.hlo_counters import census_from_compiled
    model, params = small_model
    B, T, steps = 2, 32, 4

    def fused(params, tok, cache, key):
        return model.decode_many(params, tok, cache, key, num_steps=steps)

    key = jax.random.key(0)
    compiled = jax.jit(fused).lower(
        model.abstract_params(), jax.ShapeDtypeStruct((B, 1), jnp.int32),
        model.abstract_cache(B, T),
        jax.ShapeDtypeStruct(key.shape, key.dtype)).compile()
    census = census_from_compiled(compiled)
    assert census.mxu_flops > 0
    assert census.total_instructions > 0
    # the token loop appears as a trip-counted while: per-layer matmul work
    # must scale with num_steps x n_layers, far above a single step's
    single = model.cfg.n_layers * 2 * model.cfg.d_model
    assert census.mxu_flops > single


# ---------------------------------------------------------------------------
# stream _grid fallback (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,block,expect", [
    (24, 64, 24), (100, 64, 50), (8, 8, 8), (7, 8, 7), (256, 64, 64),
])
def test_stream_block_rows_fallback(rows, block, expect):
    from repro.kernels.stream.stream import _block_rows
    assert _block_rows(rows, block) == expect
    assert rows % _block_rows(rows, block) == 0


def test_stream_odd_rows_no_crash():
    from repro.kernels.stream import ref, stream
    a = jax.random.normal(jax.random.key(0), (24, 128), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (24, 128), jnp.float32)
    got = stream.add(a, b, block_rows=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.add(a, b)),
                               rtol=1e-6)
