"""Fused decode subsystem tests: decode_many vs the legacy per-token loop
(greedy AND seeded temperature must be token-identical), Pallas
decode-attention (dense AND paged) vs the jnp references in interpret mode,
per-slot stop conditions, the paged engine's continuous-batching guarantees
(mid-flight joins, first-request token-identity, outliving max_seq, zero
recompiles — migrated from the retired dense lockstep engine), and the
census-ability of the fused/paged decode programs (paged transaction count
scales with live tokens, not max_seq; COW page-copy bytes scale with pages
copied, not pool size)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get
from repro.models import get_model
from repro.serve.engine import PagedEngine, ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def small_model():
    cfg = get("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def _prompts(model, n=2, seed=3):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, model.cfg.vocab_size, size=ln).astype(np.int32)
            for ln in rng.randint(5, 12, size=n)]


# ---------------------------------------------------------------------------
# fused loop vs legacy loop
# ---------------------------------------------------------------------------

def test_fused_matches_legacy_greedy(small_model):
    model, params = small_model
    eng = ServingEngine(model, params,
                        ServeConfig(max_batch=2, max_seq=48,
                                    max_new_tokens=6, temperature=0.0))
    prompts = _prompts(model)
    assert eng.generate_batch(prompts, fused=True) == \
        eng.generate_batch(prompts, fused=False)


def test_fused_matches_legacy_temperature(small_model):
    """Same seed => identical key-split discipline => identical tokens."""
    model, params = small_model
    prompts = _prompts(model)
    cfg = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=6,
                      temperature=0.7, seed=11)
    a = ServingEngine(model, params, cfg).generate_batch(prompts, fused=True)
    b = ServingEngine(model, params, cfg).generate_batch(prompts, fused=False)
    assert a == b


def test_decode_many_eos_freezes_slot(small_model):
    """Once a slot samples eos its output is frozen to pad_id while the
    other slots keep decoding."""
    model, params = small_model
    B, S, steps = 2, 8, 5
    cache = model.init_cache(B, S + steps + 1)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    key = jax.random.key(0)
    tok = jnp.zeros((B, 1), jnp.int32)
    ref, *_ = model.decode_many(params, tok, cache, key, num_steps=steps)
    eos = int(ref[0, 0])                    # force slot 0's first sample
    cache = model.init_cache(B, S + steps + 1)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    toks, _, _, done = model.decode_many(
        params, tok, cache, key, num_steps=steps, eos_id=eos, pad_id=255)
    toks = np.asarray(toks)
    assert int(toks[0, 0]) == eos
    assert all(int(t) == 255 for t in toks[1:, 0])       # frozen after eos
    assert bool(np.asarray(done)[0])
    if eos not in toks[:, 1]:
        assert not bool(np.asarray(done)[1])


def test_decode_many_advances_cache_pos(small_model):
    model, params = small_model
    cache = model.init_cache(2, 32)
    cache["pos"] = jnp.asarray(4, jnp.int32)
    toks, cache, _, _ = model.decode_many(
        params, jnp.zeros((2, 1), jnp.int32), cache, jax.random.key(0),
        num_steps=6)
    assert toks.shape == (6, 2)
    assert int(cache["pos"]) == 10


# ---------------------------------------------------------------------------
# Pallas decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,T,H,KV,D", [
    (2, 32, 4, 2, 16),        # GQA
    (3, 48, 4, 1, 16),        # MQA
    (1, 128, 8, 8, 64),       # MHA, aligned
    (2, 24, 6, 2, 32),        # odd T
])
def test_decode_attention_matches_ref(B, T, H, KV, D):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, KV, D), jnp.float32)
    rng = np.random.RandomState(1)
    kv_len = jnp.int32(rng.randint(1, T + 1))
    starts = jnp.asarray(rng.randint(0, int(kv_len), size=B), jnp.int32)
    got = decode_attention(q, k, v, kv_len, starts, interpret=True)
    want = decode_attention_ref(q, k, v, kv_len, starts)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_no_start_mask():
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 16), jnp.float32)
    k = jax.random.normal(ks[1], (2, 40, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (2, 40, 2, 16), jnp.float32)
    got = decode_attention(q, k, v, jnp.int32(17), None, interpret=True)
    want = decode_attention_ref(q, k, v, jnp.int32(17), None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# paged Pallas decode attention vs the jnp gather oracle
# ---------------------------------------------------------------------------

def _paged_case(seed, B, H, KV, D, page, NB, L, extra_pages=3):
    """Random pool + a block table of DISTINCT non-null pages per slot +
    ragged per-slot lengths (deliberately not multiples of ``page``)."""
    rng = np.random.RandomState(seed)
    P = B * NB + extra_pages
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (L, P, page, KV, D), jnp.float32)
    vp = jax.random.normal(ks[2], (L, P, page, KV, D), jnp.float32)
    tbl = rng.permutation(np.arange(1, P))[:B * NB].reshape(B, NB)
    lens = rng.randint(1, NB * page + 1, size=B)
    layer = rng.randint(0, L)
    return (q, kp, vp, jnp.asarray(tbl, jnp.int32),
            jnp.asarray(lens, jnp.int32), layer)


@pytest.mark.parametrize("B,H,KV,D,page,NB,L", [
    (2, 4, 2, 16, 8, 3, 2),       # GQA group 2, multi-layer pool
    (3, 4, 1, 16, 16, 2, 1),      # MQA (group 4)
    (1, 8, 8, 32, 8, 4, 3),       # MHA (group 1)
    (2, 6, 2, 32, 16, 2, 2),      # group 3, page !| kv_len
    (2, 4, 2, 16, 1, 5, 1),       # degenerate single-row pages
])
def test_paged_decode_attention_matches_gather_oracle(B, H, KV, D, page,
                                                      NB, L):
    from repro.kernels.decode_attention.ops import paged_decode_attention
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    q, kp, vp, tbl, lens, layer = _paged_case(B + H, B, H, KV, D, page,
                                              NB, L)
    assert any(int(x) % page for x in lens) or page == 1, \
        "case must exercise a partially-filled page"
    got = paged_decode_attention(q, kp, vp, tbl, lens, layer,
                                 interpret=True)
    want = paged_decode_attention_ref(q, kp, vp, tbl, lens, layer)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("NB,P,want", [
    (5, 1, 5), (5, 2, 3), (5, 4, 2), (8, 4, 2), (3, 4, 1), (1, 4, 1),
])
def test_multipage_grid_arithmetic(NB, P, want):
    """P pages per grid step -> ceil(NB / P) steps along the block axis,
    table padded to steps * P with null-page entries."""
    from repro.kernels.decode_attention.paged import grid_steps, padded_blocks
    assert grid_steps(NB, P) == want == -(-NB // P)
    assert padded_blocks(NB, P) == want * P
    assert padded_blocks(NB, P) >= NB


def test_multipage_kernel_runs_ceil_grid_steps(monkeypatch):
    """The pages_per_step=4 kernel must RUN ceil(NB/4) grid steps per
    (slot, kv-head) — asserted on the actual pallas grid, not just the
    arithmetic helper."""
    import repro.kernels.decode_attention.paged as paged_mod
    recorded = {}
    orig = paged_mod.pltpu.PrefetchScalarGridSpec

    def spy(*args, **kwargs):
        recorded["grid"] = kwargs.get("grid", args[1] if len(args) > 1
                                      else None)
        return orig(*args, **kwargs)

    monkeypatch.setattr(paged_mod.pltpu, "PrefetchScalarGridSpec", spy)
    B, H, KV, D, page, NB, L = 2, 4, 2, 16, 8, 5, 1
    q, kp, vp, tbl, lens, layer = _paged_case(0, B, H, KV, D, page, NB, L)
    for pps, steps in ((4, 2), (2, 3), (1, 5)):
        paged_mod.paged_decode_attention_fwd(
            q, kp, vp, tbl, lens, layer, pages_per_step=pps, interpret=True)
        assert recorded["grid"] == (B, KV, steps), \
            f"pages_per_step={pps}: grid {recorded['grid']}"


@pytest.mark.parametrize("pps", [1, 2, 4])
@pytest.mark.parametrize("B,H,KV,D,page,NB,L", [
    (2, 4, 2, 16, 8, 5, 2),       # GQA group 2; 5 % 2 and 5 % 4 != 0
    (3, 4, 1, 16, 8, 3, 1),       # MQA; NB < P at pps=4
    (1, 8, 8, 32, 8, 4, 2),       # MHA; NB % pps == 0 at 2 and 4
    (2, 6, 2, 32, 16, 2, 2),      # group 3; trailing partial page
])
def test_multipage_paged_decode_matches_oracle(pps, B, H, KV, D, page,
                                               NB, L):
    """Multi-page blocking sweeps P physically-scattered pages per grid
    step through the online softmax; the output must match the jnp gather
    oracle bit-for-fp32 across GQA groups, ragged lengths and page counts
    not dividing kv_len OR pages_per_step."""
    from repro.kernels.decode_attention.ops import paged_decode_attention
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    q, kp, vp, tbl, lens, layer = _paged_case(B + H + pps, B, H, KV, D,
                                              page, NB, L)
    got = paged_decode_attention(q, kp, vp, tbl, lens, layer,
                                 pages_per_step=pps, interpret=True)
    want = paged_decode_attention_ref(q, kp, vp, tbl, lens, layer)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# ragged multi-token paged PREFILL kernel vs the jnp gather oracle
# ---------------------------------------------------------------------------

def _prefill_case(seed, B, T, H, KV, D, page, NB, L, extra_pages=3,
                  base=None, grants=None):
    """Random pool + distinct non-null pages per slot + RAGGED chunk
    geometry: per-slot base lengths (tokens resident before the chunk) and
    grants (chunk tokens granted, 1..T) drawn so chunks start mid-page and
    cross page boundaries unless pinned by the caller."""
    rng = np.random.RandomState(seed)
    P = B * NB + extra_pages
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, T, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (L, P, page, KV, D), jnp.float32)
    vp = jax.random.normal(ks[2], (L, P, page, KV, D), jnp.float32)
    tbl = rng.permutation(np.arange(1, P))[:B * NB].reshape(B, NB)
    if base is None:
        base = rng.randint(0, NB * page - T + 1, size=B)
    if grants is None:
        grants = rng.randint(1, T + 1, size=B)
    layer = rng.randint(0, L)
    base = np.asarray(base, np.int32)
    new = base + np.asarray(grants, np.int32)
    return (q, kp, vp, jnp.asarray(tbl, jnp.int32), jnp.asarray(base),
            jnp.asarray(new, jnp.int32), layer)


@pytest.mark.parametrize("B,T,H,KV,D,page,NB,L", [
    (2, 6, 4, 2, 16, 8, 3, 2),    # GQA group 2; T=6 !| page=8
    (3, 8, 4, 1, 16, 4, 5, 1),    # MQA; chunk spans 2+ pages
    (1, 5, 8, 8, 32, 8, 4, 3),    # MHA; odd T
    (2, 7, 6, 2, 32, 16, 2, 2),   # group 3; T !| page
    (2, 4, 4, 2, 16, 1, 9, 1),    # degenerate single-row pages
])
def test_paged_prefill_matches_gather_oracle(B, T, H, KV, D, page, NB, L):
    """Interpret-mode equivalence of the multi-token prefill kernel vs the
    jnp gather oracle across GQA/MQA/MHA, ragged per-slot base lengths and
    grants, chunk sizes not dividing the page, and chunks crossing page
    boundaries — row-for-row, including rows past a slot's grant."""
    from repro.kernels.decode_attention.ops import paged_prefill_attention
    from repro.kernels.decode_attention.ref import paged_prefill_attention_ref
    args = _prefill_case(B + T + H, B, T, H, KV, D, page, NB, L)
    got = paged_prefill_attention(*args, interpret=True)
    want = paged_prefill_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_prefill_chunk_crosses_page_boundary():
    """Pinned geometry: base mid-page, grant spanning three pages — the
    chunk starts mid-page, fills it, crosses a whole page and ends mid-way
    into a third; ragged second slot gets a single-token grant."""
    from repro.kernels.decode_attention.ops import paged_prefill_attention
    from repro.kernels.decode_attention.ref import paged_prefill_attention_ref
    args = _prefill_case(7, 2, 8, 4, 2, 16, 4, 4, 1,
                         base=[3, 5], grants=[8, 1])
    got = paged_prefill_attention(*args, interpret=True)
    want = paged_prefill_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_prefill_empty_slot_isolated():
    """A fully EMPTY slot in the batch (base=0, grant=0 — an unoccupied
    row during a mixed prefill tick) must not perturb any other slot's
    rows; its own all-masked rows are the ONE documented kernel/oracle
    divergence (zeros vs a degenerate uniform softmax) and the engine
    never reads them."""
    from repro.kernels.decode_attention.ops import paged_prefill_attention
    from repro.kernels.decode_attention.ref import paged_prefill_attention_ref
    args = _prefill_case(9, 2, 6, 4, 2, 16, 8, 3, 1,
                         base=[5, 0], grants=[4, 0])
    got = paged_prefill_attention(*args, interpret=True)
    want = paged_prefill_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(got)[0], np.asarray(want)[0],
                               rtol=2e-5, atol=2e-5)
    assert not np.asarray(got)[1].any()         # empty slot: guarded zeros


def test_multipage_prefill_kernel_runs_ceil_grid_steps(monkeypatch):
    """The prefill kernel's pages_per_step blocking must RUN ceil(NB/P)
    grid steps along the block axis — asserted on the actual pallas grid
    of the PREFILL kernel (mirrors the decode-kernel spy above)."""
    import repro.kernels.decode_attention.prefill_paged as prefill_mod
    recorded = {}
    orig = prefill_mod.pltpu.PrefetchScalarGridSpec

    def spy(*args, **kwargs):
        recorded["grid"] = kwargs.get("grid", args[1] if len(args) > 1
                                      else None)
        return orig(*args, **kwargs)

    monkeypatch.setattr(prefill_mod.pltpu, "PrefetchScalarGridSpec", spy)
    B, T, H, KV, D, page, NB, L = 2, 4, 4, 2, 16, 8, 5, 1
    args = _prefill_case(0, B, T, H, KV, D, page, NB, L)
    for pps, steps in ((4, 2), (2, 3), (1, 5)):
        prefill_mod.paged_prefill_attention_fwd(
            *args, pages_per_step=pps, interpret=True)
        assert recorded["grid"] == (B, KV, steps), \
            f"pages_per_step={pps}: grid {recorded['grid']}"


@pytest.mark.parametrize("pps", [1, 2, 4])
@pytest.mark.parametrize("B,T,H,KV,D,page,NB,L", [
    (2, 6, 4, 2, 16, 8, 5, 2),    # GQA group 2; 5 % 2 and 5 % 4 != 0
    (3, 8, 4, 1, 16, 4, 3, 1),    # MQA; NB < P at pps=4; chunk spans pages
    (1, 5, 8, 8, 32, 8, 4, 2),    # MHA; odd T; NB % pps == 0 at 2 and 4
    (2, 7, 6, 2, 32, 16, 2, 2),   # group 3; trailing partial page
])
def test_multipage_paged_prefill_matches_oracle(pps, B, T, H, KV, D, page,
                                                NB, L):
    """Multi-page blocking on the RAGGED PREFILL sweep: P physically-
    scattered pages per grid step through the online softmax, output equal
    to the jnp gather oracle across GQA groups, ragged base/grant
    geometry and page counts not dividing pages_per_step — the shape a
    speculative verify chunk over a long decode history hits every
    tick."""
    from repro.kernels.decode_attention.ops import paged_prefill_attention
    from repro.kernels.decode_attention.ref import paged_prefill_attention_ref
    args = _prefill_case(B + T + H + pps, B, T, H, KV, D, page, NB, L)
    got = paged_prefill_attention(*args, pages_per_step=pps, interpret=True)
    want = paged_prefill_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_prefill_oracle_matches_dense_causal():
    """Oracle-of-oracle: hand-pack a contiguous cache into pages; the
    prefill gather oracle must equal dense causal attention with the same
    per-slot query offsets and lengths."""
    from repro.kernels.decode_attention.ref import paged_prefill_attention_ref
    from repro.models.attention import direct_attention
    B, T, H, KV, D, page, NB = 2, 5, 4, 2, 16, 8, 3
    TT = page * NB
    ks = jax.random.split(jax.random.key(11), 3)
    q = jax.random.normal(ks[0], (B, T, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, TT, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, TT, KV, D), jnp.float32)
    rng = np.random.RandomState(3)
    P = 1 + B * NB
    kp = np.zeros((1, P, page, KV, D), np.float32)
    vp = np.zeros_like(kp)
    tbl = np.zeros((B, NB), np.int32)
    pages = 1 + rng.permutation(B * NB)
    for b in range(B):
        for j in range(NB):
            pg = pages[b * NB + j]
            tbl[b, j] = pg
            kp[0, pg] = np.asarray(k)[b, j * page:(j + 1) * page]
            vp[0, pg] = np.asarray(v)[b, j * page:(j + 1) * page]
    base = jnp.asarray([7, 2], jnp.int32)          # mid-page, ragged
    new = base + jnp.asarray([5, 3], jnp.int32)
    got = paged_prefill_attention_ref(q, jnp.asarray(kp), jnp.asarray(vp),
                                      jnp.asarray(tbl), base, new)
    want = direct_attention(q, k, v, causal=True, q_offset=base, kv_len=new)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_prefill_step_paged_matches_sequential_decode(small_model):
    """THE lane-equivalence pin: one ragged chunked-prefill step must leave
    the pool rows, the per-slot lengths AND the last-position logits
    bit-identical to feeding the same tokens one decode step at a time
    (the prefill-by-decode path it replaces)."""
    model, params = small_model
    B, page, nb, pool, T = 2, 4, 4, 9, 6
    tbl = np.zeros((B, nb), np.int32)
    tbl[0] = [1, 2, 3, 4]
    tbl[1] = [5, 6, 7, 8]

    def fresh():
        cache = model.init_paged_cache(B, nb, page, pool)
        return dict(cache, table=jnp.asarray(tbl))

    rng = np.random.RandomState(0)
    toks = rng.randint(0, model.cfg.vocab_size, size=(B, T)).astype(np.int32)
    grants = np.array([T, 3], np.int32)            # ragged: slot 1 partial

    cache = fresh()
    logits_seq = [None] * B
    for t in range(T):
        act = jnp.asarray([t < grants[0], t < grants[1]])
        logits, cache = model.decode_step_paged(
            params, jnp.asarray(toks[:, t:t + 1]), cache, act)
        for i in range(B):
            if t == grants[i] - 1:
                logits_seq[i] = np.asarray(logits[i])

    cache2 = fresh()
    logits2, cache2 = model.prefill_step_paged(
        params, jnp.asarray(toks), cache2, jnp.asarray(grants))
    np.testing.assert_array_equal(np.asarray(cache["length"]),
                                  np.asarray(cache2["length"]))
    for i in range(B):
        np.testing.assert_array_equal(np.asarray(logits2[i]), logits_seq[i])
    k_seq, k_chunk = np.asarray(cache["k"]), np.asarray(cache2["k"])
    for i in range(B):
        for t in range(grants[i]):
            pg, off = tbl[i, t // page], t % page
            np.testing.assert_array_equal(k_seq[:, pg, off],
                                          k_chunk[:, pg, off])


@pytest.mark.parametrize("lane", [True, False])
def test_prefill_lane_token_identical_to_decode_lane(small_model, lane):
    """The engine's outputs must be byte-for-byte identical with the
    prefill lane on and off (greedy): the lane changes WHEN prompt rows
    are appended (chunks vs steps), never WHAT is appended or sampled."""
    model, params = small_model
    prompts = _prompts(model, n=4, seed=21)
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=2, max_seq=64, max_new_tokens=5,
                                 page_size=8, prefill_chunk=3,
                                 prefill_lane=lane))
    rids = [pe.submit(p) for p in prompts]
    res = pe.run()
    single = ServingEngine(model, params,
                           ServeConfig(max_batch=1, max_seq=64,
                                       max_new_tokens=5))
    for rid, p in zip(rids, prompts):
        assert res[rid] == single.generate_batch([p])[0], \
            f"lane={lane} rid={rid}"
    if lane:
        assert pe.forced_upload_bytes == 0      # prompts never rode forced
        assert pe.prefill_upload_bytes > 0
    else:
        assert pe.forced_upload_bytes > 0       # legacy path measured
        assert pe.prefill_upload_bytes == 0


def test_prefill_lane_fewer_dispatches_per_prompt(small_model):
    """The perf-shape claim behind the lane: admitting a P-token prompt
    costs ceil(P / T) prefill dispatches, not P decode steps.  A 24-token
    prompt with T=8 must fully drain in 3 prefill-lane ticks."""
    model, params = small_model
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, model.cfg.vocab_size, size=24).astype(np.int32)
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=1, max_seq=64, max_new_tokens=2,
                                 page_size=8, prefill_chunk=2,
                                 prefill_chunk_tokens=8))
    pe.submit(prompt)
    ticks = 0
    while any(s.active and s.prompt_left for s in pe.slots) or pe.queue:
        pe.step()
        ticks += 1
    assert ticks == 3                       # ceil(24 / 8), not 24 steps
    assert int(pe.kv.length[0]) == 24       # whole prompt resident
    assert len(pe.slots[0].out) == 1        # first output sampled in-lane


def test_paged_oracle_matches_dense_on_packed_pages():
    """Oracle-of-oracle: hand-pack a contiguous (B, T, KV, D) cache into
    pages; the gather oracle must equal the dense direct attention with the
    same per-slot lengths."""
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    from repro.models.attention import direct_attention
    B, H, KV, D, page, NB = 2, 4, 2, 16, 8, 3
    T = page * NB
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, KV, D), jnp.float32)
    # pack slot b's rows into pages 1 + b*NB + j (order scrambled per slot)
    rng = np.random.RandomState(3)
    P = 1 + B * NB
    kp = np.zeros((1, P, page, KV, D), np.float32)
    vp = np.zeros_like(kp)
    tbl = np.zeros((B, NB), np.int32)
    pages = 1 + rng.permutation(B * NB)
    for b in range(B):
        for j in range(NB):
            pg = pages[b * NB + j]
            tbl[b, j] = pg
            kp[0, pg] = np.asarray(k)[b, j * page:(j + 1) * page]
            vp[0, pg] = np.asarray(v)[b, j * page:(j + 1) * page]
    lens = jnp.asarray([T - 3, page + 1], jnp.int32)      # ragged, page !| len
    got = paged_decode_attention_ref(q, jnp.asarray(kp), jnp.asarray(vp),
                                     jnp.asarray(tbl), lens)
    want = direct_attention(q, k, v, causal=False, kv_len=lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_pallas_decode_path_token_identical(small_model):
    """Whole serving path with cfg.attention_impl='pallas' (kernel inside
    the layer scan inside decode_many) vs the jnp reference path."""
    model, params = small_model
    model_pl = get_model(dataclasses.replace(model.cfg,
                                             attention_impl="pallas"))
    sc = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=5)
    prompts = _prompts(model)
    a = ServingEngine(model, params, sc).generate_batch(prompts)
    b = ServingEngine(model_pl, params, sc).generate_batch(prompts)
    assert a == b


# ---------------------------------------------------------------------------
# paged continuous batching (the regression guarantees migrated from the
# retired dense lockstep engine)
# ---------------------------------------------------------------------------

def test_paged_first_request_matches_generate(small_model):
    """A request admitted into an idle engine decodes exactly like
    generate_batch (chunked prefill-by-decode == prefill: same causal
    math, same request-relative positions)."""
    model, params = small_model
    prompt = _prompts(model, n=1, seed=9)[0]
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=2, max_seq=64, max_new_tokens=6,
                                 page_size=8, prefill_chunk=3))
    rid = pe.submit(prompt)
    res = pe.run()
    single = ServingEngine(
        model, params, ServeConfig(max_batch=1, max_seq=48,
                                   max_new_tokens=6)
    ).generate_batch([prompt])[0]
    assert res[rid] == single


def test_paged_slot_release_and_join(small_model):
    """More requests than slots: finished sequences release their pages and
    queued requests join mid-flight (no recompilation, per-slot pages)."""
    model, params = small_model
    cfg = ServeConfig(max_batch=2, max_seq=128, max_new_tokens=4,
                      page_size=8, prefill_chunk=4)
    pe = PagedEngine(model, params, cfg)
    prompts = _prompts(model, n=5, seed=4)
    rids = [pe.submit(p) for p in prompts]
    res = pe.run()
    assert set(res) == set(rids)
    assert all(len(res[r]) == 4 for r in rids)
    assert pe.joins == 5                        # every request got a slot
    assert all(not s.active for s in pe.slots)
    V = model.cfg.vocab_size
    assert all(0 <= t < V for r in rids for t in res[r])
    # late joiners genuinely joined mid-flight: more joins than slots
    assert pe.joins > cfg.max_batch


def test_paged_outlives_max_seq_token_identical():
    """REGRESSION (the retired lockstep engine's wraparound guarantee): a
    long-lived engine must keep serving after total traffic far exceeds
    max_seq — pages recycle through the free list — and every request must
    stay token-identical to a fresh run.  rope_theta=0 makes attention
    position-free, so ANY leak of a previous occupant's rows changes the
    softmax and breaks exact token-identity with the oracle."""
    cfg = dataclasses.replace(get("qwen2-0.5b").reduced(), rope_theta=0.0)
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=2, max_seq=32, max_new_tokens=4,
                                 page_size=4, prefill_chunk=4))
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size,
                           size=rng.randint(5, 10)).astype(np.int32)
               for _ in range(8)]
    rids = [pe.submit(p) for p in prompts]
    res = pe.run()
    # total token traffic (prompts + outputs) well past max_seq
    assert sum(len(p) + 4 for p in prompts) > 2 * pe.cfg.max_seq
    oracle = ServingEngine(model, params,
                           ServeConfig(max_batch=1, max_seq=32,
                                       max_new_tokens=4))
    for rid, p in zip(rids, prompts):
        assert res[rid] == oracle.generate_batch([p])[0], \
            f"rid={rid}: read rows outside its own pages"


def test_paged_zero_recompiles(small_model):
    """The whole engine lifetime — admissions, mid-flight joins, stalls,
    partial grants, evictions — reuses the compiled cells, each compiled
    exactly once.  With the prefill lane ON the universe is the ragged
    prefill cell + the forced-free decode twin (the forced decode cell
    never runs: prompt traffic moved to the lane); with the lane OFF it is
    the legacy pair (forced decode + plain twin)."""
    model, params = small_model
    rng = np.random.RandomState(2)

    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=2, max_seq=48, max_new_tokens=4,
                                 page_size=4, num_pages=13,
                                 prefill_chunk=3))
    if not hasattr(pe._many, "_cache_size"):
        pytest.skip("jit cache-size introspection unavailable")
    for n in (3, 7, 5, 9, 4, 6):
        pe.submit(rng.randint(0, model.cfg.vocab_size,
                              size=n).astype(np.int32))
    pe.run()
    assert pe._prefill_lane._cache_size() == 1   # ragged prefill cell
    assert pe._many_plain._cache_size() == 1     # pure-decode twin
    assert pe._many._cache_size() == 0           # forced cell retired

    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=2, max_seq=48, max_new_tokens=4,
                                 page_size=4, num_pages=13,
                                 prefill_chunk=3, prefill_lane=False))
    for n in (3, 7, 5, 9, 4, 6):
        pe.submit(rng.randint(0, model.cfg.vocab_size,
                              size=n).astype(np.int32))
    pe.run()
    assert pe._prefill_lane._cache_size() == 0   # lane off: never compiled
    assert pe._many._cache_size() == 1           # legacy forced cell
    assert pe._many_plain._cache_size() <= 1


def test_steady_state_tick_uploads_zero_table_bytes(small_model):
    """REGRESSION (device-resident tick state): once a slot's prompt has
    drained and no allocation/COW/admission happens, an engine tick must
    upload ZERO table/length bytes and ZERO forced-token bytes — only the
    B-int feed/grant vectors move, and the tick is exactly one device
    dispatch (the fused decode cell)."""
    model, params = small_model
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=2, max_seq=64, max_new_tokens=12,
                                 page_size=32, prefill_chunk=2))
    pe.submit(np.arange(1, 5, dtype=np.int32))       # 4-token prompt
    while any(s.active and s.forced for s in pe.slots) or pe.queue:
        pe.step()                 # drain admission + chunked prefill
    pe.step()                     # settle residual dirty rows
    tb0, fb0 = pe.table_upload_bytes, pe.forced_upload_bytes
    d0 = pe.kv.cow_dispatches
    pe.step()                     # a pure steady-state decode tick
    assert pe.table_upload_bytes == tb0, "steady tick re-uploaded the table"
    assert pe.forced_upload_bytes == fb0, "steady tick built forced arrays"
    assert pe.kv.cow_dispatches == d0
    assert pe.dispatch_trace[-1] == 1        # just the fused decode cell
    assert pe.upload_trace[-1] == 2 * pe.cfg.max_batch * 4  # feed + grants


# ---------------------------------------------------------------------------
# paged engine: pallas path + fused-vs-stepwise + sampling discipline
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pps", [1, 2])
def test_paged_pallas_path_token_identical(small_model, pps):
    """Whole paged serving path with cfg.attention_impl='pallas' (paged
    kernel inside the layer scan inside decode_many_paged) vs the jnp
    gather-oracle path — including the multi-page blocking mode, which
    must be invisible in the tokens."""
    model, params = small_model
    model_pl = get_model(dataclasses.replace(model.cfg,
                                             attention_impl="pallas",
                                             pages_per_step=pps))
    sc = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=5, page_size=8,
                     prefill_chunk=3)
    prompts = _prompts(model, n=3)
    outs = []
    for m in (model, model_pl):
        pe = PagedEngine(m, params, sc)
        rids = [pe.submit(p) for p in prompts]
        res = pe.run()
        outs.append([res[r] for r in rids])
    assert outs[0] == outs[1]


def test_decode_many_paged_matches_stepwise_temperature(small_model):
    """The fused paged scan and a per-step decode_step_paged loop must be
    token-identical under seeded temperature sampling (same key-split
    discipline), including forced-token overrides."""
    from repro.models.model import sample_token
    model, params = small_model
    B, steps, page, nb, pool = 2, 5, 4, 4, 9
    forced = np.zeros((steps, B), np.int32)
    fmask = np.zeros((steps, B), bool)
    forced[0] = [7, 9]
    fmask[0] = [True, True]
    active = jnp.ones((B,), bool)
    tok0 = jnp.asarray([[3], [4]], jnp.int32)

    def fresh():
        cache = model.init_paged_cache(B, nb, page, pool)
        tbl = np.zeros((B, nb), np.int32)
        tbl[0] = [1, 2, 3, 4]
        tbl[1] = [5, 6, 7, 8]
        return dict(cache, table=jnp.asarray(tbl))

    key = jax.random.key(42)
    toks_f, cache_f, _ = model.decode_many_paged(
        params, tok0, fresh(), key, active, jnp.asarray(forced),
        jnp.asarray(fmask), num_steps=steps, temperature=0.8)

    cache = fresh()
    tok = tok0
    rows = []
    for s in range(steps):
        logits, cache = model.decode_step_paged(params, tok, cache, active)
        nxt, key = sample_token(logits, key, 0.8)
        nxt = jnp.where(jnp.asarray(fmask[s]), jnp.asarray(forced[s]), nxt)
        rows.append(np.asarray(nxt))
        tok = nxt[:, None]
    np.testing.assert_array_equal(np.asarray(toks_f), np.stack(rows))
    np.testing.assert_array_equal(np.asarray(cache_f["length"]),
                                  np.asarray(cache["length"]))
    assert list(np.asarray(cache_f["length"])) == [steps, steps]


def test_decode_many_paged_per_step_active(small_model):
    """A (num_steps, B) active mask packs PARTIAL chunks: a slot active for
    its first s steps advances exactly s tokens, its emitted stream is
    frozen from step s on (the host reads a stable value at any step >=
    s-1), and its tokens for the active prefix are identical to a full-
    chunk run."""
    model, params = small_model
    B, steps, page, nb, pool = 2, 4, 4, 3, 7

    def fresh():
        cache = model.init_paged_cache(B, nb, page, pool)
        tbl = np.zeros((B, nb), np.int32)
        tbl[0] = [1, 2, 3]
        tbl[1] = [4, 5, 6]
        return dict(cache, table=jnp.asarray(tbl))

    tok0 = jnp.asarray([[3], [4]], jnp.int32)
    key = jax.random.key(0)
    full, cache_full, _ = model.decode_many_paged(
        params, tok0, fresh(), key, jnp.ones((B,), bool), num_steps=steps)
    mask = np.ones((steps, B), bool)
    mask[2:, 1] = False                       # slot 1: only 2 of 4 steps
    part, cache_part, _ = model.decode_many_paged(
        params, tok0, fresh(), key, jnp.asarray(mask), num_steps=steps)
    full, part = np.asarray(full), np.asarray(part)
    np.testing.assert_array_equal(part[:, 0], full[:, 0])   # slot 0 untouched
    np.testing.assert_array_equal(part[:2, 1], full[:2, 1])  # active prefix
    assert all(int(t) == int(part[1, 1]) for t in part[2:, 1])  # frozen
    assert list(np.asarray(cache_part["length"])) == [steps, 2]


def test_decode_step_paged_inactive_slot_frozen(small_model):
    """An inactive slot must not advance its length and must not perturb
    any live page (its append lands on the null page 0)."""
    model, params = small_model
    B, page, nb, pool = 2, 4, 2, 5
    cache = model.init_paged_cache(B, nb, page, pool)
    tbl = np.zeros((B, nb), np.int32)
    tbl[0] = [1, 2]
    cache["table"] = jnp.asarray(tbl)
    cache["length"] = jnp.asarray([3, 0], jnp.int32)
    active = jnp.asarray([True, False])
    before_k = np.asarray(cache["k"])
    _, cache2 = jax.jit(model.decode_step_paged)(
        params, jnp.zeros((B, 1), jnp.int32), cache, active)
    assert list(np.asarray(cache2["length"])) == [4, 0]
    after_k = np.asarray(cache2["k"])
    np.testing.assert_array_equal(before_k[:, 2:], after_k[:, 2:])  # pages >= 2
    assert not np.array_equal(before_k[:, 1], after_k[:, 1])        # slot 0 wrote


# ---------------------------------------------------------------------------
# the fused decode cell is censusable (the PR's motivation)
# ---------------------------------------------------------------------------

def test_fused_decode_program_census(small_model):
    from repro.core.hlo_counters import census_from_compiled
    model, params = small_model
    B, T, steps = 2, 32, 4

    def fused(params, tok, cache, key):
        return model.decode_many(params, tok, cache, key, num_steps=steps)

    key = jax.random.key(0)
    compiled = jax.jit(fused).lower(
        model.abstract_params(), jax.ShapeDtypeStruct((B, 1), jnp.int32),
        model.abstract_cache(B, T),
        jax.ShapeDtypeStruct(key.shape, key.dtype)).compile()
    census = census_from_compiled(compiled)
    assert census.mxu_flops > 0
    assert census.total_instructions > 0
    # the token loop appears as a trip-counted while: per-layer matmul work
    # must scale with num_steps x n_layers, far above a single step's
    single = model.cfg.n_layers * 2 * model.cfg.d_model
    assert census.mxu_flops > single


def test_paged_decode_census_scales_with_live_tokens():
    """The roofline claim the paged cache exists to make measurable: the
    paged decode step's transaction count scales with LIVE tokens (block-
    table width), not with the pool / max_seq.  Two fills of each cache
    flavor, byte-count ratios asserted.  f32 config: the CPU backend wraps
    bf16 scatters in full-pool converts that would pollute the traffic
    model (TPU scatters natively)."""
    from repro.core.hlo_counters import census_from_compiled
    cfg = dataclasses.replace(get("qwen2-0.5b").reduced(), dtype="float32")
    model = get_model(cfg)
    B, page = 2, 16

    def paged(nb, pool):
        cache = model.abstract_paged_cache(B, nb, page, pool)
        compiled = jax.jit(lambda p, t, c: model.decode_step_paged(p, t, c),
                           donate_argnums=(2,)).lower(
            model.abstract_params(), jax.ShapeDtypeStruct((B, 1), jnp.int32),
            cache).compile()
        return census_from_compiled(compiled)

    def dense_cache(max_seq):
        cache = model.abstract_cache(B, max_seq)
        compiled = jax.jit(model.decode_step, donate_argnums=(2,)).lower(
            model.abstract_params(), jax.ShapeDtypeStruct((B, 1), jnp.int32),
            cache).compile()
        return census_from_compiled(compiled)

    p_small_pool = paged(2, 33)           # 2 live blocks, 512-row pool
    p_big_pool = paged(2, 65)             # 2 live blocks, 1024-row pool
    p_more_live = paged(8, 65)            # 8 live blocks, 1024-row pool
    d_512, d_1024 = dense_cache(512), dense_cache(1024)

    # fill 1 vs fill 2, paged: doubling the POOL moves zero extra bytes
    assert p_big_pool.hbm_bytes == p_small_pool.hbm_bytes
    assert p_big_pool.irregular_bytes == p_small_pool.irregular_bytes
    # more LIVE blocks do move more bytes (gather grows with the table)
    assert p_more_live.hbm_bytes > p_big_pool.hbm_bytes
    assert p_more_live.irregular_bytes > 3 * p_big_pool.irregular_bytes
    # fill 1 vs fill 2, dense: bytes track max_seq whether or not it is live
    assert d_1024.hbm_bytes > 1.5 * d_512.hbm_bytes
    # and at equal capacity the paged step moves a fraction of the dense one
    assert d_1024.hbm_bytes > 2 * p_big_pool.hbm_bytes


def test_paged_prefill_census_scales_with_chunk_and_live_tokens():
    """Mirror of ``test_paged_decode_census_scales_with_live_tokens`` for
    the ragged prefill lane: a prefill step's hbm_bytes scale with CHUNK
    tokens and LIVE pages (block-table width), never with the pool size —
    the kernel-level half of the lane's roofline claim.  f32 config: the
    CPU backend wraps bf16 scatters in full-pool converts that would
    pollute the traffic model (TPU scatters natively)."""
    from repro.core.hlo_counters import census_from_compiled
    cfg = dataclasses.replace(get("qwen2-0.5b").reduced(), dtype="float32")
    model = get_model(cfg)
    B, page = 2, 16

    def prefill(T, nb, pool):
        cache = model.abstract_paged_cache(B, nb, page, pool)
        compiled = jax.jit(
            lambda p, t, c, g: model.prefill_step_paged(p, t, c, g),
            donate_argnums=(2,)).lower(
            model.abstract_params(), jax.ShapeDtypeStruct((B, T), jnp.int32),
            cache, jax.ShapeDtypeStruct((B,), jnp.int32)).compile()
        return census_from_compiled(compiled)

    p_small_pool = prefill(8, 2, 33)      # 8-tok chunk, 2 blocks, 512-row pool
    p_big_pool = prefill(8, 2, 65)        # 8-tok chunk, 2 blocks, 1024-row pool
    p_more_live = prefill(8, 8, 65)       # 8-tok chunk, 8 blocks
    p_more_chunk = prefill(32, 8, 65)     # 32-tok chunk, 8 blocks

    # doubling the POOL moves zero extra bytes (chunk scatter + page
    # gather address only granted rows and live pages)
    assert p_big_pool.hbm_bytes == p_small_pool.hbm_bytes
    assert p_big_pool.irregular_bytes == p_small_pool.irregular_bytes
    # more LIVE blocks move more bytes (the gather grows with the table)
    assert p_more_live.hbm_bytes > p_big_pool.hbm_bytes
    # more CHUNK tokens move more bytes (scatter + attention grow with T)
    assert p_more_chunk.hbm_bytes > 1.5 * p_more_live.hbm_bytes


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_cow_page_copy_census_scales_with_pages(dtype):
    """The COW page copy's census bytes scale with the pages COPIED, never
    with the pool — standalone (the engine's jitted copy) and with the
    copy fused into an append step.  bf16 exercises the dtype-bracket
    elision: the CPU backend wraps the in-place update in whole-pool
    converts that would otherwise charge 3x the pool per copy (TPU updates
    the storage dtype natively).  int8 pins the quantized page pools: the
    CPU backend scatters s8 natively (no brackets), and the page-wise
    accounting must survive at 1-byte granularity."""
    from repro.core.hlo_counters import census_from_compiled
    from repro.serve.cache import _copy_pages
    L, page, KV, hd = 4, 16, 2, 16

    def census(P, n):
        pool = jax.ShapeDtypeStruct((L, P, page, KV, hd), dtype)
        idx = jax.ShapeDtypeStruct((n,), jnp.int32)
        compiled = jax.jit(_copy_pages, donate_argnums=(0,)).lower(
            pool, idx, idx).compile()
        return census_from_compiled(compiled)

    page_bytes = L * page * KV * hd * jnp.dtype(dtype).itemsize
    page_f32 = L * page * KV * hd * 4       # compute-dtype page (CPU widens)
    c2_small, c2_big = census(33, 2), census(65, 2)
    c3, c4 = census(65, 3), census(65, 4)
    # pool-size independence: doubling the pool moves zero extra bytes
    assert c2_big.hbm_bytes == c2_small.hbm_bytes
    # page scaling: the batched-COW claim — bytes == pages_copied x
    # page_bytes regardless of the batch size the tick collected
    assert c3.hbm_bytes == pytest.approx(1.5 * c2_big.hbm_bytes, rel=0.01)
    assert c4.hbm_bytes == pytest.approx(2 * c2_big.hbm_bytes, rel=0.01)
    assert c4.irregular_bytes == pytest.approx(2 * c2_big.irregular_bytes,
                                               rel=0.01)
    # absolute sanity: a handful of page-moves per copied page (the
    # fusion-boundary model counts this lowering's intermediate page
    # materializations), nowhere near the 33-page pool per copy
    assert c2_big.hbm_bytes < 2 * 12 * page_f32
    assert c2_big.hbm_bytes >= 2 * 2 * page_bytes      # read src + write dst

    # in-fusion: the copy composed with an append into the private page
    # stays page-scaled and pool-independent
    def cow_append(pool, dst, src, kv_new, row):
        pool = _copy_pages(pool, dst, src)
        return pool.at[:, dst[0], row].set(kv_new)

    def fused_census(P):
        pool = jax.ShapeDtypeStruct((L, P, page, KV, hd), dtype)
        idx = jax.ShapeDtypeStruct((1,), jnp.int32)
        kvn = jax.ShapeDtypeStruct((L, KV, hd), dtype)
        row = jax.ShapeDtypeStruct((), jnp.int32)
        compiled = jax.jit(cow_append, donate_argnums=(0,)).lower(
            pool, idx, idx, kvn, row).compile()
        return census_from_compiled(compiled)

    f_small, f_big = fused_census(33), fused_census(65)
    assert f_big.hbm_bytes == f_small.hbm_bytes
    assert f_big.hbm_bytes < 12 * page_f32


def test_cow_bytes_zero_without_shared_writes(small_model):
    """The engine-level half of the COW accounting claim: a workload that
    never writes a shared page (sharing disabled entirely) performs ZERO
    copy-on-write traffic, and a shared-prefix workload's COW bytes equal
    copies x page_bytes exactly."""
    model, params = small_model
    rng = np.random.RandomState(1)
    common = rng.randint(0, model.cfg.vocab_size, size=6).astype(np.int32)
    # STAGGERED tails: sharing matches live slots only, so request
    # lifetimes must overlap for a donor to exist at admission time
    prompts = [np.concatenate([common,
                               rng.randint(0, model.cfg.vocab_size,
                                           size=n).astype(np.int32)])
               for n in (3, 6, 2, 5)]
    for sharing in (False, True):
        # prefill chunk pinned to one page so prompt drains stay slow
        # enough for request lifetimes to overlap (sharing needs a donor
        # still LIVE when the next request is admitted)
        pe = PagedEngine(model, params,
                         ServeConfig(max_batch=2, max_seq=32,
                                     max_new_tokens=3, page_size=4,
                                     prefill_chunk=3,
                                     prefill_chunk_tokens=4,
                                     prefix_sharing=sharing))
        # budgets staggered too: equal budgets + equal chunked-prefill
        # tick counts would finish both donors in the same tick, leaving
        # no live donor for the later admissions
        for j, p in enumerate(prompts):
            pe.submit(p, 3 + 2 * (j % 2))
        pe.run()
        if sharing:
            assert pe.shared_tokens > 0
            assert pe.kv.cow_bytes == pe.kv.cow_copies * pe.kv.page_bytes
        else:
            assert pe.kv.cow_copies == 0 and pe.kv.cow_bytes == 0
            assert pe.shared_tokens == 0


# ---------------------------------------------------------------------------
# stream _grid fallback (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,block,expect", [
    (24, 64, 24), (100, 64, 50), (8, 8, 8), (7, 8, 7), (256, 64, 64),
])
def test_stream_block_rows_fallback(rows, block, expect):
    from repro.kernels.stream.stream import _block_rows
    assert _block_rows(rows, block) == expect
    assert rows % _block_rows(rows, block) == 0


def test_stream_odd_rows_no_crash():
    from repro.kernels.stream import ref, stream
    a = jax.random.normal(jax.random.key(0), (24, 128), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (24, 128), jnp.float32)
    got = stream.add(a, b, block_rows=64, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.add(a, b)),
                               rtol=1e-6)
