"""Overload & failure-semantics harness for the paged serving engine.

The happy-path property harness (tests/test_paged_cache_props.py) fuzzes
schedules the pool can absorb; THIS module drives the engine past its
capacity on purpose and asserts the overload machinery keeps every
guarantee:

  * NO DEADLOCK, NO CRASH — oversubscribed schedules (requests >> pool,
    bursty submits, injected faults) terminate in bounded ticks; the
    legacy "page pool exhausted" RuntimeError is unreachable with
    ``preempt=True`` (the default) for any admissible workload;
  * TYPED TERMINALITY — every submitted rid ends in a terminal
    ``RequestStatus`` (FINISHED | PREEMPTED_RESUMED | REJECTED |
    CANCELLED | DEADLINE_EXCEEDED), never a hang;
  * RECOMPUTE IDENTITY — a preempted-then-resumed request's output is
    EXACTLY token-identical to the same request run uninterrupted on the
    dense-cache oracle, including under injected faults (sampled
    positions unembed at f32, so the old bf16 near-tie escape hatch is
    retired);
  * POOL SAFETY — ``PagedKVCache.check()`` holds after every tick
    (including the cross-lifetime retained-pool partition), and a drained
    engine holds zero live pages and zero refcounts; flushing the
    retained pool restores the full free list, squeeze or no squeeze.

Fault schedules come from ``serve/faults.py`` — deterministic, seeded,
replayable (the seed is in every assertion message via the test id).
"""
import numpy as np
import jax
import pytest

from repro.configs import get
from repro.models import get_model
from repro.serve.engine import (PagedEngine, RequestStatus, ServeConfig,
                                ServingEngine, TERMINAL_STATUSES)
from repro.serve.faults import FaultEvent, FaultPlan
from repro.serve.scheduler import TickScheduler

from test_paged_cache_props import (_assert_drained_clean,
                                    _assert_tokens_identical, _check_tick,
                                    _seeded_repro)

BUDGETS = (3, 5)
PROMPT_LENS = (3, 5, 8)


@pytest.fixture(scope="module")
def harness():
    cfg = get("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    oracle = ServingEngine(model, params,
                           ServeConfig(max_batch=1, max_seq=64,
                                       max_new_tokens=max(BUDGETS)))
    return model, params, oracle


@pytest.fixture(scope="module")
def draft(harness):
    """1-layer slice of the harness target as a (deliberately weak) draft
    model — speculation correctness must not depend on accept rate."""
    import dataclasses as _dc
    model, params, _ = harness
    dcfg = _dc.replace(model.cfg, n_layers=1)
    dparams = dict(params)
    dparams["blocks"] = jax.tree.map(lambda x: x[:1], params["blocks"])
    return get_model(dcfg), dparams


def _drain(pe, max_ticks=2000):
    """Drain the engine with a hard tick bound (a wedge fails the test,
    not the CI wall clock), then ride out any still-squeezed pages."""
    t = 0
    while pe.busy:
        pe.step()
        t += 1
        assert t < max_ticks, "engine failed to terminate (wedged?)"
    while pe._squeezed:
        pe.step()
        t += 1
        assert t < max_ticks + 100
    return t


# ---------------------------------------------------------------------------
# the formerly-crashing schedule (ISSUE regression)
# ---------------------------------------------------------------------------

def test_formerly_crashing_schedule_completes(harness):
    """REGRESSION for the engine.py pool-exhausted crash: two requests
    that each fit the pool alone but jointly wedge it used to raise
    RuntimeError mid-run; with preempt-and-recompute (the default) the
    same schedule completes, at least one request is PREEMPTED_RESUMED,
    and BOTH outputs are token-identical to uninterrupted runs."""
    model, params, oracle = harness
    rng = np.random.RandomState(0)
    p1 = rng.randint(0, model.cfg.vocab_size, size=3).astype(np.int32)
    p2 = rng.randint(0, model.cfg.vocab_size, size=3).astype(np.int32)
    sc = ServeConfig(max_batch=2, max_seq=8, page_size=4, num_pages=3,
                     prefill_chunk=2, max_new_tokens=4)
    pe = PagedEngine(model, params, sc)
    r1, r2 = pe.submit(p1, 4), pe.submit(p2, 4)
    res = pe.run()                         # used to raise right here
    assert pe.preemptions >= 1
    assert pe.recompute_tokens > 0
    statuses = {pe.status[r1], pe.status[r2]}
    assert statuses <= TERMINAL_STATUSES
    assert RequestStatus.PREEMPTED_RESUMED in statuses
    for rid, p in ((r1, p1), (r2, p2)):
        _assert_tokens_identical(
            res[rid], oracle.generate_batch([p], max_new_tokens=4)[0],
            label=f"rid={rid} preempt-resume vs uninterrupted")
    pe.kv.check()
    assert pe.kv.live_pages == 0


def test_forced_eviction_recompute_identical(harness):
    """A fault-injected eviction mid-decode requeues the victim with its
    emitted output; the resumed run must be bit-identical."""
    model, params, oracle = harness
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, model.cfg.vocab_size, size=5).astype(np.int32)
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=2, max_seq=32, page_size=4, prefill_chunk=2,
        max_new_tokens=5))
    rid = pe.submit(prompt, 5)
    pe.install_faults(FaultPlan([FaultEvent(3, "evict", slot=0),
                                 FaultEvent(6, "evict", slot=0)]))
    res = pe.run()
    assert pe.status[rid] is RequestStatus.PREEMPTED_RESUMED
    assert pe.preemptions >= 1
    _assert_tokens_identical(
        res[rid], oracle.generate_batch([prompt], max_new_tokens=5)[0],
        label="forced-eviction resume")


# ---------------------------------------------------------------------------
# lifecycle: deadlines, cancels, queue bounds, policy validation
# ---------------------------------------------------------------------------

def test_deadline_exceeded_keeps_partial_output(harness):
    model, params, oracle = harness
    rng = np.random.RandomState(2)
    prompt = rng.randint(0, model.cfg.vocab_size, size=3).astype(np.int32)
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=1, max_seq=32, page_size=4, prefill_chunk=1,
        prefill_lane=False, max_new_tokens=8))
    rid = pe.submit(prompt, 8, deadline_ticks=5)
    res = pe.run()
    assert pe.status[rid] is RequestStatus.DEADLINE_EXCEEDED
    assert pe.deadline_exceeded == 1
    got = res[rid]
    assert 0 < len(got) < 8                # partial, not empty, not full
    want = oracle.generate_batch([prompt], max_new_tokens=8)[0]
    _assert_tokens_identical(got, want[:len(got)],
                             label="deadline partial prefix")


def test_queued_deadline_expires_without_running(harness):
    """A request whose deadline passes while it WAITS terminates with
    empty output — the queue cannot hold a corpse forever."""
    model, params, _ = harness
    rng = np.random.RandomState(3)
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=1, max_seq=32, page_size=4, max_new_tokens=6))
    long_p = rng.randint(0, model.cfg.vocab_size, size=8).astype(np.int32)
    a = pe.submit(long_p, 6)               # hogs the only slot
    b = pe.submit(long_p[:3], 6, deadline_ticks=1)
    res = pe.run()
    assert pe.status[a] is RequestStatus.FINISHED
    assert pe.status[b] is RequestStatus.DEADLINE_EXCEEDED
    assert res[b] == []


def test_cancel_queued_and_running(harness):
    model, params, _ = harness
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, model.cfg.vocab_size, size=5).astype(np.int32)
               for _ in range(3)]
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=1, max_seq=32, page_size=4, prefill_chunk=1,
        max_new_tokens=6))
    a, b, c = (pe.submit(p, 6) for p in prompts)
    for _ in range(3):
        pe.step()                          # a is running, b/c queued
    assert pe.status[a] is RequestStatus.RUNNING
    assert pe.cancel(a)                    # cancel RUNNING: frees the slot
    assert pe.cancel(b)                    # cancel QUEUED
    assert not pe.cancel(b)                # already terminal: no-op
    assert not pe.cancel(999)              # unknown rid
    res = pe.run()
    assert pe.status[a] is RequestStatus.CANCELLED
    assert pe.status[b] is RequestStatus.CANCELLED
    assert pe.status[c] is RequestStatus.FINISHED
    assert pe.cancelled == 2
    assert res[b] == []
    pe.kv.check()
    assert pe.kv.live_pages == 0


def test_max_queue_bounds_admission(harness):
    model, params, _ = harness
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=1, max_seq=32, page_size=4, max_new_tokens=4,
        max_queue=2))
    p = np.arange(1, 4, dtype=np.int32)
    rids = [pe.submit(p, 4) for _ in range(4)]
    assert pe.status[rids[0]] is RequestStatus.QUEUED
    assert pe.status[rids[1]] is RequestStatus.QUEUED
    for rid in rids[2:]:
        assert pe.status[rid] is RequestStatus.REJECTED
        assert "queue full" in pe.reject_reason[rid]
    assert pe.rejected == 2
    pe.run()                               # the two admitted ones drain
    assert all(pe.status[r] in TERMINAL_STATUSES for r in rids)


def test_preempt_policy_validation(harness):
    model, params, _ = harness
    with pytest.raises(ValueError, match="preempt policy"):
        PagedEngine(model, params, ServeConfig(
            max_batch=1, max_seq=16, preempt_policy="coin-flip"))


def test_pick_victim_policies():
    """Victim selection is pure bookkeeping — pin both policies on a
    synthetic slot/pool state (no model needed)."""
    class S:                               # minimal slot stand-in
        def __init__(self, active, out):
            self.active, self.out = active, out

    class KV:
        owned = [[1, 2, 3], [4], [5, 6], []]

    slots = [S(True, [0, 0]), S(True, [0]), S(True, [0]), S(False, [])]
    fewest = TickScheduler(preempt_policy="fewest-tokens")
    # fewest tokens: slots 1 and 2 tie at 1 token; most pages breaks the
    # tie toward slot 2 (2 pages vs 1)
    assert fewest.pick_victim(slots, KV()) == 2
    most = TickScheduler(preempt_policy="most-pages")
    assert most.pick_victim(slots, KV()) == 0      # 3 pages held
    assert fewest.pick_victim(slots, KV(), exclude=(1, 2)) == 0
    assert fewest.pick_victim([S(False, [])], KV()) == -1


# ---------------------------------------------------------------------------
# targeted faults
# ---------------------------------------------------------------------------

def test_poison_quarantines_and_resumes(harness):
    """A poisoned tick (out-of-vocab sampled tokens) must never leak into
    results: the slot is quarantined, the request resumes elsewhere/later
    and still finishes token-identical."""
    model, params, oracle = harness
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, model.cfg.vocab_size, size=3).astype(np.int32)
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=2, max_seq=32, page_size=4, prefill_chunk=2,
        max_new_tokens=5, quarantine_ticks=2))
    rid = pe.submit(prompt, 5)
    # tick 1 drains the 3-token prompt (lane) and samples output 1; poison
    # tick 2, a pure-decode tick, so the garbage hits kept tokens
    pe.install_faults(FaultPlan([FaultEvent(2, "poison", slot=0)]))
    res = pe.run()
    assert pe.quarantines == 1
    assert pe.status[rid] is RequestStatus.PREEMPTED_RESUMED
    vocab = model.cfg.vocab_size
    assert all(0 <= t < vocab for t in res[rid])   # no garbage leaked
    _assert_tokens_identical(
        res[rid], oracle.generate_batch([prompt], max_new_tokens=5)[0],
        label="poison-quarantine resume")


def test_poison_under_speculation_quarantines(harness, draft):
    """SPECULATIVE ticks keep up to k+1 verified tokens at once; a
    poisoned verify window must be caught in FULL — the guard inspects
    every kept token, quarantines the slot, requeues with the pre-tick
    output, and the resumed request still finishes bit-identical to the
    plain-decode oracle."""
    model, params, oracle = harness
    dm, dp = draft
    rng = np.random.RandomState(8)
    prompt = rng.randint(0, model.cfg.vocab_size, size=3).astype(np.int32)
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=2, max_seq=32, page_size=4, prefill_chunk=2,
        max_new_tokens=5, quarantine_ticks=2, spec_k=3),
        draft_model=dm, draft_params=dp)
    rid = pe.submit(prompt, 5)
    # tick 1 drains the 3-token prompt (lane) and samples output 1; tick 2
    # is the first draft-and-verify tick, so the poison garbages a whole
    # multi-token verify window, not a single sampled token
    pe.install_faults(FaultPlan([FaultEvent(2, "poison", slot=0)]))
    res = pe.run()
    assert pe.quarantines == 1
    assert pe.status[rid] is RequestStatus.PREEMPTED_RESUMED
    vocab = model.cfg.vocab_size
    assert all(0 <= t < vocab for t in res[rid])   # none of the window leaked
    _assert_tokens_identical(
        res[rid], oracle.generate_batch([prompt], max_new_tokens=5)[0],
        label="poison-under-speculation resume")
    pe.kv.check()
    pe.dkv.check()
    assert pe.kv.live_pages == 0


def test_squeeze_starves_then_recovers(harness):
    """Pool pressure that seizes most of the free list forces idle ticks
    or preemptions but never wedges: pages release on schedule, the
    engine drains, and the pool partition (incl. the seized set) holds
    every tick."""
    model, params, oracle = harness
    rng = np.random.RandomState(6)
    prompts = [rng.randint(0, model.cfg.vocab_size, size=5).astype(np.int32)
               for _ in range(3)]
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=2, max_seq=24, page_size=4, num_pages=7, prefill_chunk=2,
        max_new_tokens=5))
    rids = [pe.submit(p, 5) for p in prompts]
    pe.install_faults(FaultPlan([
        FaultEvent(2, "squeeze", pages=4, duration=5),
        FaultEvent(4, "squeeze", pages=2, duration=3)]))
    t = 0
    while pe.busy:
        pe.step()
        _check_tick(pe)                    # partition holds under seizure
        t += 1
        assert t < 500
    while pe._squeezed:
        pe.step()
    assert pe.fault_counts.get("squeeze") == 2
    assert not pe.kv.seized
    pe.kv.check()
    _assert_drained_clean(pe)
    for rid, p in zip(rids, prompts):
        assert pe.status[rid] in (RequestStatus.FINISHED,
                                  RequestStatus.PREEMPTED_RESUMED)
        _assert_tokens_identical(
            pe.results[rid],
            oracle.generate_batch([p], max_new_tokens=5)[0],
            label=f"squeeze rid={rid}")


def test_dropped_grant_is_retried(harness):
    """A dropped grant loses a tick's work, not the request: the engine
    re-grants next tick and the output is unchanged."""
    model, params, oracle = harness
    rng = np.random.RandomState(7)
    prompt = rng.randint(0, model.cfg.vocab_size, size=5).astype(np.int32)
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=1, max_seq=32, page_size=4, prefill_chunk=2,
        max_new_tokens=5))
    rid = pe.submit(prompt, 5)
    pe.install_faults(FaultPlan([FaultEvent(1, "drop", slot=-1),
                                 FaultEvent(3, "drop", slot=0)]))
    res = pe.run()
    assert pe.dropped_grants > 0
    assert pe.status[rid] is RequestStatus.FINISHED
    _assert_tokens_identical(
        res[rid], oracle.generate_batch([prompt], max_new_tokens=5)[0],
        label="dropped-grant retry")


def test_cancel_races_preemption_same_tick(harness):
    """cancel() landing in the SAME tick window as a forced eviction: the
    victim is preempted (requeued at the queue front, possibly re-admitted
    within the very same tick) and then cancelled before the engine runs
    again.  Exactly ONE terminal transition must happen — CANCELLED, never
    flipped to PREEMPTED_RESUMED by the stale queue entry — the partial
    output must be an oracle prefix with no token double-counted across
    the preempt's emitted-extend and the cancel's, and no page may leak."""
    model, params, oracle = harness
    rng = np.random.RandomState(11)
    prompts = [rng.randint(0, model.cfg.vocab_size, size=n).astype(np.int32)
               for n in (8, 5)]
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=2, max_seq=48, page_size=4, num_pages=8,
        prefill_chunk=3, max_new_tokens=5))
    rids = [pe.submit(p, 5) for p in prompts]
    victim = rids[0]                       # slot 0 holds the first admit
    # evict at tick 2: the victim has decoded at most a few tokens, so
    # its recompute (8-token prompt + emitted as forced prefill) cannot
    # finish inside the eviction tick — the race window provably exists
    pe.install_faults(FaultPlan([FaultEvent(2, "evict", slot=0)]))
    raced = False
    ticks = 0
    while pe.busy:
        pre = pe.preemptions
        pe.step()
        _check_tick(pe)
        if not raced and pe.preemptions > pre:
            # the race: the evict just requeued (or re-admitted) the
            # victim this tick; cancel it before the engine moves again
            assert pe.status[victim] not in TERMINAL_STATUSES
            assert pe.cancel(victim) is True
            assert pe.cancel(victim) is False, \
                "second cancel observed a non-terminal status"
            raced = True
        ticks += 1
        assert ticks < 200
    assert raced, "evict fault never fired"
    # exactly one terminal transition, stable through the drain
    assert pe.status[victim] is RequestStatus.CANCELLED
    assert pe.cancelled == 1
    assert all(not (s.active and s.rid == victim) for s in pe.slots)
    assert all(r.rid != victim for r in pe.queue)
    # no leaked pages: pool partition exact after full drain
    pe.kv.check()
    _assert_drained_clean(pe)
    # victim output: oracle PREFIX, no duplicated tokens from the
    # preempt/cancel double emitted-extend window
    want = oracle.generate_batch([prompts[0]], max_new_tokens=5)[0]
    got = pe.results[victim]
    assert len(got) < len(want), "victim finished: the race never happened"
    assert len(got) <= len(want)
    _assert_tokens_identical(got, want[:len(got)], label="cancel-race victim")
    # the bystander finishes oracle-identical
    assert pe.status[rids[1]] in (RequestStatus.FINISHED,
                                  RequestStatus.PREEMPTED_RESUMED)
    _assert_tokens_identical(
        pe.results[rids[1]],
        oracle.generate_batch([prompts[1]], max_new_tokens=5)[0],
        label="cancel-race bystander")


# ---------------------------------------------------------------------------
# oversubscription fuzz: requests >> pool x deadlines x cancels x faults
# ---------------------------------------------------------------------------

def _overload_fuzz(model, params, oracle, seed, *, with_faults,
                   spec=None, extra_events=()):
    """Seeded-repro wrapper: assertion failures out of the fuzz body carry
    ``[repro: schedule_seed=N fault_seed=M]`` — the schedule seed and fault
    seed are the same value here, but both are named so a failure message
    states exactly how to rebuild BOTH the schedule and the plan."""
    with _seeded_repro(schedule_seed=seed,
                       fault_seed=seed if with_faults else None):
        return _overload_fuzz_impl(model, params, oracle, seed,
                                   with_faults=with_faults, spec=spec,
                                   extra_events=extra_events)


def _overload_fuzz_impl(model, params, oracle, seed, *, with_faults,
                        spec=None, extra_events=()):
    """One seeded oversubscribed schedule.  Pool: 7 allocatable pages
    (28 tokens); load: 10 requests of up to 13 tokens each, submitted in
    bursts, 30% carrying tight deadlines, ~15% cancelled mid-flight,
    optionally under a random fault plan.  Asserts termination, per-tick
    pool invariants, typed terminality for every rid, leak-freedom after
    drain, and EXACT output identity for every request that ran to
    completion (sampled positions unembed at f32, so paged and oracle
    argmax agree bit-for-bit).

    ``spec=(k, draft_model, draft_params)`` runs the whole schedule on a
    SPECULATIVE engine — the draft pool shares the same tiny page budget,
    so draft-stall partial catch-up and k=0 verify ticks get exercised
    alongside the faults.  ``extra_events`` appends hand-placed faults to
    the random plan (e.g. guaranteed poison ticks)."""
    rng = np.random.RandomState(seed)
    cfg = model.cfg
    spec_k, dm, dp = spec if spec else (0, None, None)
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=3, max_seq=48, page_size=4, num_pages=8,
        prefill_chunk=3, max_new_tokens=max(BUDGETS), spec_k=spec_k),
        draft_model=dm, draft_params=dp)
    if with_faults:
        plan = FaultPlan.random(seed, n_events=5, max_tick=25,
                                max_batch=3, max_pages=3,
                                max_duration=4)
        pe.install_faults(FaultPlan(list(plan.events) + list(extra_events)))
    submitted = {}
    pending = [(rng.randint(0, cfg.vocab_size,
                            size=rng.choice(PROMPT_LENS)).astype(np.int32),
                int(rng.choice(BUDGETS)),
                int(rng.randint(4, 25)) if rng.rand() < 0.3 else 0)
               for _ in range(10)]
    ticks = 0
    while pending or pe.busy:
        # bursty submit: dump a few requests at once, then starve
        if pending and (ticks % 5 == 0 or not pe.busy):
            for _ in range(min(len(pending), rng.randint(2, 5))):
                p, b, dl = pending.pop()
                submitted[pe.submit(p, b, deadline_ticks=dl)] = (p, b)
        if rng.rand() < 0.15 and submitted:
            victim = int(rng.choice(sorted(submitted)))
            pe.cancel(victim)              # False on terminal rids: fine
        if pe.busy:
            pe.step()
            _check_tick(pe)
        ticks += 1
        assert ticks < 1500, f"seed={seed}: schedule failed to terminate"
    while pe._squeezed:
        pe.step()
        _check_tick(pe)
    # leak-freedom after drain (retained prefixes of finished requests
    # legitimately outlive them; flushing restores the whole pool)
    pe.kv.check()
    assert not pe.kv.seized
    _assert_drained_clean(pe)
    if pe.dkv is not None:
        pe.dkv.check()                     # draft pool partition too
    # typed terminality for EVERY rid ever submitted
    for rid in submitted:
        assert pe.status[rid] in TERMINAL_STATUSES, \
            f"seed={seed} rid={rid}: non-terminal {pe.status[rid]}"
        assert rid in pe.results
    # output identity for completed requests (incl. preempted-resumed,
    # incl. under faults); partial outputs must be an oracle PREFIX
    for rid, (p, b) in submitted.items():
        got = pe.results[rid]
        st = pe.status[rid]
        if st is RequestStatus.REJECTED:
            assert got == []
            continue
        want = oracle.generate_batch([p], max_new_tokens=b)[0]
        if st in (RequestStatus.FINISHED, RequestStatus.PREEMPTED_RESUMED):
            _assert_tokens_identical(got, want,
                                     label=f"seed={seed} rid={rid} ({st})")
        else:                              # cancelled / deadline: prefix
            assert len(got) <= len(want)
            _assert_tokens_identical(got, want[:len(got)],
                                     label=f"seed={seed} rid={rid} ({st})")
    return pe


@pytest.mark.parametrize("seed", [0, 1])
def test_oversubscription_fuzz(harness, seed):
    model, params, oracle = harness
    pe = _overload_fuzz(model, params, oracle, seed, with_faults=False)
    assert pe.preemptions + pe.deadline_exceeded + pe.cancelled > 0, \
        "schedule never stressed the overload machinery"


@pytest.mark.parametrize("seed", [2, 3])
def test_oversubscription_fuzz_with_faults(harness, seed):
    model, params, oracle = harness
    _overload_fuzz(model, params, oracle, seed, with_faults=True)


@pytest.mark.parametrize("seed", [17])
def test_oversubscription_fuzz_speculative(harness, draft, seed):
    """Fuzz seed exercising poison-under-speculation: the random fault
    plan (squeeze/evict/drop/poison) runs against a SPECULATIVE engine,
    with two hand-placed poison events guaranteed to land on live ticks —
    quarantine must absorb a garbaged multi-token verify window without
    leaking a single token, and every completed request stays bit-
    identical to the plain-decode oracle."""
    model, params, oracle = harness
    dm, dp = draft
    pe = _overload_fuzz(model, params, oracle, seed, with_faults=True,
                        spec=(3, dm, dp),
                        extra_events=(FaultEvent(3, "poison", slot=-1),
                                      FaultEvent(8, "poison", slot=-1)))
    assert pe.fault_counts.get("poison", 0) >= 1, \
        "poison never fired under speculation"
    assert pe.quarantines >= 1


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(4, 16)))
def test_oversubscription_fuzz_long(harness, seed):
    model, params, oracle = harness
    _overload_fuzz(model, params, oracle, seed,
                   with_faults=bool(seed % 2))
