"""Training substrate tests: optimizer math, checkpoint/restart (incl.
corruption), data-pipeline determinism + straggler reassignment, gradient
compression error-feedback, elastic batch replanning."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.optim.adamw import AdamW, AdamWConfig
from repro.optim.grad_compress import compress, decompress, ef_step
from repro.optim.schedule import cosine_with_warmup, linear_warmup
from repro.train import checkpoint as ckpt
from repro.train.elastic import replan_batch
from repro.train.straggler import StragglerConfig, StragglerMonitor


# --- AdamW ------------------------------------------------------------------

def _ref_adamw_step(p, g, m, v, step, lr, b1, b2, eps, wd):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** step)
    vh = v / (1 - b2 ** step)
    return p - lr * (mh / (np.sqrt(vh) + eps) + wd * p), m, v


def test_adamw_matches_reference():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
                      grad_clip_norm=None)
    opt = AdamW(cfg)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]], jnp.float32)}
    state = opt.init(p)
    new_p, new_state, _ = opt.update(g, state, p)

    ref_p, ref_m, ref_v = _ref_adamw_step(
        np.asarray(p["w"]), np.asarray(g["w"]),
        np.zeros((2, 2)), np.zeros((2, 2)), 1, 1e-2, 0.9, 0.99, 1e-8, 0.01)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref_p, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(new_state["m"]["w"]), ref_m,
                               rtol=1e-5)


def test_adamw_grad_clip():
    cfg = AdamWConfig(lr=1e-2, grad_clip_norm=1.0, weight_decay=0.0)
    opt = AdamW(cfg)
    p = {"w": jnp.zeros((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0)}
    state = opt.init(p)
    _, _, metrics = opt.update(g, state, p)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


@pytest.mark.parametrize("moment_dtype,tol", [
    ("float32", 0.05), ("bfloat16", 0.08),
    # int8 moments dither within ~2 lr of the optimum (quantization noise),
    # but must not diverge
    ("int8", 0.3),
])
def test_adamw_moment_dtypes_converge(moment_dtype, tol):
    """Quadratic bowl: every moment precision must reach the optimum."""
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip_norm=None,
                      moment_dtype=moment_dtype)
    opt = AdamW(cfg)
    p = {"w": jnp.asarray(np.linspace(-2, 2, 512), jnp.float32)}
    state = opt.init(p)
    for _ in range(150):
        g = {"w": 2.0 * p["w"]}
        p, state, _ = opt.update(g, state, p)
    assert float(jnp.max(jnp.abs(p["w"]))) < tol


def test_schedules():
    lw = linear_warmup(1.0, 10)
    assert float(lw(jnp.asarray(5))) == pytest.approx(0.5)
    cw = cosine_with_warmup(1.0, 10, 100, min_ratio=0.1)
    assert float(cw(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-5)


# --- checkpointing ------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
             "b": {"c": jnp.ones((2,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, state, {"data_step": 7})
    got = ckpt.restore(str(tmp_path), state)
    assert got is not None
    step, restored, extra = got
    assert step == 7 and extra["data_step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]))


def test_checkpoint_skips_partial(tmp_path):
    state = {"a": jnp.ones((2, 2))}
    ckpt.save(str(tmp_path), 5, state)
    ckpt.save(str(tmp_path), 9, state)
    # corrupt step 9: remove COMMIT
    os.remove(os.path.join(str(tmp_path), "step_000000009", "COMMIT"))
    got = ckpt.restore(str(tmp_path), state)
    assert got is not None and got[0] == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), {"a": jnp.ones((3, 3))})


def test_async_checkpointer(tmp_path):
    c = ckpt.AsyncCheckpointer()
    state = {"a": jnp.ones((64, 64))}
    c.save(str(tmp_path), 3, state)
    c.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


# --- data pipeline --------------------------------------------------------------

def test_pipeline_deterministic_and_restartable():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8)
    p1 = SyntheticTokenPipeline(cfg)
    p2 = SyntheticTokenPipeline(cfg)
    b1 = p1.batch_at(42)
    b2 = p2.batch_at(42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch_at(43)["tokens"], b1["tokens"])
    # labels are next tokens
    assert b1["labels"].shape == (8, 16)


def test_pipeline_host_sharding_disjoint():
    kw = dict(vocab_size=1000, seq_len=8, global_batch=8, num_hosts=4)
    batches = [SyntheticTokenPipeline(
        DataConfig(host_index=h, **kw)).batch_at(0)["tokens"]
        for h in range(4)]
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(batches[i], batches[j])


def test_pipeline_straggler_reassign():
    kw = dict(vocab_size=1000, seq_len=8, global_batch=8, num_hosts=4)
    slow = SyntheticTokenPipeline(DataConfig(host_index=2, **kw))
    spare = SyntheticTokenPipeline(DataConfig(host_index=3, **kw))
    spare.reassign(slow_host=2, spare_host=3)
    np.testing.assert_array_equal(spare.batch_at(10)["tokens"],
                                  slow.batch_at(10)["tokens"])


def test_pipeline_prefetch_iterator():
    cfg = DataConfig(vocab_size=100, seq_len=4, global_batch=2)
    p = SyntheticTokenPipeline(cfg)
    it = p.iterator(start_step=5)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], p.batch_at(5)["tokens"])
    p.close()


# --- straggler monitor -----------------------------------------------------------

def test_straggler_flagging():
    mon = StragglerMonitor(4, StragglerConfig(alpha=1.0, threshold=1.5,
                                              patience=2))
    base = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    assert mon.update(base) == []
    slow = {**base, 2: 5.0}
    assert mon.update(slow) == []          # strike 1
    assert mon.update(slow) == [2]         # strike 2 -> flagged
    mon.reset(2)
    assert mon.update(base) == []


# --- gradient compression ---------------------------------------------------------

def test_compress_roundtrip_accuracy():
    x = jnp.asarray(np.random.RandomState(0).randn(1000), jnp.float32)
    q, s = compress(x)
    y = decompress(q, s, x.shape)
    assert float(jnp.max(jnp.abs(x - y))) < float(jnp.max(jnp.abs(x))) / 100


def test_error_feedback_unbiased():
    """EF invariant: sum of dequantized grads ~= sum of true grads."""
    rng = np.random.RandomState(1)
    residual = jnp.zeros((512,), jnp.float32)
    total_true = np.zeros((512,))
    total_sent = np.zeros((512,))
    for _ in range(50):
        g = jnp.asarray(rng.randn(512) * 0.1, jnp.float32)
        q, s, residual, deq = ef_step(g, residual)
        total_true += np.asarray(g)
        total_sent += np.asarray(deq)
    # residual bounds the cumulative error
    np.testing.assert_allclose(total_sent + np.asarray(residual), total_true,
                               rtol=1e-4, atol=1e-4)


# --- elastic -----------------------------------------------------------------------

def test_replan_batch_constant_global():
    for world in (2, 4, 8, 16):
        plan = replan_batch(256, world, max_per_shard=16)
        assert plan.per_step_batch == 256
        assert plan.per_shard_batch <= 16
