"""Crash-consistency properties for serve/snapshot.py.

The snapshot subsystem serializes the COMPLETE paged serving state —
both page pools verbatim (bf16 raw, int8 + per-row scales), block
tables, free list, refcounts, pending COW reservations, the retained
pool, scheduler queues, per-request lifecycle state and RNG keys — so a
killed engine can be rebuilt from disk and finish EXACTLY the run it
was going to produce.  This module asserts that headline end to end:

  * ROUNDTRIP — restore(save(engine)) and the original engine, both
    drained to completion, emit bit-identical tokens with identical
    terminal statuses;
  * KILL-AND-RECOVER — a seeded fuzz drives an engine under a random
    recoverable fault plan (squeeze/evict/drop/poison) PLUS injected
    ``kill`` events; every kill is recovered by restoring the latest
    on-disk snapshot, resubmitting the not-yet-snapshotted requests
    (rids realign deterministically) and re-arming the plan with the
    fired kills filtered out.  The recovered run's outputs and statuses
    must be bit-identical to an uninterrupted oracle engine driven by
    the same schedule and the same recoverable plan — across int8
    pools, speculation, prefix sharing on/off, and cold recovery (kill
    before the first snapshot lands);
  * ATOMICITY — a truncated snapshot file is detected by checksum
    (``SnapshotCorruptError``) and ``latest_snapshot`` falls back to
    the previous intact file, so a crash DURING a snapshot write can
    never poison recovery;
  * TYPED MISMATCH — restoring into an engine whose architecture or
    serving geometry differs from the snapshot's fingerprint raises
    ``SnapshotMismatchError`` naming every differing field;
  * WEDGE DETECTOR — ``ServeConfig.wedge_ticks`` bounds consecutive
    idle-but-busy ticks, and ``no_progress_ticks`` surfaces the count.

Explicit seeded fuzz loops (no hypothesis in the container image);
assertion messages carry ``[repro: schedule_seed=N fault_seed=M]``.
"""
import dataclasses
import os

import numpy as np
import jax
import pytest

from repro.configs import get
from repro.models import get_model
from repro.serve.engine import (PagedEngine, RequestStatus, ServeConfig,
                                TERMINAL_STATUSES)
from repro.serve.faults import (EngineKilled, FaultEvent, FaultPlan,
                                RECOVERABLE_KINDS)
from repro.serve import snapshot as snap

from test_paged_cache_props import (_assert_drained_clean,
                                    _assert_tokens_identical, _check_tick,
                                    _seeded_repro)

PROMPT_LENS = (3, 5, 8)
BUDGETS = (3, 5)
MAX_TICKS = 3000


@pytest.fixture(scope="module")
def harness():
    cfg = get("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


@pytest.fixture(scope="module")
def int8_harness(harness):
    """Same weights, int8 page pools: kv_dtype only changes the paged
    cache, so the bf16 harness params transfer verbatim."""
    model, params = harness
    icfg = dataclasses.replace(model.cfg, kv_dtype="int8")
    return get_model(icfg), params


@pytest.fixture(scope="module")
def int8_draft(int8_harness):
    """1-layer slice of the int8 target as the draft — the draft pool is
    quantized too, so the snapshot carries int8 pages + scales for BOTH
    pools."""
    model, params = int8_harness
    dcfg = dataclasses.replace(model.cfg, n_layers=1)
    dparams = dict(params)
    dparams["blocks"] = jax.tree.map(lambda x: x[:1], params["blocks"])
    return get_model(dcfg), dparams


# ---------------------------------------------------------------------------
# deterministic driver: submissions gated on the engine's OWN tick counter,
# so a restored engine replays the exact schedule the dead one was running
# ---------------------------------------------------------------------------

def _make_items(model, seed, n=8, last_tick=18):
    """The schedule: ``[(at_tick, prompt, budget), ...]`` submitted in
    order when the engine's tick counter reaches ``at_tick``.  Pure
    function of the seed — both the oracle run and every recovery replay
    rebuild it identically."""
    rng = np.random.RandomState(seed)
    ats = sorted(int(t) for t in rng.randint(0, last_tick, size=n))
    return [(at,
             rng.randint(0, model.cfg.vocab_size,
                         size=int(rng.choice(PROMPT_LENS))).astype(np.int32),
             int(rng.choice(BUDGETS)))
            for at in ats]


def _run_schedule(pe, items):
    """Drive the engine to completion, submitting ``items`` when their
    tick gate passes.  ``pe._next_rid`` doubles as the submission cursor:
    rids are sequential from 0 (no admission rejection in these configs),
    so after a restore the cursor lands exactly on the first request the
    snapshot does NOT contain and the replay resubmits from there."""
    t = 0
    while True:
        while pe._next_rid < len(items) and items[pe._next_rid][0] <= pe.ticks:
            want_rid = pe._next_rid
            _, p, b = items[want_rid]
            assert pe.submit(p, b) == want_rid, "rid realignment broke"
        if not pe.busy and pe._next_rid >= len(items) and not pe._squeezed:
            return
        pe.step()
        _check_tick(pe)
        t += 1
        assert t < MAX_TICKS, "schedule failed to terminate"


def _drive_with_recovery(mk_engine, items, plan, snap_dir):
    """The recovery protocol under test: on ``EngineKilled``, restore the
    newest intact snapshot into a FRESH engine (or start cold if none
    landed yet), re-arm the plan with fired kills filtered out, and keep
    driving.  Returns (engine, kills, restores)."""
    pe = mk_engine()
    if plan is not None:
        pe.install_faults(plan)
    kills = restores = 0
    while True:
        try:
            _run_schedule(pe, items)
            return pe, kills, restores
        except EngineKilled as e:
            kills += 1
            assert kills < 10, "kill storm: recovery never converged"
            latest = snap.latest_snapshot(snap_dir)
            pe = mk_engine()
            if latest is not None:
                snap.restore_engine(pe, latest)
                restores += 1
            plan = plan.without_kills_through(e.tick)
            pe.install_faults(plan)


def _assert_runs_identical(got, want, label):
    """Full bit-identity between two drained engines: same rid universe,
    same terminal status per rid, same tokens per rid."""
    assert set(got.results) == set(want.results), f"{label}: rid sets differ"
    for rid in sorted(want.results):
        gs, ws = got.status[rid], want.status[rid]
        assert gs in TERMINAL_STATUSES and gs is ws, \
            f"{label} rid={rid}: status {gs} vs oracle {ws}"
        _assert_tokens_identical(got.results[rid], want.results[rid],
                                 label=f"{label} rid={rid}")


def _mk(model, params, snap_dir, *, every=2, spec=None,
        prefix_sharing=True):
    spec_k, dm, dp = spec if spec else (0, None, None)
    return PagedEngine(model, params, ServeConfig(
        max_batch=3, max_seq=48, page_size=4, num_pages=8,
        prefill_chunk=3, max_new_tokens=max(BUDGETS), spec_k=spec_k,
        prefix_sharing=prefix_sharing,
        snapshot_every_ticks=every if snap_dir else 0,
        snapshot_dir=snap_dir or ""),
        draft_model=dm, draft_params=dp)


def _kill_restore_case(model, params, seed, snap_dir, *, spec=None,
                       prefix_sharing=True, kill_ticks=(8,),
                       with_faults=True, every=2):
    """One seeded kill-and-recover drill vs its uninterrupted oracle."""
    with _seeded_repro(schedule_seed=seed,
                       fault_seed=seed if with_faults else None):
        items = _make_items(model, seed)
        recoverable = (FaultPlan.random(seed, n_events=4, max_tick=20,
                                        max_batch=3, max_pages=3,
                                        max_duration=3,
                                        kinds=RECOVERABLE_KINDS).events
                       if with_faults else ())
        oracle, _, _ = _drive_with_recovery(
            lambda: _mk(model, params, None, spec=spec,
                        prefix_sharing=prefix_sharing),
            items, FaultPlan(list(recoverable)), snap_dir)
        plan = FaultPlan(list(recoverable)
                         + [FaultEvent(t, "kill") for t in kill_ticks])
        pe, kills, restores = _drive_with_recovery(
            lambda: _mk(model, params, snap_dir, every=every, spec=spec,
                        prefix_sharing=prefix_sharing),
            items, plan, snap_dir)
        assert kills == len(kill_ticks), "a scheduled kill never fired"
        _assert_runs_identical(pe, oracle, f"seed={seed}")
        pe.kv.check()
        if pe.dkv is not None:
            pe.dkv.check()
        _assert_drained_clean(pe)
        return pe, restores


# ---------------------------------------------------------------------------
# roundtrip: restore(save(engine)) continues bit-identically
# ---------------------------------------------------------------------------

def test_snapshot_roundtrip_continue_identical(harness, tmp_path):
    """Mid-flight snapshot, then BOTH the original engine and a fresh
    restore drain to completion: tokens and statuses bit-identical, and
    the restored pool passes every per-tick invariant on the way."""
    model, params = harness
    items = _make_items(model, seed=42, n=5, last_tick=1)
    pe = _mk(model, params, None)
    for _, p, b in items:
        pe.submit(p, b)
    for _ in range(4):
        pe.step()
        _check_tick(pe)
    path = snap.snapshot_path(str(tmp_path), pe.ticks)
    snap.save_snapshot(pe, path)
    assert os.path.exists(path)

    fresh = _mk(model, params, None)
    snap.restore_engine(fresh, path)
    assert fresh.ticks == pe.ticks
    assert fresh._next_rid == pe._next_rid
    _check_tick(fresh)                     # pool sane immediately on restore

    _run_schedule(pe, items)
    _run_schedule(fresh, items)
    _assert_runs_identical(fresh, pe, "roundtrip")
    _assert_drained_clean(fresh)


# ---------------------------------------------------------------------------
# kill-and-recover fuzz: int8 x speculation x prefix sharing x fault plans
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_kill_restore_fuzz(harness, tmp_path, seed):
    """bf16, prefix sharing on, random recoverable plan + one kill late
    enough that a snapshot exists — recovery must restore from disk (not
    just cold-start) and still match the oracle bit for bit."""
    model, params = harness
    _, restores = _kill_restore_case(model, params, seed, str(tmp_path),
                                     kill_ticks=(9,))
    assert restores == 1, "kill fired but recovery never restored a snapshot"


def test_kill_restore_int8_speculative(int8_harness, int8_draft, tmp_path):
    """The hard quadrant: int8 target AND draft pools (pages + per-row
    scales snapshotted verbatim), speculation in flight, double kill —
    the second kill lands on the RESTORED engine, so recovery must be
    re-entrant."""
    model, params = int8_harness
    dm, dp = int8_draft
    pe, restores = _kill_restore_case(model, params, 5, str(tmp_path),
                                      spec=(2, dm, dp),
                                      kill_ticks=(7, 13))
    assert pe.kv.quantized and pe.dkv.quantized
    assert restores >= 1


def test_kill_restore_no_prefix_sharing(harness, tmp_path):
    """Sharing off: the restored prefix index must stay empty instead of
    being rebuilt from histories, and recovery still matches the oracle."""
    model, params = harness
    _kill_restore_case(harness[0], harness[1], 3, str(tmp_path),
                       prefix_sharing=False, kill_ticks=(8,))


def test_kill_before_first_snapshot_cold_recovery(harness, tmp_path):
    """Kill at tick 1 with snapshot cadence 50: no snapshot exists, so
    recovery cold-starts a fresh engine and resubmits EVERYTHING — the
    degenerate case must still be oracle-identical."""
    model, params = harness
    _, restores = _kill_restore_case(model, params, 7, str(tmp_path),
                                     kill_ticks=(1,), with_faults=False,
                                     every=50)
    assert restores == 0, "no snapshot could exist, yet restore ran"


@pytest.mark.slow
@pytest.mark.parametrize("seed", list(range(8, 16)))
def test_kill_restore_fuzz_long(harness, tmp_path, seed):
    model, params = harness
    _kill_restore_case(model, params, seed, str(tmp_path),
                       kill_ticks=(int(5 + seed % 9),),
                       with_faults=bool(seed % 2))


# ---------------------------------------------------------------------------
# atomicity: truncation is detected, recovery falls back
# ---------------------------------------------------------------------------

def test_truncated_snapshot_detected_and_skipped(harness, tmp_path):
    """A crash mid-write leaves either no file (atomic rename) or — if
    the filesystem is ruder — a short/garbled one.  Every truncation
    point must raise ``SnapshotCorruptError`` on load, and
    ``latest_snapshot`` must fall back to the previous intact file."""
    model, params = harness
    pe = _mk(model, params, None)
    for _, p, b in _make_items(model, seed=9, n=3, last_tick=1):
        pe.submit(p, b)
    for _ in range(2):
        pe.step()
    good = snap.snapshot_path(str(tmp_path), 1)
    newer = snap.snapshot_path(str(tmp_path), 2)
    snap.save_snapshot(pe, good)
    snap.save_snapshot(pe, newer)
    assert snap.latest_snapshot(str(tmp_path)) == newer

    blob = open(newer, "rb").read()
    # representative truncation points: inside the magic, the header,
    # the state JSON, and the raw array bytes (checksum tail cut off)
    for cut in (4, 24, len(blob) // 2, len(blob) - 3):
        with open(newer, "wb") as f:
            f.write(blob[:cut])
        with pytest.raises(snap.SnapshotCorruptError):
            snap.load_snapshot(newer)
        assert snap.latest_snapshot(str(tmp_path)) == good, \
            f"truncation at byte {cut} not skipped"
    # the fallback file actually restores
    fresh = _mk(model, params, None)
    snap.restore_engine(fresh, good)
    assert fresh.ticks == pe.ticks


def test_prune_keeps_newest(harness, tmp_path):
    model, params = harness
    pe = _mk(model, params, None)
    pe.submit(np.arange(3, dtype=np.int32), 3)
    pe.step()
    for t in (1, 2, 3, 4):
        snap.save_snapshot(pe, snap.snapshot_path(str(tmp_path), t))
    removed = snap.prune_snapshots(str(tmp_path), keep=2)
    assert [os.path.basename(r) for r in removed] == \
        ["snap-00000001.bin", "snap-00000002.bin"]
    assert sorted(os.listdir(tmp_path)) == \
        ["snap-00000003.bin", "snap-00000004.bin"]


# ---------------------------------------------------------------------------
# fingerprint mismatch: typed, named fields
# ---------------------------------------------------------------------------

def test_fingerprint_mismatch_typed(harness, tmp_path):
    model, params = harness
    pe = _mk(model, params, None)
    pe.submit(np.arange(3, dtype=np.int32), 3)
    pe.step()
    path = snap.snapshot_path(str(tmp_path), pe.ticks)
    snap.save_snapshot(pe, path)
    other = PagedEngine(model, params, ServeConfig(
        max_batch=2, max_seq=64, page_size=4, num_pages=8,
        prefill_chunk=3, max_new_tokens=max(BUDGETS)))
    with pytest.raises(snap.SnapshotMismatchError) as ei:
        snap.restore_engine(other, path)
    msg = str(ei.value)
    assert "max_batch" in msg and "max_seq" in msg


# ---------------------------------------------------------------------------
# wedge detector: configurable threshold, surfaced counter
# ---------------------------------------------------------------------------

def test_wedge_ticks_configurable(harness):
    """A squeeze that outlives any admissible progress trips the wedge
    detector after ``wedge_ticks`` consecutive idle-but-busy ticks — at
    the CONFIGURED threshold, not the 10k default — and the
    ``no_progress_ticks`` counter records the idle span."""
    model, params = harness
    pe = PagedEngine(model, params, ServeConfig(
        max_batch=1, max_seq=32, page_size=4, num_pages=4,
        prefill_chunk=2, max_new_tokens=3, wedge_ticks=5))
    pe.submit(np.arange(5, dtype=np.int32), 3)
    pe.install_faults(FaultPlan([FaultEvent(1, "squeeze", pages=3,
                                            duration=500)]))
    with pytest.raises(RuntimeError, match="wedged"):
        for _ in range(50):
            pe.step()
    assert pe.no_progress_ticks >= 5
