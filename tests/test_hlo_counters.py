"""Census correctness against compiled XLA programs with known costs.

These compile tiny jitted functions on the single CPU device (and an 8-host
device subprocess-free collective case is covered in test_sharding.py) and
assert the parsed flops / bytes / issues / trip-count handling match
hand-computed values.
"""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_counters import (
    Census, Shape, census_from_compiled, classify, parse_module,
    parse_shapes)


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


# ---------------------------------------------------------------------------
# unit parsing
# ---------------------------------------------------------------------------

def test_parse_shapes_simple():
    (s,) = parse_shapes("bf16[32,256]{1,0}")
    assert s.dtype == "bf16" and s.dims == (32, 256)
    assert s.bytes == 32 * 256 * 2


def test_parse_shapes_tuple_and_scalar():
    shapes = parse_shapes("(f32[2,3]{1,0}, s32[], pred[7])")
    assert [s.dtype for s in shapes] == ["f32", "s32", "pred"]
    assert shapes[1].dims == ()
    assert shapes[2].bytes == 7


def test_vreg_padding():
    # (8,128) exactly one vreg
    assert Shape("f32", (8, 128)).padded_vreg_issues() == 1
    # minor dims padded: (1,1) still one issue
    assert Shape("f32", (1, 1)).padded_vreg_issues() == 1
    # (16, 200): 2 sublanes-groups x 2 lane-groups
    assert Shape("f32", (16, 200)).padded_vreg_issues() == 4
    # leading dims multiply
    assert Shape("f32", (3, 8, 128)).padded_vreg_issues() == 3
    # rank-1
    assert Shape("f32", (257,)).padded_vreg_issues() == 3


def test_classify():
    assert classify("dot") == "mxu"
    assert classify("all-reduce") == "collective"
    assert classify("all-reduce-start") == "collective"
    assert classify("copy") == "layout"
    assert classify("gather") == "irregular"
    assert classify("add") == "vpu"
    assert classify("while") == "flow"
    assert classify("parameter") == "none"


# ---------------------------------------------------------------------------
# compiled-program census
# ---------------------------------------------------------------------------

def test_matmul_flops_exact():
    M, K, N = 128, 256, 512

    def f(a, b):
        return a @ b

    compiled = _compile(f, jax.ShapeDtypeStruct((M, K), jnp.float32),
                        jax.ShapeDtypeStruct((K, N), jnp.float32))
    census = census_from_compiled(compiled)
    assert census.mxu_flops == pytest.approx(2 * M * K * N)
    # aligned shapes: exact tile count, no padding waste
    assert census.mxu_issues == (M // 128) * (N // 128) * (K // 128)
    assert census.mxu_flops_padded == pytest.approx(census.mxu_flops)
    # bytes: read a + b, write out (fusion-boundary model)
    expect = 4 * (M * K + K * N + M * N)
    assert census.hbm_bytes == pytest.approx(expect, rel=0.05)


def test_matmul_padding_waste_visible():
    """head_dim-64-style contraction: FLOP census halves, issue census does
    not — the padding-efficiency readout must expose it."""
    def f(a, b):
        return a @ b

    compiled = _compile(f, jax.ShapeDtypeStruct((128, 64), jnp.float32),
                        jax.ShapeDtypeStruct((64, 128), jnp.float32))
    census = census_from_compiled(compiled)
    assert census.mxu_issues == 1          # one padded pass
    assert census.mxu_flops == pytest.approx(2 * 128 * 64 * 128)
    assert census.mxu_flops / census.mxu_flops_padded == pytest.approx(0.5)


def test_scan_trip_count_scaling():
    """cost_analysis counts a while body once; the census must scale by the
    known_trip_count backend config."""
    L, D = 7, 64

    def body(h, w):
        return jnp.tanh(h @ w), None

    def f(h, ws):
        h, _ = jax.lax.scan(body, h, ws)
        return h

    compiled = _compile(f, jax.ShapeDtypeStruct((D, D), jnp.float32),
                        jax.ShapeDtypeStruct((L, D, D), jnp.float32))
    census = census_from_compiled(compiled)
    assert census.mxu_flops == pytest.approx(L * 2 * D * D * D)
    # XLA's own analysis sees one iteration:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):         # older jax: one dict per device
        ca = ca[0]
    assert ca["flops"] < census.mxu_flops / 2


def test_scan_weight_bytes_slice_aware():
    """Each scan iteration must charge one layer's weights, not the whole
    stacked buffer (slice-aware fusion reads)."""
    L, D = 10, 128

    def body(h, w):
        return jnp.tanh(h @ w), None

    def f(h, ws):
        h, _ = jax.lax.scan(body, h, ws)
        return h

    compiled = _compile(f, jax.ShapeDtypeStruct((D, D), jnp.float32),
                        jax.ShapeDtypeStruct((L, D, D), jnp.float32))
    census = census_from_compiled(compiled)
    weights_once = L * D * D * 4
    # total traffic should be O(L * (one-layer-slice + activations)) — about
    # 5.4 MB here — far below charging L x the full stacked buffer (>10 MB)
    assert census.hbm_bytes < 9 * weights_once
    assert census.hbm_bytes > weights_once          # reads each layer once


def test_elementwise_census():
    N = 8 * 128 * 4

    def f(a, b):
        return a * b + 1.0

    compiled = _compile(f, jax.ShapeDtypeStruct((N,), jnp.float32),
                        jax.ShapeDtypeStruct((N,), jnp.float32))
    census = census_from_compiled(compiled)
    assert census.mxu_flops == 0
    assert census.vpu_flops >= 2 * N                # mul + add
    assert census.hbm_bytes >= 3 * N * 4            # 2 reads 1 write


def test_reduce_census():
    def f(a):
        return a.sum()

    compiled = _compile(f, jax.ShapeDtypeStruct((64, 256), jnp.float32))
    census = census_from_compiled(compiled)
    assert census.vpu_flops >= 64 * 256
    assert census.scalar_ops >= 0


def test_census_total_instructions_positive():
    def f(a, b):
        return jnp.dot(a, b).sum()

    compiled = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                        jax.ShapeDtypeStruct((32, 32), jnp.float32))
    census = census_from_compiled(compiled)
    assert census.total_instructions > 0
    assert census.mxu_issues == 1


# ---------------------------------------------------------------------------
# synthetic-text collectives (real multi-device case in test_sharding.py)
# ---------------------------------------------------------------------------

SYNTH = """
HloModule synth, is_scheduled=true, entry_computation_layout={(f32[128,128])->f32[128,128]}, num_partitions=8

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main_spmd (param: f32[128,128]) -> f32[128,128] {
  %param = f32[128,128]{1,0} parameter(0)
  %ar = f32[128,128]{1,0} all-reduce(%param), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add
  %ag = f32[128,128]{1,0} all-gather(%ar), channel_id=2, replica_groups=[4,2]<=[8], dimensions={0}, use_global_device_ids=true
  ROOT %cp = f32[128,128]{1,0} collective-permute(%ag), channel_id=3, source_target_pairs={{0,1},{1,0}}
}
"""


SYNTH_S8_BRACKET = """
HloModule synth_s8, is_scheduled=true, entry_computation_layout={(s8[4,64,16],f32[1,1,16],s32[])->s8[4,64,16]}

ENTRY %main (pool: s8[4,64,16], row: f32[1,1,16], i: s32[]) -> s8[4,64,16] {
  %pool = s8[4,64,16]{2,1,0} parameter(0)
  %row = f32[1,1,16]{2,1,0} parameter(1)
  %i = s32[] parameter(2)
  %c0 = s32[] constant(0)
  %up = f32[4,64,16]{2,1,0} convert(%pool)
  %dus = f32[4,64,16]{2,1,0} dynamic-update-slice(%up, %row, %c0, %i, %c0)
  ROOT %down = s8[4,64,16]{2,1,0} convert(%dus)
}
"""

SYNTH_S8_ONEWAY = """
HloModule synth_s8_oneway, is_scheduled=true, entry_computation_layout={(s8[4,64,16],f32[1,1,16],s32[])->f32[4,64,16]}

ENTRY %main (pool: s8[4,64,16], row: f32[1,1,16], i: s32[]) -> f32[4,64,16] {
  %pool = s8[4,64,16]{2,1,0} parameter(0)
  %row = f32[1,1,16]{2,1,0} parameter(1)
  %i = s32[] parameter(2)
  %c0 = s32[] constant(0)
  %up = f32[4,64,16]{2,1,0} convert(%pool)
  ROOT %dus = f32[4,64,16]{2,1,0} dynamic-update-slice(%up, %row, %c0, %i, %c0)
}
"""


def test_s8_dtype_bracket_elision_matched_pair():
    """The dtype-bracket matcher is narrow-dtype generic: an s8->f32
    upcast straight off a parameter paired with a same-shape f32->s8
    downcast at the root (the shape a backend without native s8 scatter
    would emit around a quantized-pool update) is elided — BOTH converts,
    nothing else."""
    from repro.core.hlo_counters import (_dtype_bracket_elisions,
                                         parse_module)
    comps, entry = parse_module(SYNTH_S8_BRACKET)
    elide = _dtype_bracket_elisions(comps[entry], comps)
    assert elide == {"up", "down"}
    # and the census actually drops their whole-pool bytes: only the
    # update slice + row traffic remains, not 2x the f32 pool
    from repro.core.hlo_counters import census_from_text
    census = census_from_text(SYNTH_S8_BRACKET)
    pool_f32 = 4 * 64 * 16 * 4
    assert census.hbm_bytes < 2 * pool_f32


def test_s8_one_way_cast_still_counted():
    """A genuine one-way s8->f32 upcast (dequantization for compute, no
    same-shape downcast partner) must STAY counted — eliding it would hide
    real dequant traffic from the quantized-pool byte model."""
    from repro.core.hlo_counters import (_dtype_bracket_elisions,
                                         census_from_text, parse_module)
    comps, entry = parse_module(SYNTH_S8_ONEWAY)
    assert _dtype_bracket_elisions(comps[entry], comps) == set()
    census = census_from_text(SYNTH_S8_ONEWAY)
    pool_s8 = 4 * 64 * 16
    # the convert reads the s8 pool and writes the f32 copy at minimum
    assert census.hbm_bytes >= 5 * pool_s8


def test_int8_pool_update_census_pool_independent():
    """Compiled-program regression for the quantized append: an in-place
    int8 row update (the quantize write path's pool op) moves bytes
    independent of the POOL size on this backend — whether the lowering
    scatters s8 natively (CPU today) or brackets in converts (elided)."""
    def upd(pool, row, p):
        return pool.at[0, p, 3].set(row)

    def census(P):
        pool = jax.ShapeDtypeStruct((2, P, 8, 2, 16), jnp.int8)
        row = jax.ShapeDtypeStruct((2, 16), jnp.int8)
        c = jax.jit(upd, donate_argnums=(0,)).lower(
            pool, row, jax.ShapeDtypeStruct((), jnp.int32)).compile()
        return census_from_compiled(c)

    small, big = census(33), census(65)
    assert big.hbm_bytes == small.hbm_bytes
    assert small.hbm_bytes < 2 * 8 * 2 * 16 * 4   # a page's worth, not a pool


def test_synthetic_collective_census():
    from repro.core.hlo_counters import census_from_text
    census = census_from_text(SYNTH)
    b = 128 * 128 * 4
    ar = census.collectives["all-reduce"]
    ag = census.collectives["all-gather"]
    cp = census.collectives["collective-permute"]
    assert ar.count == 1 and ag.count == 1 and cp.count == 1
    assert ar.wire_bytes == pytest.approx(2 * b * 3 / 4)   # group size 4
    assert ag.wire_bytes == pytest.approx(b * 1 / 2)       # group size 2
    assert cp.wire_bytes == pytest.approx(b)
    assert census.collective_wire_bytes == pytest.approx(
        2 * b * 3 / 4 + b / 2 + b)
