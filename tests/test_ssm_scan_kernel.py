"""Pallas selective-scan kernel vs the jnp sequential scan oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ssm_scan.scan import selective_scan_fwd
from repro.models.ssm import mamba1_scan


def _mk(B, S, d_in, N, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    x = jax.random.normal(ks[0], (B, S, d_in), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d_in)) * 0.5 - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (d_in, N)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cc = jax.random.normal(jax.random.key(seed + 1), (B, S, N), jnp.float32)
    return x, dt, A, Bc, Cc


@pytest.mark.parametrize("B,S,d_in,N,bd,c", [
    (1, 64, 128, 16, 128, 32),
    (2, 128, 256, 16, 128, 64),
    (1, 96, 128, 8, 128, 32),
])
def test_kernel_matches_scan(B, S, d_in, N, bd, c):
    x, dt, A, Bc, Cc = _mk(B, S, d_in, N)
    y_ref, h_ref = mamba1_scan(x, dt, A, Bc, Cc, chunk=c)
    y, h = selective_scan_fwd(x, dt, A, Bc, Cc, block_d=bd, chunk=c,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-5, atol=2e-5)


def test_kernel_state_carries_across_chunks():
    """Same data, different chunk decomposition -> identical output (the
    VMEM state must survive chunk boundaries)."""
    x, dt, A, Bc, Cc = _mk(1, 128, 128, 16, seed=7)
    y1, h1 = selective_scan_fwd(x, dt, A, Bc, Cc, block_d=128, chunk=128,
                                interpret=True)
    y2, h2 = selective_scan_fwd(x, dt, A, Bc, Cc, block_d=128, chunk=32,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(B=st.integers(1, 2), nc=st.integers(1, 3),
       c=st.sampled_from([16, 32]), N=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**30))
def test_kernel_property(B, nc, c, N, seed):
    S = nc * c
    x, dt, A, Bc, Cc = _mk(B, S, 128, N, seed=seed)
    y_ref, h_ref = mamba1_scan(x, dt, A, Bc, Cc, chunk=c)
    y, h = selective_scan_fwd(x, dt, A, Bc, Cc, block_d=128, chunk=c,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-5, atol=3e-5)
