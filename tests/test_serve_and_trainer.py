"""Integration tests: serving engine generation and trainer
checkpoint/restart determinism."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get
from repro.data.pipeline import DataConfig
from repro.models import get_model
from repro.optim.adamw import AdamWConfig
from repro.serve.engine import ServeConfig, ServingEngine
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def small_model():
    cfg = get("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def test_engine_greedy_deterministic(small_model):
    model, params = small_model
    eng = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=6,
                      temperature=0.0)
    engine = ServingEngine(model, params, eng)
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, model.cfg.vocab_size, size=7).astype(np.int32),
               rng.randint(0, model.cfg.vocab_size, size=11).astype(np.int32)]
    a = engine.generate_batch(prompts)
    b = engine.generate_batch(prompts)
    assert a == b
    assert all(len(o) == 6 for o in a)
    assert all(0 <= t < model.cfg.vocab_size for o in a for t in o)


def test_engine_decode_matches_incremental_forward(small_model):
    """Greedy engine output must equal naive re-forward generation."""
    model, params = small_model
    cfg = model.cfg
    rng = np.random.RandomState(5)
    prompt = rng.randint(0, cfg.vocab_size, size=9).astype(np.int32)

    engine = ServingEngine(model, params,
                           ServeConfig(max_batch=1, max_seq=32,
                                       max_new_tokens=4, temperature=0.0))
    fast = engine.generate_batch([prompt])[0]

    # naive: re-run full prefill each step, take argmax
    from repro.models import transformer as T
    toks = list(prompt)
    slow = []
    for _ in range(4):
        tk = jnp.asarray(np.asarray(toks)[None], jnp.int32)
        pos = jnp.broadcast_to(jnp.arange(tk.shape[1])[None], tk.shape)
        logits, _, _ = jax.jit(
            lambda p, t, po: T.lm_forward(p, cfg, t, po, mode="train")
        )(params, tk, pos)
        nxt = int(jnp.argmax(logits[0, -1]))
        slow.append(nxt)
        toks.append(nxt)
    assert fast == slow


def test_trainer_restart_resumes(tmp_path):
    cfg = get("qwen2-0.5b").reduced()
    model = get_model(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    opt = AdamWConfig(lr=1e-3)

    # run 1: 6 steps, checkpoint every 3, synchronous saves
    t1 = Trainer(model, opt, data, TrainerConfig(
        steps=6, checkpoint_every=3, checkpoint_dir=str(tmp_path),
        log_every=1000, async_checkpoint=False, seed=7))
    out1 = t1.run()

    # run 2: restart from checkpoint at step 6, continue to 9
    t2 = Trainer(model, opt, data, TrainerConfig(
        steps=9, checkpoint_every=3, checkpoint_dir=str(tmp_path),
        log_every=1000, async_checkpoint=False, seed=7))
    out2 = t2.run()
    assert len(out2["losses"]) == 3            # resumed at 6, ran 6..8

    # run 3 (control): 9 straight steps from scratch, no checkpoints
    t3 = Trainer(model, opt, data, TrainerConfig(
        steps=9, checkpoint_dir=None, log_every=1000, seed=7))
    out3 = t3.run()

    # the resumed trajectory must match the straight-through one
    np.testing.assert_allclose(out2["losses"], out3["losses"][6:],
                               rtol=2e-3, atol=2e-3)


def test_trainer_loss_decreases():
    cfg = get("qwen2-0.5b").reduced()
    model = get_model(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    tr = Trainer(model, AdamWConfig(lr=3e-3), data,
                 TrainerConfig(steps=25, checkpoint_dir=None, log_every=1000))
    out = tr.run()
    assert out["last_loss"] < out["first_loss"]
