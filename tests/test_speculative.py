"""Speculative decoding (draft-and-verify) tests.

The contract under test: with ``spec_k > 0`` and a draft model, every
decode tick proposes up to k tokens from the DRAFT's own paged cache and
verifies the ragged [feed, p_1..p_k] block in ONE target prefill-lane
dispatch — and the emitted greedy stream is BIT-IDENTICAL to plain
(non-speculative) decode, whatever the draft proposes.  Covered edges:

  * k = 0 accepted — a pure-reject tick still advances by the bonus token;
  * all-k accepted + bonus — draft == target makes every proposal match,
    so each tick emits k+1 tokens and the draft carries a 1-token deficit
    (the sampled-but-never-appended p_k) absorbed by forced replay;
  * draft proposing EOS mid-window — the accepted EOS finishes the slot
    mid-chunk and overshoot tokens are discarded;
  * preempt-and-recompute MID-SPECULATION — the victim requeues, replays
    through the prefill lane, the draft cache rebuilds by catch-up, and
    the output stays bit-identical to the uninterrupted oracle;
  * rejection TRUNCATION — target and draft lengths roll back to the
    accepted frontier and every cache invariant survives (``check()``);
  * the verify cell's device-side accept reduction (unit level);
  * compiled-cell discipline — draft + verify cells each compile once.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get
from repro.models import get_model
from repro.serve.engine import PagedEngine, ServeConfig


@pytest.fixture(scope="module")
def target():
    cfg = get("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


@pytest.fixture(scope="module")
def draft(target):
    """A 1-layer slice of the target: a REAL small model sharing the
    target's tokenizer (embed/ln_f/unembed) — proposals are plausible but
    mostly rejected, exercising truncation and bonus-token progress."""
    model, params = target
    dcfg = dataclasses.replace(model.cfg, n_layers=1)
    dparams = dict(params)
    dparams["blocks"] = jax.tree.map(lambda x: x[:1], params["blocks"])
    return get_model(dcfg), dparams


def _prompts(model, n=4, seed=3):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, model.cfg.vocab_size, size=ln).astype(np.int32)
            for ln in rng.randint(4, 14, size=n)]


def _drive(model, params, prompts, spec_k=0, draft_pair=None, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 96)
    kw.setdefault("max_new_tokens", 18)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("page_size", 8)
    cfg = ServeConfig(spec_k=spec_k, **kw)
    dm, dp = draft_pair if (spec_k and draft_pair) else (None, None)
    eng = PagedEngine(model, params, cfg, draft_model=dm, draft_params=dp)
    for p in prompts:
        eng.submit(p)
    res = eng.run()
    eng.kv.check()
    if eng.dkv is not None:
        eng.dkv.check()
    return res, eng


# ---------------------------------------------------------------------------
# construction contract
# ---------------------------------------------------------------------------

def test_spec_requires_draft_greedy_and_lane(target, draft):
    model, params = target
    with pytest.raises(ValueError, match="draft model"):
        PagedEngine(model, params, ServeConfig(spec_k=2))
    with pytest.raises(ValueError, match="greedy"):
        PagedEngine(model, params, ServeConfig(spec_k=2, temperature=0.7),
                    draft_model=draft[0], draft_params=draft[1])
    with pytest.raises(ValueError, match="prefill lane"):
        PagedEngine(model, params,
                    ServeConfig(spec_k=2, prefill_lane=False),
                    draft_model=draft[0], draft_params=draft[1])
    bad = get_model(dataclasses.replace(draft[0].cfg,
                                        vocab_size=draft[0].cfg.vocab_size
                                        // 2))
    with pytest.raises(ValueError, match="tokenizer"):
        PagedEngine(model, params, ServeConfig(spec_k=2),
                    draft_model=bad, draft_params=draft[1])


# ---------------------------------------------------------------------------
# verify cell semantics (unit level)
# ---------------------------------------------------------------------------

def _fresh_paged(model, params, B=2, page=8, NB=8):
    cache = model.init_paged_cache(B, NB, page, B * NB + 1)
    cache["table"] = jnp.arange(1, B * NB + 1,
                                dtype=jnp.int32).reshape(B, NB)
    cache["length"] = jnp.zeros((B,), jnp.int32)
    return cache


def test_verify_accept_matches_proposals(target):
    """Device-side accept reduction: proposals copied from the plain
    greedy chain accept in full (all-k + bonus); proposals shifted off the
    chain accept zero (bonus-only progress); a half-matching window
    accepts exactly its matching prefix."""
    model, params = target
    B, k = 2, 3
    prompts = _prompts(model, n=B, seed=5)
    # plain greedy chains via sequential decode on a fresh paged cache
    cache = _fresh_paged(model, params)
    grants = jnp.asarray([len(p) for p in prompts], jnp.int32)
    T0 = max(len(p) for p in prompts)
    toks = np.zeros((B, T0), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    logits, cache = jax.jit(model.prefill_step_paged)(
        params, jnp.asarray(toks), cache, grants)
    feed = np.asarray(jnp.argmax(logits, -1), np.int32)
    step = jax.jit(model.decode_step_paged)
    chain = [feed]
    c2 = cache
    for _ in range(k):
        logits, c2 = step(params, jnp.asarray(chain[-1])[:, None], c2)
        chain.append(np.asarray(jnp.argmax(logits, -1), np.int32))
    chain = np.stack(chain, axis=1)          # (B, k+1): feed + k greedy

    def verify(props):
        tok = np.concatenate([feed[:, None], props], axis=1)
        g, a, _ = jax.jit(model.verify_many_paged)(
            params, jnp.asarray(tok), dict(cache),
            jnp.full((B,), k + 1, jnp.int32))
        return np.asarray(g), np.asarray(a)

    g, a = verify(chain[:, 1:])              # exact chain: all accepted
    assert (a == k).all()
    np.testing.assert_array_equal(g[:, :k], chain[:, 1:])
    g, a = verify((chain[:, 1:] + 1) % model.cfg.vocab_size)
    assert (a == 0).all()                    # pure reject: bonus = greedy
    np.testing.assert_array_equal(g[:, 0], chain[:, 1])
    half = chain[:, 1:].copy()
    half[:, 1] = (half[:, 1] + 1) % model.cfg.vocab_size
    _, a = verify(half)                      # mismatch at position 1
    assert (a == 1).all()


# ---------------------------------------------------------------------------
# engine-level token identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_k", [1, 3, 5])
def test_spec_token_identical_to_plain(target, draft, spec_k):
    """The headline gate: whatever the draft proposes (here a 1-layer
    slice with a low accept rate — most ticks accept 0 proposals and
    advance on the bonus token alone), the emitted stream is bit-identical
    to plain greedy decode."""
    model, params = target
    prompts = _prompts(model)
    plain, _ = _drive(model, params, prompts)
    spec, eng = _drive(model, params, prompts, spec_k=spec_k,
                       draft_pair=draft)
    assert plain == spec
    assert eng.spec_proposed > 0
    assert eng.verify_dispatches > 0 and eng.draft_dispatches > 0
    # rejections really happened and really truncated
    assert eng.spec_accepted < eng.spec_proposed
    assert eng.spec_trunc_tokens > 0


def test_all_k_accepted_with_bonus(target):
    """Draft == target: every proposal equals the target argmax, so every
    full-width tick accepts all k and emits k+1 tokens — and the emitted
    stream still equals plain decode.  The draft runs a 1-token deficit in
    steady state (p_k sampled, never appended), absorbed by the forced
    replay, so accept stays perfect across ticks."""
    model, params = target
    prompts = _prompts(model, n=2, seed=9)
    plain, _ = _drive(model, params, prompts)
    spec, eng = _drive(model, params, prompts, spec_k=3,
                       draft_pair=(model, params))
    assert plain == spec
    assert eng.spec_proposed > 0
    assert eng.spec_accepted == eng.spec_proposed     # nothing rejected
    assert eng.spec_trunc_tokens == 0
    # k+1 tokens per steady verify dispatch: far fewer ticks than tokens
    assert eng.verify_dispatches < sum(len(v) for v in spec.values())


def test_draft_eos_mid_window_finishes_slot(target):
    """EOS proposed and accepted mid-window: pick the token plain decode
    emits a few steps in as eos_id — with draft == target the draft
    proposes it inside a verify window, the slot finishes there, and
    overshoot tokens are discarded (output equals plain decode's)."""
    model, params = target
    prompts = _prompts(model, n=1, seed=11)
    plain, _ = _drive(model, params, prompts)
    # first output token with no earlier duplicate: the stop really
    # lands at that position, not at an accidental earlier repeat
    j = next(j for j in range(1, len(plain[0]))
             if plain[0][j] not in plain[0][:j])
    eos = plain[0][j]
    plain_eos, _ = _drive(model, params, prompts, eos_id=eos)
    spec_eos, eng = _drive(model, params, prompts, spec_k=4,
                           draft_pair=(model, params), eos_id=eos)
    assert plain_eos == spec_eos
    assert spec_eos[0][-1] == eos and len(spec_eos[0]) == j + 1
    assert not any(s.active for s in eng.slots)


def test_preempt_mid_speculation_bit_identical(target, draft):
    """Preempt-and-recompute composed with speculation: a pool too small
    for both slots forces preemption mid-decode; the victim replays its
    emitted output through the prefill lane, the DRAFT cache rebuilds by
    catch-up on resume, and every request finishes bit-identical to the
    uninterrupted (big pool) oracle AND to plain decode."""
    model, params = target
    prompts = _prompts(model, n=4, seed=13)
    oracle, _ = _drive(model, params, prompts, spec_k=3, draft_pair=draft)
    plain, _ = _drive(model, params, prompts, num_pages=6)
    squeezed, eng = _drive(model, params, prompts, spec_k=3,
                           draft_pair=draft, num_pages=6)
    assert eng.preemptions > 0
    assert squeezed == oracle == plain


def test_k0_accept_tick_progresses_on_bonus(target, draft):
    """A tick whose every proposal is rejected still emits exactly the
    bonus token: with the contrarian 1-layer draft, single-step the engine
    and find a tick where accepted stayed flat while output grew."""
    model, params = target
    cfg = ServeConfig(max_batch=1, max_seq=96, max_new_tokens=12,
                      page_size=8, spec_k=4)
    eng = PagedEngine(model, params, cfg, draft_model=draft[0],
                      draft_params=draft[1])
    eng.submit(_prompts(model, n=1, seed=3)[0])
    saw_pure_reject = False
    while eng.busy:
        out_before = sum(len(s.out) for s in eng.slots)
        acc_before, prop_before = eng.spec_accepted, eng.spec_proposed
        eng.step()
        out_after = sum(len(s.out) for s in eng.slots) \
            + sum(len(v) for v in eng.results.values())
        if (eng.spec_proposed > prop_before
                and eng.spec_accepted == acc_before):
            assert out_after == out_before + 1      # the bonus token
            saw_pure_reject = True
    assert saw_pure_reject


def test_spec_cells_compile_once(target, draft):
    """Compiled-cell discipline extends to speculation: the draft propose
    cell, the draft catch-up (prefill) cell and the target verify cell
    each compile exactly once across a mixed multi-request run."""
    model, params = target
    _, eng = _drive(model, params, _prompts(model), spec_k=3,
                    draft_pair=draft)
    assert eng._verify._cache_size() == 1
    assert eng._draft_many._cache_size() == 1
    assert eng._draft_prefill._cache_size() == 1


def test_spec_composes_with_int8_pages(target):
    """Quantized page pools under speculation: both the target and the
    draft carry int8 pools with per-row scales; identity to the plain
    int8 drive holds."""
    cfg8 = dataclasses.replace(get("qwen2-0.5b").reduced(), kv_dtype="int8")
    model = get_model(cfg8)
    params = model.init(jax.random.key(0))
    dcfg = dataclasses.replace(cfg8, n_layers=1)
    dparams = dict(params)
    dparams["blocks"] = jax.tree.map(lambda x: x[:1], params["blocks"])
    prompts = _prompts(model, n=3, seed=7)
    plain, _ = _drive(model, params, prompts)
    spec, eng = _drive(model, params, prompts, spec_k=3,
                       draft_pair=(get_model(dcfg), dparams))
    assert plain == spec
    assert eng.kv.quantized and eng.dkv.quantized
