"""Fused unembed+CE vs the naive logits path: values and grads must match."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.losses import fused_unembed_xent
from repro.models.layers import unembed
from repro.models.model import cross_entropy


def _naive(h, table, labels):
    logits = unembed(h, table).astype(jnp.float32)
    return cross_entropy(logits, labels)


@pytest.mark.parametrize("B,S,d,V,chunk", [
    (2, 32, 16, 50, 8),
    (1, 64, 8, 17, 16),      # V not multiple of anything
    (3, 16, 32, 128, 16),
])
def test_fused_xent_value(B, S, d, V, chunk):
    ks = jax.random.split(jax.random.key(0), 3)
    h = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    table = jax.random.normal(ks[1], (V, d), jnp.float32) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    ref = _naive(h, table, labels)
    got = fused_unembed_xent(h, table, labels, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_fused_xent_grads():
    B, S, d, V = 2, 32, 16, 64
    ks = jax.random.split(jax.random.key(1), 3)
    h = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    table = jax.random.normal(ks[1], (V, d), jnp.float32) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V)

    g_ref = jax.grad(_naive, argnums=(0, 1))(h, table, labels)
    g_fus = jax.grad(lambda *a: fused_unembed_xent(*a, chunk=8),
                     argnums=(0, 1))(h, table, labels)
    np.testing.assert_allclose(np.asarray(g_fus[0]), np.asarray(g_ref[0]),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(g_fus[1]), np.asarray(g_ref[1]),
                               rtol=1e-4, atol=1e-6)


def test_fused_xent_bf16():
    B, S, d, V = 2, 16, 8, 32
    ks = jax.random.split(jax.random.key(2), 3)
    h = jax.random.normal(ks[0], (B, S, d), jnp.bfloat16)
    table = (jax.random.normal(ks[1], (V, d), jnp.float32) * 0.1
             ).astype(jnp.bfloat16)
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    ref = _naive(h, table, labels)
    got = fused_unembed_xent(h, table, labels, chunk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-2, atol=5e-2)
    # grads flow and are finite
    g = jax.grad(lambda *a: fused_unembed_xent(*a, chunk=8),
                 argnums=(0, 1))(h, table, labels)
    for x in g:
        assert np.all(np.isfinite(np.asarray(x, np.float32)))
