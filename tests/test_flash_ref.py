"""Custom-VJP flash attention vs direct softmax attention: forward AND
gradients must agree (the backward pass is hand-derived)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import direct_attention
from repro.models.flash import flash_attention_ref


def _mk(B, S, T, H, D, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, H, D), dtype)
    v = jax.random.normal(ks[2], (B, T, H, D), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,S,T,H,D,cq,ck", [
    (2, 128, 128, 2, 16, 32, 32),
    (1, 64, 128, 1, 8, 32, 64),      # cross-attn shape (T > S)
    (2, 96, 96, 3, 32, 32, 32),      # non-power-of-two head count
])
def test_flash_matches_direct(causal, B, S, T, H, D, cq, ck):
    if causal and S != T:
        pytest.skip("causal offset semantics differ for S != T")
    q, k, v = _mk(B, S, T, H, D)
    ref = direct_attention(q, k, v, causal=causal)
    out = flash_attention_ref(q, k, v, causal=causal, chunk_q=cq, chunk_kv=ck)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_grads_match(causal):
    B, S, H, D = 2, 64, 2, 16
    q, k, v = _mk(B, S, S, H, D)

    def loss_ref(q, k, v):
        return jnp.sum(direct_attention(q, k, v, causal=causal) ** 2)

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention_ref(q, k, v, causal=causal,
                                           chunk_q=16, chunk_kv=16) ** 2)

    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g_fl, g_ref, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"d{name} mismatch")


def test_flash_bf16():
    B, S, H, D = 2, 128, 2, 32
    q, k, v = _mk(B, S, S, H, D, jnp.bfloat16)
    ref = direct_attention(q, k, v, causal=True)
    out = flash_attention_ref(q, k, v, causal=True, chunk_q=32, chunk_kv=32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


@settings(max_examples=15, deadline=None)
@given(
    nq=st.integers(1, 4), nk=st.integers(1, 4),
    cq=st.sampled_from([8, 16, 32]), ck=st.sampled_from([8, 16, 32]),
    h=st.integers(1, 3), d=st.sampled_from([4, 8, 16]),
    causal=st.booleans(), seed=st.integers(0, 2**30),
)
def test_flash_property(nq, nk, cq, ck, h, d, causal, seed):
    """Property: for any block decomposition, flash == direct."""
    if causal:
        nk = nq
        ck = cq
    S, T = nq * cq, nk * ck
    q, k, v = _mk(1, S, T, h, d, seed=seed)
    ref = direct_attention(q, k, v, causal=causal)
    out = flash_attention_ref(q, k, v, causal=causal, chunk_q=cq,
                              chunk_kv=ck)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
