"""Pallas flash kernel (interpret mode) vs jnp oracles — shape/dtype sweep
plus hypothesis property test, per kernel-validation policy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.flash import flash_attention_fwd
from repro.models.attention import direct_attention


def _mk(B, S, T, H, D, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, H, D), dtype)
    v = jax.random.normal(ks[2], (B, T, H, D), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,S,H,D,bq,bkv", [
    (1, 256, 1, 128, 128, 128),
    (2, 256, 2, 128, 64, 128),
    (1, 512, 1, 128, 128, 64),
])
def test_pallas_flash_fp32(causal, B, S, H, D, bq, bkv):
    q, k, v = _mk(B, S, S, H, D)
    ref = direct_attention(q, k, v, causal=causal)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=bq,
                              block_kv=bkv, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype,rtol", [(jnp.bfloat16, 3e-2),
                                        (jnp.float32, 2e-5)])
def test_pallas_flash_dtypes(dtype, rtol):
    q, k, v = _mk(1, 256, 256, 2, 128, dtype)
    ref = direct_attention(q, k, v, causal=True)
    out = flash_attention_fwd(q, k, v, causal=True, block_q=128,
                              block_kv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=rtol, atol=rtol)


def test_pallas_flash_cross_lengths():
    """T != S (cross-attention shape)."""
    q, k, v = _mk(1, 128, 384, 1, 128)
    ref = direct_attention(q, k, v, causal=False)
    out = flash_attention_fwd(q, k, v, causal=False, block_q=128,
                              block_kv=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    nq=st.integers(1, 3), h=st.integers(1, 2),
    bq=st.sampled_from([64, 128]),
    causal=st.booleans(), seed=st.integers(0, 2**30),
)
def test_pallas_flash_property(nq, h, bq, causal, seed):
    S = nq * bq
    q, k, v = _mk(1, S, S, h, 128, seed=seed)
    ref = direct_attention(q, k, v, causal=causal)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=bq,
                              block_kv=bq, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
