"""Property-test harness for the paged serving path.

Randomized admit/finish/join schedules drive the non-lockstep ``PagedEngine``
(mixed prompt lengths and budgets, staggered submissions, mid-flight joins,
random defrags, shared prompt prefixes) and assert three properties after
every engine tick:

  * SAFETY — the refcounted page pool never double-allocates or leaks:
    every page's refcount equals the number of block-table references to
    it, and the null page + referenced pages + free list cover the pool
    exactly (``PagedKVCache.check()``);
  * IMMUTABILITY — a page mapped into several block tables (prefix
    sharing) is never mutated while shared: any append into it
    copy-on-write privatizes the page first, so its content is frozen
    across ticks for as long as its refcount exceeds one, and evicting a
    sharer never frees a page another slot still references;
  * CORRECTNESS — every request's output is token-identical to a fresh
    dense-cache ``ServingEngine`` run of the same prompt (the oracle):
    per-slot positions mean a mid-flight join decodes exactly like a
    batch-of-one run from position 0, and a shared prefix references
    bit-identical K/V rows, so sharing must be invisible in the tokens.

Runs a SHORT fuzz profile (>= 200 randomized engine steps across seeds)
under tier-1; the LONG profile is ``@pytest.mark.slow``
(``pytest --runslow``).  Written as explicit seeded fuzz loops because the
container image has no hypothesis; with hypothesis present these would be
``@given`` schedules.

Prompt lengths and budgets are drawn from small sets so the oracle's
compile universe stays bounded (one prefill per distinct prompt length, one
decode_many per distinct budget).
"""
import contextlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get
from repro.models import get_model
from repro.serve.cache import PagedKVCache
from repro.serve.engine import (PagedEngine, RequestStatus, ServeConfig,
                                ServingEngine)

PROMPT_LENS = (3, 5, 8)
BUDGETS = (3, 5)
SUFFIX_LENS = (2, 3, 5)                  # shared-prefix fuzz tails


@pytest.fixture(scope="module")
def harness():
    cfg = get("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    oracle = ServingEngine(model, params,
                           ServeConfig(max_batch=1, max_seq=64,
                                       max_new_tokens=max(BUDGETS)))
    return model, params, oracle


@contextlib.contextmanager
def _seeded_repro(**seeds):
    """Stamp every AssertionError escaping a fuzz body with the seeds that
    reproduce it, so a CI failure is a ONE-LINE repro: paste the printed
    ``[repro: schedule_seed=N fault_seed=M]`` values back into the harness
    and the exact failing schedule (and fault plan, if any) replays.  Seeds
    passed as ``None`` are omitted (e.g. a fuzz run with no fault plan)."""
    try:
        yield
    except AssertionError as e:
        tag = " ".join(f"{k}={v}" for k, v in seeds.items() if v is not None)
        head = str(e.args[0]) if e.args else ""
        e.args = (f"{head}\n[repro: {tag}]",) + tuple(e.args[1:])
        raise


def _assert_tokens_identical(got, want, label=""):
    """EXACT token identity between the paged engine and the oracle.

    This used to be near-tie-aware (``_assert_match_or_near_tie``): the
    unembed ran at activation dtype, and bf16 logit rounding (~2^-8
    relative) could flip an argmax between two numerically-equivalent
    lanes on the ragged workload.  Root-caused and fixed — every sampled
    position (decode steps and the prefill last position) now unembeds at
    f32 (``transformer._logits_exact``), so batched prefill and
    prefill-by-decode pick the same token and any divergence is a REAL
    bug, asserted loudly."""
    got, want = list(got), list(want)
    assert got == want, f"{label}: outputs diverged: {got} vs {want}"


def _check_tick(pe):
    """Per-tick invariants beyond ``kv.check()``: the engine's host token
    history mirrors the device lengths exactly (the prefix-sharing donor
    index must never drift from the cache), and the rolling-hash prefix
    index holds entries ONLY for live slots, consistent with their real
    histories (staleness across preempt->requeue->recompute cycles would
    surface here)."""
    pe.kv.check()
    for i, slot in enumerate(pe.slots):
        if slot.active:
            assert len(slot.history) == int(pe.kv.length[i]), \
                f"slot {i}: history/length drift"
    pe._pindex.check(pe.slots)


def _assert_drained_clean(pe):
    """Post-drain pool accounting, retention-aware: no live references,
    every non-retained page free — and after flushing the retained pool,
    the free list is the ENTIRE pool (nothing leaked through retention)."""
    assert pe.kv.live_pages == 0
    assert (pe.kv.refcount[1:] == 0).all()
    retained_only = pe.kv.retained_pages
    assert len(pe.kv.free) == pe.kv.num_pages - 1 - retained_only
    pe.kv.flush_retained()
    pe.kv.check()
    assert len(pe.kv.free) == pe.kv.num_pages - 1


def _snapshot_shared(pe):
    """Content snapshot of every page with refcount > 1 (k-pool rows)."""
    k = np.asarray(pe.kv.k)
    return {int(p): k[:, p].copy()
            for p in range(1, pe.kv.num_pages) if pe.kv.refcount[p] > 1}


def _assert_shared_frozen(pe, before):
    """IMMUTABILITY: a page that was shared at the last tick and is STILL
    shared now must be bit-identical — COW never mutates a page another
    slot can still see."""
    k = np.asarray(pe.kv.k)
    for p, rows in before.items():
        if pe.kv.refcount[p] > 1:
            np.testing.assert_array_equal(
                rows, k[:, p],
                err_msg=f"shared page {p} mutated while refcount > 1")


def _fuzz_schedule(model, params, oracle, seed: int, min_ticks: int,
                   n_requests: int, **kw) -> dict:
    """Seeded-repro wrapper: any assertion out of the fuzz body carries
    ``[repro: schedule_seed=N]`` for a one-line replay."""
    with _seeded_repro(schedule_seed=seed):
        return _fuzz_schedule_impl(model, params, oracle, seed, min_ticks,
                                   n_requests, **kw)


def _fuzz_schedule_impl(model, params, oracle, seed: int, min_ticks: int,
                        n_requests: int, *, max_batch=3, page_size=4,
                        prefill_chunk=3, prefill_lane=True,
                        prefill_chunk_tokens=0, defrag_every=0, prefixes=(),
                        check_frozen=False) -> dict:
    """One randomized schedule; returns engine stats.  Asserts the
    refcount/free-list invariants every tick and oracle token-identity at
    the end.  ``prefixes``: pool of common prompt prefixes — when set,
    every prompt is prefix + short suffix, exercising sharing and COW.
    The ragged prefill lane is ON by default (the production path);
    ``prefill_lane=False`` fuzzes the legacy prefill-by-decode route."""
    rng = np.random.RandomState(seed)
    cfg = model.cfg
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=max_batch, max_seq=48,
                                 max_new_tokens=max(BUDGETS),
                                 page_size=page_size,
                                 prefill_chunk=prefill_chunk,
                                 prefill_lane=prefill_lane,
                                 prefill_chunk_tokens=prefill_chunk_tokens))
    submitted = {}

    def make_prompt():
        if prefixes and rng.rand() < 0.85:
            pre = prefixes[rng.randint(len(prefixes))]
            suf = rng.randint(0, cfg.vocab_size,
                              size=rng.choice(SUFFIX_LENS)).astype(np.int32)
            return np.concatenate([pre, suf])
        return rng.randint(0, cfg.vocab_size,
                           size=rng.choice(PROMPT_LENS)).astype(np.int32)

    shared_snap = {}
    for it in range(10 * min_ticks + 10 * n_requests + 100):
        # keep the schedule alive until BOTH the request count and the tick
        # count are met — late submissions are exactly the mid-flight joins
        # the harness exists to fuzz
        want_more = (len(submitted) < n_requests
                     or pe.steps_run < min_ticks)
        if want_more and rng.rand() < 0.6:
            for _ in range(rng.randint(1, 3)):
                p = make_prompt()
                b = int(rng.choice(BUDGETS))
                submitted[pe.submit(p, b)] = (p, b)
        if pe.busy:
            cow_disp0 = pe.kv.cow_dispatches
            pe.step()
            # batched COW: however many pages a tick privatizes, it
            # issues at most ONE copy dispatch
            assert pe.kv.cow_dispatches - cow_disp0 <= 1
            _check_tick(pe)                   # refcounts, no leak, no drift
            if check_frozen:
                _assert_shared_frozen(pe, shared_snap)
                shared_snap = _snapshot_shared(pe)
        if defrag_every and pe.steps_run and \
                pe.steps_run % defrag_every == 0:
            pe.defrag()
            pe.kv.check()
            shared_snap = _snapshot_shared(pe)    # defrag renumbers pages
        if len(submitted) >= n_requests and not pe.busy \
                and pe.steps_run >= min_ticks:
            break
    res = pe.run()
    pe.kv.check()
    # eviction returns every page: nothing live, nothing leaked after drain
    # — and no page was ever freed while another slot still referenced it
    # (a premature free would surface as a refcount/partition violation in
    # the per-tick check above).  Finished requests' prefixes legitimately
    # outlive them in the RETAINED pool; flushing it must restore the full
    # free list.
    _assert_drained_clean(pe)
    assert set(res) == set(submitted)
    assert pe.joins == len(submitted)
    for rid, (p, b) in submitted.items():
        want = oracle.generate_batch([p], max_new_tokens=b)[0]
        _assert_tokens_identical(
            res[rid], want,
            label=f"seed={seed} rid={rid} (paged vs dense-cache oracle)")
    return {"ticks": pe.steps_run, "shared": pe.shared_tokens,
            "cow": pe.kv.cow_copies}


# ---------------------------------------------------------------------------
# short profile (tier-1): >= 200 randomized engine steps across seeds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,defrag_every", [(0, 0), (1, 5), (2, 3)])
def test_fuzz_schedule_token_identical(harness, seed, defrag_every):
    model, params, oracle = harness
    stats = _fuzz_schedule(model, params, oracle, seed, min_ticks=67,
                           n_requests=12, defrag_every=defrag_every)
    assert stats["ticks"] >= 67               # 3 seeds x 67 >= 200 steps


def test_fuzz_shared_prefix_token_identical(harness):
    """The sharing fuzz: prompts drawn from common-prefix families, so
    admissions share resident pages and appends into the shared trailing
    page exercise COW — outputs must stay oracle-identical and shared
    pages bit-frozen (checked tick by tick)."""
    model, params, oracle = harness
    rng = np.random.RandomState(100)
    prefixes = tuple(rng.randint(0, model.cfg.vocab_size,
                                 size=n).astype(np.int32) for n in (6, 9))
    stats = _fuzz_schedule(model, params, oracle, seed=3, min_ticks=40,
                           n_requests=10, prefixes=prefixes,
                           check_frozen=True)
    assert stats["shared"] > 0, "schedule never shared a prefix"
    assert stats["cow"] > 0, "schedule never exercised copy-on-write"


def test_fuzz_shared_prefix_with_defrag(harness):
    """Sharing + periodic defrag: renumbering must preserve refcounts and
    multi-table references (one physical move, all tables rewritten)."""
    model, params, oracle = harness
    rng = np.random.RandomState(200)
    prefixes = (rng.randint(0, model.cfg.vocab_size,
                            size=7).astype(np.int32),)
    stats = _fuzz_schedule(model, params, oracle, seed=5, min_ticks=30,
                           n_requests=8, prefixes=prefixes, defrag_every=4)
    assert stats["shared"] > 0


def test_fuzz_single_slot_chunked(harness):
    """max_batch=1 with chunk > prompt: the pure chunked-prefill path."""
    model, params, oracle = harness
    _fuzz_schedule(model, params, oracle, seed=7, min_ticks=20,
                   n_requests=6, max_batch=1, prefill_chunk=6)


def test_fuzz_prefill_lane_odd_chunk(harness):
    """Prefill-lane chunk NOT dividing the page (T=5, page=4): every
    mid-prompt chunk is clipped to a page boundary and the final chunk
    carries the ragged tail — outputs must stay oracle-identical with the
    refcount invariants intact every tick."""
    model, params, oracle = harness
    rng = np.random.RandomState(500)
    prefixes = (rng.randint(0, model.cfg.vocab_size,
                            size=6).astype(np.int32),)
    _fuzz_schedule(model, params, oracle, seed=13, min_ticks=30,
                   n_requests=8, prefill_chunk_tokens=5, prefixes=prefixes)


def test_fuzz_legacy_prefill_by_decode(harness):
    """REGRESSION: the legacy forced-token route (lane off) must keep its
    guarantees — it is the measured baseline the lane is gated against."""
    model, params, oracle = harness
    stats = _fuzz_schedule(model, params, oracle, seed=17, min_ticks=25,
                           n_requests=6, prefill_lane=False)
    assert stats["ticks"] >= 25


def test_fuzz_page_size_one(harness):
    """page_size=1 maximizes allocation churn (one page per token; every
    shared page is full, so sharing never needs COW)."""
    model, params, oracle = harness
    _fuzz_schedule(model, params, oracle, seed=11, min_ticks=25,
                   n_requests=5, max_batch=2, page_size=1, prefill_chunk=2)


# ---------------------------------------------------------------------------
# long profile (manual): pytest --runslow
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("seed", [101, 202])
def test_fuzz_schedule_long(harness, seed):
    model, params, oracle = harness
    stats = _fuzz_schedule(model, params, oracle, seed, min_ticks=500,
                           n_requests=60, defrag_every=7)
    assert stats["ticks"] >= 500


@pytest.mark.slow
def test_fuzz_shared_prefix_long(harness):
    model, params, oracle = harness
    rng = np.random.RandomState(300)
    prefixes = tuple(rng.randint(0, model.cfg.vocab_size,
                                 size=n).astype(np.int32) for n in (5, 9))
    stats = _fuzz_schedule(model, params, oracle, seed=303, min_ticks=400,
                           n_requests=50, prefixes=prefixes,
                           defrag_every=9, check_frozen=True)
    assert stats["shared"] > 0 and stats["cow"] > 0


# ---------------------------------------------------------------------------
# targeted edge cases
# ---------------------------------------------------------------------------

def test_eos_truncates_like_oracle(harness):
    """eos sampled mid-stream finishes the slot with the same truncation
    rule as the dense oracle."""
    model, params, _ = harness
    prompt = np.random.RandomState(5).randint(
        0, model.cfg.vocab_size, size=5).astype(np.int32)
    # pick an eos the model actually emits: the 2nd greedy token
    probe = ServingEngine(model, params,
                          ServeConfig(max_batch=1, max_seq=32,
                                      max_new_tokens=4))
    eos = probe.generate_batch([prompt])[0][1]
    sc = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=6, eos_id=eos,
                     page_size=4, prefill_chunk=3)
    pe = PagedEngine(model, params, sc)
    rid = pe.submit(prompt)
    res = pe.run()
    want = ServingEngine(model, params,
                         ServeConfig(max_batch=1, max_seq=32,
                                     max_new_tokens=6, eos_id=eos)
                         ).generate_batch([prompt])[0]
    assert res[rid] == want
    assert res[rid][-1] == eos and len(res[rid]) == 2


def test_stall_recovers_via_eviction(harness):
    """A slot that cannot get step capacity stalls (zero granted steps for
    the tick) and resumes after another slot finishes and its pages are
    evicted — no deadlock, outputs still oracle-identical."""
    model, params, oracle = harness
    # 3 allocatable pages, two slots each eventually needing 2 pages
    sc = ServeConfig(max_batch=2, max_seq=8, max_new_tokens=5, page_size=4,
                     num_pages=4, prefill_chunk=2)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(9)
    prompts = [rng.randint(0, model.cfg.vocab_size, size=3).astype(np.int32)
               for _ in range(2)]
    rids = [pe.submit(p) for p in prompts]
    res = pe.run()
    assert pe.stalls > 0
    for rid, p in zip(rids, prompts):
        assert res[rid] == oracle.generate_batch([p], max_new_tokens=5)[0]


def test_scheduler_partial_grant_budget_fairness(harness):
    """The tick scheduler's packing policies, deterministically: a slot
    short on pages gets a PARTIAL grant (prefix of the tick's steps)
    instead of stalling outright; ``tick_budget`` caps total fresh tokens
    per tick; least-served fairness hands pages to the slot with the
    fewest tokens appended."""
    from repro.serve.cache import PagedKVCache
    from repro.serve.engine import _Slot
    from repro.serve.scheduler import TickScheduler
    model, params, _ = harness

    def slots(served=(0, 0)):
        return [_Slot(rid=i, forced=list(range(9)), budget=3, served=s,
                      active=True) for i, s in enumerate(served)]

    # 5 allocatable single-row pages, two slots wanting chunk 4 each:
    # first-in-order takes its full chunk, the other packs the 1 left
    kv = PagedKVCache(model, 2, 16, page_size=1, num_pages=6)
    plan = TickScheduler().plan(slots(), kv, chunk=4)
    assert list(plan.steps) == [4, 1]
    assert plan.active[:, 0].all()
    assert plan.active[0, 1] and not plan.active[1:, 1].any()
    assert plan.stalled == 0

    # the budget knob caps the tick's total fresh tokens
    kv = PagedKVCache(model, 2, 16, page_size=1, num_pages=12)
    plan = TickScheduler(tick_budget=5).plan(slots(), kv, chunk=4)
    assert int(plan.steps.sum()) == 5

    # least-served fairness: the starved slot allocates first
    kv = PagedKVCache(model, 2, 16, page_size=1, num_pages=6)
    plan = TickScheduler().plan(slots(served=(10, 0)), kv, chunk=4)
    assert list(plan.steps) == [1, 4]
    # legacy slot-order: first slot wins regardless of service
    kv = PagedKVCache(model, 2, 16, page_size=1, num_pages=6)
    plan = TickScheduler(fairness="slot-order").plan(
        slots(served=(10, 0)), kv, chunk=4)
    assert list(plan.steps) == [4, 1]


def test_scheduler_prefill_grants_page_aligned(harness):
    """Prefill-lane grants: a chunk that does not drain the prompt is
    clipped to end on a PAGE BOUNDARY (appends never leave a partially
    written page mid-prompt); the final chunk keeps its ragged tail; a
    slot whose prompt has drained gets decode steps instead; the tick
    budget caps both lanes together."""
    from repro.serve.cache import PagedKVCache
    from repro.serve.engine import _Slot
    from repro.serve.scheduler import TickScheduler
    model, params, _ = harness

    def slot(prompt_left, forced_n=None, budget=3, served=0):
        n = prompt_left - 1 if forced_n is None else forced_n
        return _Slot(rid=0, forced=list(range(max(0, n))), budget=budget,
                     served=served, prompt_left=prompt_left, active=True)

    # mid-prompt chunk clipped to the page boundary: T=6, page=4, base=0,
    # prompt_left=20 -> grant 4 (not 6); a draining chunk keeps its tail:
    # prompt_left=5 <= T -> grant 5
    kv = PagedKVCache(model, 2, 32, page_size=4, num_pages=20)
    plan = TickScheduler().plan([slot(20), slot(5)], kv, chunk=3,
                                prefill_tokens=6)
    assert list(plan.prefill) == [4, 5]
    assert list(plan.steps) == [0, 0]           # no decode while prefilling
    assert plan.any_work

    # base mid-page (prefix share at 2 tokens): the clip lands the chunk
    # end on the boundary — T=5 from base 2 would end at 7 mid-page, so
    # the grant clips to 2 (base+grant = 4 = one page); with T=6 the
    # un-clipped end (8) is already a boundary and the full 6 is granted
    for T, want in ((5, 2), (6, 6)):
        kv = PagedKVCache(model, 1, 32, page_size=4, num_pages=20)
        assert kv.ensure(0, 2)
        kv.length[0] = 2
        plan = TickScheduler().plan([slot(20)], kv, chunk=3,
                                    prefill_tokens=T)
        assert list(plan.prefill) == [want], T

    # drained prompt -> decode lane; budget caps prefill + decode together
    kv = PagedKVCache(model, 2, 32, page_size=4, num_pages=20)
    plan = TickScheduler(tick_budget=5).plan(
        [slot(8), slot(0, forced_n=0)], kv, chunk=3, prefill_tokens=4)
    assert int(plan.prefill.sum()) + int(plan.steps.sum()) == 5
    assert list(plan.prefill) == [4, 0]
    assert list(plan.steps) == [0, 1]

    # prefill_tokens=0 (lane off): prompts ride the decode cell as before
    kv = PagedKVCache(model, 1, 32, page_size=4, num_pages=20)
    plan = TickScheduler().plan([slot(20)], kv, chunk=3, prefill_tokens=0)
    assert list(plan.prefill) == [0]
    assert list(plan.steps) == [3]


def test_scheduler_prefill_partial_grant_under_pool_pressure(harness):
    """A prefill chunk that does not fit the free list is granted the
    largest feasible prefix (alignment yields to pool pressure) instead of
    stalling outright."""
    from repro.serve.cache import PagedKVCache
    from repro.serve.engine import _Slot
    from repro.serve.scheduler import TickScheduler
    model, params, _ = harness
    kv = PagedKVCache(model, 1, 32, page_size=4, num_pages=2)  # 1 page free
    s = _Slot(rid=0, forced=list(range(15)), budget=3, prompt_left=16,
              active=True)
    plan = TickScheduler().plan([s], kv, chunk=3, prefill_tokens=12)
    assert list(plan.prefill) == [4]            # one page's worth
    assert plan.stalled == 0


def test_scheduler_cow_before_ensure(harness):
    """REGRESSION: with ONE free page and an append landing in a shared
    partial page, the scheduler must spend the page on the COW copy (and
    advance within existing pages), not on extending the table — the old
    ensure-first order consumed the page, failed the COW, granted 0 to a
    completable slot, and the engine raised pool-exhausted."""
    from repro.serve.cache import PagedKVCache
    from repro.serve.engine import _Slot
    from repro.serve.scheduler import TickScheduler
    model, params, _ = harness
    kv = PagedKVCache(model, 2, 8, page_size=4, num_pages=3)  # 2 allocatable
    # donor wrote 2 tokens into page A; sharer references it at length 2
    assert kv.ensure(0, 2)
    kv.length[0] = 2
    kv.share(1, 0, 2)
    assert len(kv.free) == 1 and kv.refcount[kv.owned[0][0]] == 2
    slots = [_Slot(rid=0, forced=[1, 2, 3], budget=3, active=True), _Slot()]
    plan = TickScheduler().plan(slots, kv, chunk=4)
    assert kv.cow_copies == 1                # the free page went to the COW
    assert int(plan.steps[0]) == 2           # advances within the new page
    assert plan.stalled == 0


def test_cow_many_one_dispatch_refcount_fuzz(harness):
    """Batched COW at the cache level: randomized share topologies, then
    one ``cow_many`` over a random (slot, blk) set — N privatizations must
    cost exactly ONE device dispatch, counters must track pages (bytes ==
    copies x page_bytes), and the refcount/free-list/table invariants must
    hold after every batch."""
    from repro.serve.cache import PagedKVCache
    model, params, _ = harness
    for seed in range(4):
        rng = np.random.RandomState(40 + seed)
        kv = PagedKVCache(model, 4, 32, page_size=4, num_pages=40)
        n_tok = int(rng.randint(8, 17))
        assert kv.ensure(0, n_tok)
        kv.length[0] = n_tok
        for dst in (1, 2, 3):
            kv.share(dst, 0, int(rng.randint(1, n_tok + 1)))
        kv.check()
        items = [(i, b) for i in range(4) for b in range(len(kv.owned[i]))
                 if rng.rand() < 0.5]
        d0, c0, b0 = kv.cow_dispatches, kv.cow_copies, kv.cow_bytes
        # expected copies: each privatization drains one reference, and
        # the LAST referent of a page keeps the original (no copy)
        rc = kv.refcount.copy()
        expected = 0
        for i, b in items:
            pg = kv.owned[i][b]
            if rc[pg] > 1:
                rc[pg] -= 1
                expected += 1
        copied = kv.cow_many(items)
        assert copied == expected            # exclusive pages skipped
        assert kv.cow_dispatches - d0 == (1 if copied else 0)
        assert kv.cow_copies - c0 == copied
        assert kv.cow_bytes - b0 == copied * kv.page_bytes
        kv.check()


def test_tick_batches_cow_into_one_dispatch(harness):
    """Engine-level half of the batched-COW claim: a tick whose appends
    privatize SEVERAL shared pages (two sharers forking off one donor in
    the same tick) issues exactly ONE copy dispatch for all of them."""
    model, params, oracle = harness
    sc = ServeConfig(max_batch=3, max_seq=32, max_new_tokens=4, page_size=4,
                     prefill_chunk=2)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(31)
    prompt = rng.randint(0, model.cfg.vocab_size, size=6).astype(np.int32)
    rids = [pe.submit(prompt)]            # donor
    pe.step()                             # donor resident mid-page
    rids += [pe.submit(prompt), pe.submit(prompt)]
    pe._admit()                           # both sharers reference the page
    shared = [p for p in range(1, pe.kv.num_pages) if pe.kv.refcount[p] > 1]
    assert shared and pe.kv.refcount[shared[0]] == 3
    d0, c0 = pe.kv.cow_dispatches, pe.kv.cow_copies
    pe.step()                             # both sharers append -> 2 COWs
    assert pe.kv.cow_copies - c0 == 2, "tick should privatize two pages"
    assert pe.kv.cow_dispatches - d0 == 1, \
        "N privatizations in one tick must be ONE copy dispatch"
    res = pe.run()                        # outputs stay oracle-identical
    want = oracle.generate_batch([prompt], max_new_tokens=4)[0]
    for rid in rids:
        assert res[rid] == want


def test_identity_helper_rejects_any_divergence(harness):
    """The exact-identity comparison (which RETIRED the bf16 near-tie
    workaround — sampled positions now unembed at f32) accepts only
    token-for-token equality: any swap or length mismatch fails."""
    model, params, oracle = harness
    rng = np.random.RandomState(77)
    prompt = rng.randint(0, model.cfg.vocab_size, size=5).astype(np.int32)
    want = oracle.generate_batch([prompt], max_new_tokens=4)[0]
    _assert_tokens_identical(want, want)                       # passes
    forged = [(want[0] + 1) % model.cfg.vocab_size] + want[1:]
    with pytest.raises(AssertionError, match="diverged"):
        _assert_tokens_identical(forged, want)
    with pytest.raises(AssertionError, match="diverged"):
        _assert_tokens_identical(want[:-1], want)              # truncation


def test_cow_preserves_shared_rows(harness):
    """Copy-on-write never mutates rows another slot can still see: share
    a PARTIAL page between two slots, let both append into it on the next
    tick (COW must fire for whoever writes while the page is shared), and
    verify the shared token rows of the original physical page are
    bit-identical afterwards — the surviving owner may only have written
    rows past the shared prefix."""
    model, params, oracle = harness
    # prefill chunk pinned to 2 tokens so the donor's first tick leaves a
    # PARTIAL page for the sharer to reference
    sc = ServeConfig(max_batch=2, max_seq=32, max_new_tokens=4, page_size=4,
                     prefill_chunk=2, prefill_chunk_tokens=2)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(17)
    prompt = rng.randint(0, model.cfg.vocab_size, size=6).astype(np.int32)
    rid_a = pe.submit(prompt)             # donor
    pe.step()                             # donor at 2 tokens: page 0 PARTIAL
    rid_b = pe.submit(prompt)             # sharer: same 6-token prompt
    pe._admit()                           # shares the partial page
    n_shared = pe.shared_tokens
    assert 0 < n_shared < pe.kv.page      # partial-page share
    shared = [p for p in range(1, pe.kv.num_pages) if pe.kv.refcount[p] > 1]
    assert shared, "admission did not map a page into both tables"
    before = {p: np.asarray(pe.kv.k)[:, p, :n_shared].copy() for p in shared}
    pe.step()                             # both append into the shared page
    assert pe.kv.cow_copies > 0
    after = np.asarray(pe.kv.k)
    for p, rows in before.items():
        np.testing.assert_array_equal(
            rows, after[:, p, :n_shared],
            err_msg=f"write into shared page {p} reached shared rows")
    res = pe.run()                        # drain: outputs stay exact
    for rid in (rid_a, rid_b):
        assert res[rid] == oracle.generate_batch([prompt],
                                                 max_new_tokens=4)[0]


def test_sharer_survives_donor_eviction(harness):
    """Evicting the donor must not free pages the sharer still references:
    the donor finishes first, its exclusive pages return to the free list,
    the shared ones stay live until the sharer finishes."""
    model, params, oracle = harness
    sc = ServeConfig(max_batch=2, max_seq=32, max_new_tokens=2, page_size=4,
                     prefill_chunk=4)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(23)
    prompt = rng.randint(0, model.cfg.vocab_size, size=6).astype(np.int32)
    rid_a = pe.submit(prompt, 2)          # donor: short budget
    pe.step()                             # donor live at 4 prompt tokens
    rid_b = pe.submit(prompt, 5)          # sharer: outlives the donor
    res = pe.run()
    pe.kv.check()
    assert pe.shared_tokens > 0
    assert res[rid_a] == oracle.generate_batch([prompt],
                                               max_new_tokens=2)[0]
    assert res[rid_b] == oracle.generate_batch([prompt],
                                               max_new_tokens=5)[0]


def test_chunk_reservation_capped_at_remaining_work(harness):
    """REGRESSION: the scheduler must reserve pages for the slot's
    REMAINING work, not the whole prefill_chunk — a fitting request
    (1 page of real work) with chunk 8 on a 1-page pool must complete,
    not raise pool-exhausted.  The chunk overshoot lands on the null page
    and is discarded."""
    model, params, oracle = harness
    sc = ServeConfig(max_batch=1, max_seq=16, max_new_tokens=1, page_size=4,
                     num_pages=2, prefill_chunk=8)   # 1 allocatable page
    pe = PagedEngine(model, params, sc)
    prompt = np.arange(3, dtype=np.int32)
    rid = pe.submit(prompt)
    res = pe.run()
    assert res[rid] == oracle.generate_batch([prompt],
                                             max_new_tokens=1)[0]


def test_inadmissible_request_rejected_at_submit(harness):
    """REGRESSION: a request no eviction can ever serve (prompt + budget
    exceed the whole pool) used to spin until the deep-tick pool-exhausted
    raise; now it is typed-REJECTED at submit() and the engine stays
    usable."""
    model, params, _ = harness
    sc = ServeConfig(max_batch=1, max_seq=8, max_new_tokens=5, page_size=4,
                     num_pages=2, prefill_chunk=4)   # 1 allocatable page
    pe = PagedEngine(model, params, sc)
    rid = pe.submit(np.arange(3, dtype=np.int32))    # 3 + 5 > 4 tokens
    assert pe.status[rid] is RequestStatus.REJECTED
    assert "pool" in pe.reject_reason[rid]
    assert pe.results[rid] == []
    assert not pe.busy                               # nothing queued/stalled
    pe.run()                                         # no-op, no raise


def test_oversize_request_rejected_at_submit(harness):
    """A prompt+budget wider than the slot's block table used to raise
    ``max_blocks`` from deep inside a tick; now submit() rejects it."""
    model, params, _ = harness
    sc = ServeConfig(max_batch=1, max_seq=8, max_new_tokens=12, page_size=4)
    pe = PagedEngine(model, params, sc)          # max_blocks = 2 (8 tokens)
    rid = pe.submit(np.arange(5, dtype=np.int32))    # 5 + 12 > 8
    assert pe.status[rid] is RequestStatus.REJECTED
    assert "max_blocks" in pe.reject_reason[rid]
    assert not pe.busy


def test_pool_exhaustion_raises_only_without_preemption(harness):
    """The legacy pool-exhausted RuntimeError survives ONLY behind
    ``preempt=False``: two individually-admissible requests that jointly
    wedge a 2-page pool raise on the baseline config and complete via
    preempt-and-recompute on the default config (that regression lives in
    tests/test_overload_props.py)."""
    model, params, _ = harness
    sc = ServeConfig(max_batch=2, max_seq=8, max_new_tokens=5, page_size=4,
                     num_pages=3, prefill_chunk=2, preempt=False)
    pe = PagedEngine(model, params, sc)
    pe.submit(np.arange(3, dtype=np.int32), 5)    # 8 tokens = 2 blocks each:
    pe.submit(np.arange(3, 6, dtype=np.int32), 5)  # admissible alone, wedged
    with pytest.raises(RuntimeError, match="page pool exhausted"):
        pe.run()


def test_paged_rejects_empty_prompt(harness):
    model, params, _ = harness
    pe = PagedEngine(model, params, ServeConfig(max_batch=1, max_seq=16))
    with pytest.raises(ValueError):
        pe.submit(np.array([], np.int32))


def test_paged_rejects_ssm():
    cfg = get("falcon-mamba-7b").reduced()
    model = get_model(cfg)
    with pytest.raises(ValueError):
        PagedEngine(model, None, ServeConfig(max_batch=2, max_seq=32))


def test_scheduler_rejects_unknown_fairness(harness):
    model, params, _ = harness
    with pytest.raises(ValueError, match="fairness"):
        PagedEngine(model, params,
                    ServeConfig(max_batch=1, max_seq=16, fairness="lifo"))


def test_defrag_compacts_to_prefix(harness):
    """After defrag the kept pages occupy the contiguous pool prefix —
    [null | live | retained-only] — and the free list is exactly the tail
    (shared pages counted once).  Requests that finished during the churn
    leave page-aligned prefixes in the RETAINED pool; defrag renumbers
    those entries alongside the live mappings."""
    model, params, _ = harness
    sc = ServeConfig(max_batch=3, max_seq=32, max_new_tokens=5, page_size=2,
                     prefill_chunk=2)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(13)
    for _ in range(5):
        pe.submit(rng.randint(0, model.cfg.vocab_size,
                              size=4).astype(np.int32))
    for _ in range(4):                           # churn: some finish, some join
        if pe.busy:
            pe.step()
    pe.defrag()
    pe.kv.check()
    live = pe.kv.live_pages
    distinct = sorted({p for o in pe.kv.owned for p in o})
    assert distinct == list(range(1, live + 1))
    kept = live + len(pe.kv._retained_only())
    assert sorted(pe.kv.free) == list(range(kept + 1, pe.kv.num_pages))
    res = pe.run()                               # still drains correctly
    assert len(res) == 5


# ---------------------------------------------------------------------------
# pending-COW rollback / cancellation (the mid-plan dry-pool path)
# ---------------------------------------------------------------------------

def test_cow_rollback_restores_sharing(harness):
    """REGRESSION (mid-plan dry pool): a COW reservation undone by
    ``cow_rollback`` must restore the shared mapping exactly — source
    refcount bumped back, table/owned rewired, the reserved destination
    page returned to the free list — leaving no trace in the partition
    invariant."""
    model, _, _ = harness
    kv = PagedKVCache(model, 2, 32, page_size=4, num_pages=6)
    kv.ensure(0, 8)                       # donor: 2 pages
    kv.length[0] = 8
    kv.share(1, 0, 8)                     # both pages shared
    free0 = sorted(kv.free)
    assert kv.cow_reserve(1, 0) and kv.cow_reserve(1, 1)
    assert len(kv._pending_cow) == 2
    kv.check(allow_pending=True)          # mid-plan state is legal
    cow0 = kv.cow_copies
    # roll back only the SECOND reservation (a shrunken grant)
    assert kv.cow_rollback(1, from_blk=1) == 1
    assert len(kv._pending_cow) == 1
    assert kv.owned[1][1] == kv.owned[0][1]      # sharing restored
    assert kv.refcount[kv.owned[0][1]] == 2
    kv.check(allow_pending=True)
    # roll back the rest: the pool is exactly as before the reservations
    assert kv.cow_rollback(1) == 1
    assert not kv._pending_cow
    assert kv.owned[1] == kv.owned[0]
    assert sorted(kv.free) == free0
    assert kv.cow_copies == cow0 - 2      # counters unwound too
    kv.check()


def test_free_slot_cancels_pending_cow(harness):
    """REGRESSION: freeing a slot with a PENDING COW reservation must
    cancel the reservation, not leave a queued device copy into a page
    that just returned to the free list (whoever allocates it next would
    be silently corrupted by the late flush)."""
    model, _, _ = harness
    kv = PagedKVCache(model, 2, 32, page_size=4, num_pages=6)
    kv.ensure(0, 4)
    kv.length[0] = 4
    kv.share(1, 0, 4)
    assert kv.cow_reserve(1, 0)           # pending copy into a fresh page
    dst = kv.owned[1][0]
    kv.free_slot(1)                       # evict the sharer mid-plan
    assert not kv._pending_cow            # the copy was cancelled...
    assert dst in kv.free                 # ...and its page is free again
    kv.check()
    assert kv.cow_flush() == 0            # nothing queued for the device


def test_grant_dry_pool_leaves_no_stray_reservation(harness):
    """Scheduler-level pin for the mid-plan dry-pool path: a grant clipped
    (or refused) by pool pressure must leave the pending-COW queue holding
    ONLY reservations the granted appends actually reach — a zero grant
    holds zero pages hostage, and a clipped multi-block grant keeps
    exactly the reservations below the clip."""
    from repro.serve.scheduler import TickScheduler
    model, _, _ = harness
    sched = TickScheduler()
    # appends into the shared trailing block with ONE free page: the COW
    # takes the spare, the grant lands inside the privatized page
    kv = PagedKVCache(model, 2, 32, page_size=4, num_pages=4)
    kv.ensure(0, 8)
    kv.length[0] = 8
    kv.share(1, 0, 8)
    kv.length[1] = 6
    granted, cows = sched._grant(kv, 1, 6, 2)
    assert granted == 2 and cows == 1
    assert len(kv._pending_cow) == 1      # exactly the reachable block
    kv.check(allow_pending=True)
    kv.cow_flush()
    kv.check()
    # a grant that CANNOT advance (block 2 needed, pool dry) must not
    # leave any reservation behind
    granted, cows = sched._grant(kv, 1, 8, 2)
    assert granted == 0 and cows == 0
    assert not kv._pending_cow
    kv.check()


def test_fuzz_pending_cow_never_targets_free_page(harness):
    """Fuzz pin for the rollback/cancellation machinery: random share /
    reserve / rollback / free-slot churn on a bare pool, asserting after
    every operation that pending copies only ever reference live pages
    (``check(allow_pending=True)``) and that a full rollback + free drains
    the pool leak-free."""
    model, _, _ = harness
    rng = np.random.RandomState(11)
    for trial in range(20):
        kv = PagedKVCache(model, 3, 32, page_size=4, num_pages=8)
        kv.ensure(0, rng.randint(1, 3) * 4)
        kv.length[0] = 4 * len(kv.owned[0])
        for _ in range(rng.randint(4, 12)):
            op = rng.randint(4)
            if op == 0 and not kv.owned[1]:
                n = int(kv.length[0])
                if n:
                    kv.share(1, 0, rng.randint(1, n + 1))
            elif op == 1 and kv.owned[1]:
                blk = rng.randint(len(kv.owned[1]))
                kv.cow_reserve(1, blk)
            elif op == 2 and kv.owned[1]:
                kv.cow_rollback(1, rng.randint(0, len(kv.owned[1]) + 1))
            elif op == 3 and kv.owned[1]:
                kv.free_slot(1)
            kv.check(allow_pending=True)
        kv.cow_flush()
        for i in range(3):
            if kv.owned[i]:
                kv.free_slot(i)
        kv.check()
        assert kv.live_pages == 0, f"trial {trial} leaked"
        assert len(kv.free) == kv.num_pages - 1

# ---------------------------------------------------------------------------
# cross-lifetime retained prefix pool (serve/cache.py RetainedPrefix)
# ---------------------------------------------------------------------------

def test_retained_reshare_bit_identical_after_donor_death(harness):
    """The tentpole property: a follower submitted AFTER its donor fully
    drained adopts the donor's frozen pages BY REFERENCE — same physical
    page ids, bit-identical K/V rows — and its output is token-identical
    to a cold oracle run (request-relative rope makes the frozen rows
    exact for any adopter)."""
    model, params, oracle = harness
    sc = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=4, page_size=4,
                     prefill_chunk=2)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(41)
    prompt = rng.randint(0, model.cfg.vocab_size, size=11).astype(np.int32)
    pe.submit(prompt)
    pe.run()                               # donor finishes and is FREED
    assert not pe.busy and pe.kv.live_pages == 0
    assert pe.kv.retained, "finished donor left nothing in the retained pool"
    # the donor retains its FULL history prefix (prompt + emitted); the
    # follower's prompt only reaches the prompt's page-aligned part
    entry = pe.kv.retained[-1]
    ret_pages = list(entry.pages)
    rows_before = np.asarray(pe.kv.k)[:, ret_pages].copy()
    rid = pe.submit(prompt)                # donor is DEAD; only digests match
    pe._admit()
    assert pe.kv.retained_hits == 1
    n_hit = (len(prompt) // 4) * 4
    assert pe.kv.retained_hit_tokens == n_hit
    # adoption is by reference: the follower's table maps the SAME pages
    slot = next(i for i, s in enumerate(pe.slots) if s.active)
    assert pe.kv.owned[slot][:n_hit // 4] == ret_pages[:n_hit // 4]
    np.testing.assert_array_equal(
        rows_before, np.asarray(pe.kv.k)[:, ret_pages],
        err_msg="adoption mutated frozen retained rows")
    res = pe.run()
    want = oracle.generate_batch([prompt], max_new_tokens=4)[0]
    _assert_tokens_identical(res[rid], want,
                             label="retained re-share vs oracle")
    _assert_drained_clean(pe)


def test_reclaim_never_touches_adopted_pages(harness):
    """Reclamation under pressure must skip entries whose pages a live
    slot just re-shared (adoption bumps refcount, so the entry frees
    nothing) and drop only genuinely idle entries."""
    model, _, _ = harness
    kv = PagedKVCache(model, 3, 32, page_size=4, num_pages=10, retain=True)
    toks_a = list(range(8))
    kv.ensure(0, 8); kv.length[0] = 8
    kv.free_slot(0, retain_tokens=toks_a)          # entry A: 2 pages
    toks_b = list(range(100, 108))
    kv.ensure(0, 8); kv.length[0] = 8
    kv.free_slot(0, retain_tokens=toks_b)          # entry B: 2 pages
    kv.check()
    assert len(kv.retained) == 2
    entry_a, n = kv.match_retained(np.asarray(toks_a, np.int32), 32)
    assert entry_a is not None and n == 8
    kv.adopt_retained(1, entry_a, 8)               # A's pages live again
    kv.check()
    freed = kv.reclaim_retained(100)               # demand the whole pool
    assert freed == 2                              # only B's pages freed
    assert entry_a in kv.retained                  # A survived: adopted
    assert (kv.refcount[entry_a.pages] == 1).all()
    kv.check()
    # once the adopter dies, A's pages are retained-only again and A is
    # reclaimable
    kv.free_slot(1)
    assert kv.reclaim_retained(100) == 2
    assert not kv.retained
    kv.check()
    assert len(kv.free) == kv.num_pages - 1


def test_seize_drains_warm_retained_pool(harness):
    """A fault-plan squeeze deeper than the free list must seize straight
    through the retained pool without corrupting the digest map: entries
    are dropped cleanly (later lookups miss), seized pages release back to
    the free list whole."""
    model, _, _ = harness
    kv = PagedKVCache(model, 2, 32, page_size=4, num_pages=8, retain=True)
    toks = list(range(12))
    kv.ensure(0, 12); kv.length[0] = 12
    kv.free_slot(0, retain_tokens=toks)            # 3 retained pages
    kv.check()
    n_free = len(kv.free)
    seized = kv.seize_pages(n_free + 2)            # MUST drain retention
    assert len(seized) == n_free + 2
    assert kv.retained_reclaimed_pages >= 2
    kv.check()                                     # digest map consistent
    entry, n = kv.match_retained(np.asarray(toks, np.int32), 32)
    assert entry is None and n == 0                # dropped entries miss
    kv.release_pages(seized)
    kv.check()
    assert len(kv.free) == kv.num_pages - 1


def test_retain_policies_order_reclamation(harness):
    """"lru" evicts the oldest-touched entry first; "popularity" evicts
    the fewest-adoptions entry first even when it is the youngest."""
    model, _, _ = harness
    for policy, survivor in (("lru", "young"), ("popularity", "popular")):
        kv = PagedKVCache(model, 2, 64, page_size=4, num_pages=12,
                          retain=True, retain_policy=policy)
        toks_old = list(range(4))
        toks_young = list(range(50, 54))
        kv.ensure(0, 4); kv.length[0] = 4
        kv.free_slot(0, retain_tokens=toks_old)
        if policy == "popularity":
            # make the OLD entry popular: adopt + release it once
            e, n = kv.match_retained(np.asarray(toks_old, np.int32), 64)
            kv.adopt_retained(1, e, 4)
            kv.free_slot(1)
        kv.ensure(0, 4); kv.length[0] = 4
        kv.free_slot(0, retain_tokens=toks_young)
        kv.check()
        assert kv.reclaim_retained(1) == 1         # drop exactly one entry
        kept = kv.retained[0].tokens
        if survivor == "young":
            assert kept == toks_young, "lru must drop the oldest entry"
        else:
            assert kept == toks_old, \
                "popularity must keep the adopted (popular) entry"
        kv.check()


def test_retain_cap_bounds_idle_pages(harness):
    """``retain_cap`` bounds retained-ONLY pages: retaining past the cap
    evicts older entries instead of growing the idle set."""
    model, _, _ = harness
    kv = PagedKVCache(model, 2, 64, page_size=4, num_pages=16,
                      retain=True, retain_cap=2)
    for base in (0, 100, 200):
        kv.ensure(0, 8); kv.length[0] = 8
        kv.free_slot(0, retain_tokens=list(range(base, base + 8)))
        kv.check()
        assert len(kv._retained_only()) <= 2
    # the newest entry is the survivor
    assert kv.retained and kv.retained[-1].tokens == list(range(200, 208))


def test_retained_survives_defrag(harness):
    """Defrag renumbers retained entries' pages alongside live mappings:
    the digest lookup still hits afterwards and the adopted content is
    bit-identical to the pre-defrag rows."""
    model, params, oracle = harness
    sc = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=4, page_size=4,
                     prefill_chunk=2)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(43)
    prompt = rng.randint(0, model.cfg.vocab_size, size=9).astype(np.int32)
    pe.submit(prompt)
    pe.run()
    assert pe.kv.retained
    entry = pe.kv.retained[-1]
    rows_before = np.asarray(pe.kv.k)[:, entry.pages].copy()
    pe.defrag()
    pe.kv.check()
    np.testing.assert_array_equal(
        rows_before, np.asarray(pe.kv.k)[:, entry.pages],
        err_msg="defrag lost retained page content")
    rid = pe.submit(prompt)
    pe._admit()
    assert pe.kv.retained_hits == 1, "digest lookup broken after defrag"
    res = pe.run()
    want = oracle.generate_batch([prompt], max_new_tokens=4)[0]
    _assert_tokens_identical(res[rid], want,
                             label="post-defrag retained re-share")


def test_retention_off_restores_legacy_drain(harness):
    """``retain_prefixes=False`` keeps the pre-retention contract: a
    finished slot's pages go straight back to the free list."""
    model, params, _ = harness
    sc = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=4, page_size=4,
                     prefill_chunk=2, retain_prefixes=False)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(44)
    pe.submit(rng.randint(0, model.cfg.vocab_size, size=9).astype(np.int32))
    pe.run()
    assert not pe.kv.retained
    assert len(pe.kv.free) == pe.kv.num_pages - 1
    pe.kv.check()
