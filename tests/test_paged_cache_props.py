"""Property-test harness for the paged serving path.

Randomized admit/finish/join schedules drive the non-lockstep ``PagedEngine``
(mixed prompt lengths and budgets, staggered submissions, mid-flight joins,
random defrags) and assert two properties after every engine tick:

  * SAFETY — the page free list never double-allocates or leaks: the null
    page + every slot's owned pages + the free list partition the pool
    exactly (``PagedKVCache.check()``);
  * CORRECTNESS — every request's output is token-identical to a fresh
    dense-cache ``ServingEngine`` run of the same prompt (the oracle): the
    paged engine's per-slot positions mean a request admitted mid-flight
    decodes exactly like a batch-of-one run from position 0.

Runs a SHORT fuzz profile (>= 200 randomized engine steps across seeds)
under tier-1; the LONG profile is ``@pytest.mark.slow``
(``pytest --runslow``).  Written as explicit seeded fuzz loops because the
container image has no hypothesis; with hypothesis present these would be
``@given`` schedules.

Prompt lengths and budgets are drawn from small sets so the oracle's
compile universe stays bounded (one prefill per distinct prompt length, one
decode_many per distinct budget).
"""
import numpy as np
import jax
import pytest

from repro.configs import get
from repro.models import get_model
from repro.serve.engine import PagedEngine, ServeConfig, ServingEngine

PROMPT_LENS = (3, 5, 8)
BUDGETS = (3, 5)


@pytest.fixture(scope="module")
def harness():
    cfg = get("qwen2-0.5b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    oracle = ServingEngine(model, params,
                           ServeConfig(max_batch=1, max_seq=64,
                                       max_new_tokens=max(BUDGETS)))
    return model, params, oracle


def _fuzz_schedule(model, params, oracle, seed: int, min_ticks: int,
                   n_requests: int, *, max_batch=3, page_size=4,
                   prefill_chunk=3, defrag_every=0) -> int:
    """One randomized schedule; returns engine ticks run.  Asserts the
    free-list invariants every tick and oracle token-identity at the end."""
    rng = np.random.RandomState(seed)
    cfg = model.cfg
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=max_batch, max_seq=48,
                                 max_new_tokens=max(BUDGETS),
                                 page_size=page_size,
                                 prefill_chunk=prefill_chunk))
    submitted = {}
    for it in range(10 * min_ticks + 10 * n_requests + 100):
        # keep the schedule alive until BOTH the request count and the tick
        # count are met — late submissions are exactly the mid-flight joins
        # the harness exists to fuzz
        want_more = (len(submitted) < n_requests
                     or pe.steps_run < min_ticks)
        if want_more and rng.rand() < 0.6:
            for _ in range(rng.randint(1, 3)):
                p = rng.randint(0, cfg.vocab_size,
                                size=rng.choice(PROMPT_LENS)
                                ).astype(np.int32)
                b = int(rng.choice(BUDGETS))
                submitted[pe.submit(p, b)] = (p, b)
        if pe.busy:
            pe.step()
            pe.kv.check()                     # no double-alloc, no leak
        if defrag_every and pe.steps_run and \
                pe.steps_run % defrag_every == 0:
            pe.defrag()
            pe.kv.check()
        if len(submitted) >= n_requests and not pe.busy \
                and pe.steps_run >= min_ticks:
            break
    res = pe.run()
    pe.kv.check()
    # eviction returns every page: nothing live, nothing leaked after drain
    assert pe.kv.live_pages == 0
    assert len(pe.kv.free) == pe.kv.num_pages - 1
    assert set(res) == set(submitted)
    assert pe.joins == len(submitted)
    for rid, (p, b) in submitted.items():
        want = oracle.generate_batch([p], max_new_tokens=b)[0]
        assert res[rid] == want, f"seed={seed} rid={rid}: paged output " \
            f"diverged from the fresh dense-cache oracle"
    return pe.steps_run


# ---------------------------------------------------------------------------
# short profile (tier-1): >= 200 randomized engine steps across seeds
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,defrag_every", [(0, 0), (1, 5), (2, 3)])
def test_fuzz_schedule_token_identical(harness, seed, defrag_every):
    model, params, oracle = harness
    ticks = _fuzz_schedule(model, params, oracle, seed, min_ticks=67,
                           n_requests=12, defrag_every=defrag_every)
    assert ticks >= 67                        # 3 seeds x 67 >= 200 steps


def test_fuzz_single_slot_chunked(harness):
    """max_batch=1 with chunk > prompt: the pure chunked-prefill path."""
    model, params, oracle = harness
    _fuzz_schedule(model, params, oracle, seed=7, min_ticks=20,
                   n_requests=6, max_batch=1, prefill_chunk=6)


def test_fuzz_page_size_one(harness):
    """page_size=1 maximizes allocation churn (one page per token)."""
    model, params, oracle = harness
    _fuzz_schedule(model, params, oracle, seed=11, min_ticks=25,
                   n_requests=5, max_batch=2, page_size=1, prefill_chunk=2)


# ---------------------------------------------------------------------------
# long profile (manual): pytest --runslow
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("seed", [101, 202])
def test_fuzz_schedule_long(harness, seed):
    model, params, oracle = harness
    ticks = _fuzz_schedule(model, params, oracle, seed, min_ticks=500,
                           n_requests=60, defrag_every=7)
    assert ticks >= 500


# ---------------------------------------------------------------------------
# targeted edge cases
# ---------------------------------------------------------------------------

def test_eos_truncates_like_oracle(harness):
    """eos sampled mid-stream finishes the slot with the same truncation
    rule as the dense oracle."""
    model, params, _ = harness
    prompt = np.random.RandomState(5).randint(
        0, model.cfg.vocab_size, size=5).astype(np.int32)
    # pick an eos the model actually emits: the 2nd greedy token
    probe = ServingEngine(model, params,
                          ServeConfig(max_batch=1, max_seq=32,
                                      max_new_tokens=4))
    eos = probe.generate_batch([prompt])[0][1]
    sc = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=6, eos_id=eos,
                     page_size=4, prefill_chunk=3)
    pe = PagedEngine(model, params, sc)
    rid = pe.submit(prompt)
    res = pe.run()
    want = ServingEngine(model, params,
                         ServeConfig(max_batch=1, max_seq=32,
                                     max_new_tokens=6, eos_id=eos)
                         ).generate_batch([prompt])[0]
    assert res[rid] == want
    assert res[rid][-1] == eos and len(res[rid]) == 2


def test_stall_recovers_via_eviction(harness):
    """A slot that cannot get chunk capacity stalls (active=False for the
    tick) and resumes after another slot finishes and its pages are
    evicted — no deadlock, outputs still oracle-identical."""
    model, params, oracle = harness
    # 3 allocatable pages, two slots each eventually needing 2 pages
    sc = ServeConfig(max_batch=2, max_seq=8, max_new_tokens=5, page_size=4,
                     num_pages=4, prefill_chunk=2)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(9)
    prompts = [rng.randint(0, model.cfg.vocab_size, size=3).astype(np.int32)
               for _ in range(2)]
    rids = [pe.submit(p) for p in prompts]
    res = pe.run()
    assert pe.stalls > 0
    for rid, p in zip(rids, prompts):
        assert res[rid] == oracle.generate_batch([p], max_new_tokens=5)[0]


def test_chunk_reservation_capped_at_remaining_work(harness):
    """REGRESSION: step() must reserve pages for the slot's REMAINING work,
    not the whole prefill_chunk — a fitting request (1 page of real work)
    with chunk 8 on a 1-page pool must complete, not raise pool-exhausted.
    The chunk overshoot lands on the null page and is discarded."""
    model, params, oracle = harness
    sc = ServeConfig(max_batch=1, max_seq=16, max_new_tokens=1, page_size=4,
                     num_pages=2, prefill_chunk=8)   # 1 allocatable page
    pe = PagedEngine(model, params, sc)
    prompt = np.arange(3, dtype=np.int32)
    rid = pe.submit(prompt)
    res = pe.run()
    assert res[rid] == oracle.generate_batch([prompt],
                                             max_new_tokens=1)[0]


def test_pool_exhaustion_raises(harness):
    """A workload no eviction can ever unblock raises instead of spinning."""
    model, params, _ = harness
    sc = ServeConfig(max_batch=1, max_seq=8, max_new_tokens=5, page_size=4,
                     num_pages=2, prefill_chunk=4)   # 1 allocatable page
    pe = PagedEngine(model, params, sc)
    pe.submit(np.arange(3, dtype=np.int32))
    with pytest.raises(RuntimeError, match="page pool exhausted"):
        pe.run()


def test_oversize_request_raises(harness):
    model, params, _ = harness
    sc = ServeConfig(max_batch=1, max_seq=8, max_new_tokens=12, page_size=4)
    pe = PagedEngine(model, params, sc)          # max_blocks = 2 (8 tokens)
    pe.submit(np.arange(5, dtype=np.int32))      # 5 + 12 > 8
    with pytest.raises(RuntimeError, match="max_blocks"):
        pe.run()


def test_paged_rejects_empty_prompt(harness):
    model, params, _ = harness
    pe = PagedEngine(model, params, ServeConfig(max_batch=1, max_seq=16))
    with pytest.raises(ValueError):
        pe.submit(np.array([], np.int32))


def test_paged_rejects_ssm():
    cfg = get("falcon-mamba-7b").reduced()
    model = get_model(cfg)
    with pytest.raises(ValueError):
        PagedEngine(model, None, ServeConfig(max_batch=2, max_seq=32))


def test_defrag_compacts_to_prefix(harness):
    """After defrag the live pages occupy the contiguous pool prefix and
    the free list is exactly the tail."""
    model, params, _ = harness
    sc = ServeConfig(max_batch=3, max_seq=32, max_new_tokens=5, page_size=2,
                     prefill_chunk=2)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(13)
    for _ in range(5):
        pe.submit(rng.randint(0, model.cfg.vocab_size,
                              size=4).astype(np.int32))
    for _ in range(4):                           # churn: some finish, some join
        if pe.busy:
            pe.step()
    pe.defrag()
    pe.kv.check()
    live = pe.kv.live_pages
    owned = sorted(p for o in pe.kv.owned for p in o)
    assert owned == list(range(1, live + 1))
    assert sorted(pe.kv.free) == list(range(live + 1, pe.kv.num_pages))
    res = pe.run()                               # still drains correctly
    assert len(res) == 5
