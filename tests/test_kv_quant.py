"""Quantized KV pages: int8 page pools with per-row f32 scales.

Four layers of coverage, mirroring how the feature is built:

  * ARITHMETIC — ``quantize_rows``/``dequantize_rows`` round-trip error is
    bounded by scale/2 per element, all-zero rows (the null page) stay
    exact, and gather-then-dequantize equals dequantize-then-gather (the
    oracle's placement of the dequant is free);
  * KERNELS — both Pallas kernels (paged decode sweep incl. multi-page
    blocking, ragged multi-token prefill) dequantize inside the page sweep
    and must match the dequantizing jnp gather oracles in interpret mode
    across GQA/MQA/MHA and ragged geometry;
  * POOL MANAGEMENT — COW privatization copies int8 rows + scale rows
    bit-exactly while shared, retained-prefix adoption re-shares frozen
    quantized pages WITH their scales, and the quantized COW copy's census
    bytes stay page-scaled and pool-size independent;
  * SERVING — the two quantized WRITE paths (prefill lane vs
    prefill-by-decode) quantize identical appended rows identically, so
    the emitted streams must be token-identical on randomized schedules.
    Drift vs bf16 pools is bounded at the attention-output level (the
    token-level comparison is measured, not gated: int8 noise flips
    near-tie argmaxes at the reduced config — see serve_bench's
    ragged_int8 scenario).
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get
from repro.models import get_model
from repro.models.kv_quant import QMAX, dequantize_rows, quantize_rows
from repro.serve.engine import PagedEngine, ServeConfig


# ---------------------------------------------------------------------------
# arithmetic
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_bound():
    x = jax.random.normal(jax.random.key(0), (5, 3, 2, 16), jnp.float32)
    q, s = quantize_rows(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert q.shape == x.shape and s.shape == x.shape[:-1]
    err = np.abs(np.asarray(dequantize_rows(q, s)) - np.asarray(x))
    assert (err <= np.asarray(s)[..., None] * 0.5 + 1e-7).all()
    # the row absmax is representable exactly (hits +-127)
    assert (np.abs(np.asarray(q)).max(axis=-1) == QMAX).all()


def test_quantize_zero_rows_exact():
    """All-zero rows -> scale 1.0 and exact zero dequant: the null page and
    never-written pool rows decode to zeros regardless of scale init."""
    q, s = quantize_rows(jnp.zeros((2, 4, 8), jnp.float32))
    assert (np.asarray(s) == 1.0).all()
    assert not np.asarray(q).any()
    assert not np.asarray(dequantize_rows(q, s)).any()


def test_bf16_rows_roundtrip_through_f32():
    """The write paths quantize bf16 activations: quantization happens in
    f32 and the bound holds against the f32 view of the input."""
    x = jax.random.normal(jax.random.key(3), (4, 2, 32),
                          jnp.float32).astype(jnp.bfloat16)
    q, s = quantize_rows(x)
    err = np.abs(np.asarray(dequantize_rows(q, s))
                 - np.asarray(x, np.float32))
    assert (err <= np.asarray(s)[..., None] * 0.5 + 1e-7).all()


# ---------------------------------------------------------------------------
# kernels vs the dequantizing gather oracles (interpret mode)
# ---------------------------------------------------------------------------

def _quantized_paged_case(seed, B, H, KV, D, page, NB, L, extra_pages=3):
    """Random f32 pool quantized row-wise + distinct non-null pages per
    slot + ragged per-slot lengths (not multiples of ``page``)."""
    rng = np.random.RandomState(seed)
    P = B * NB + extra_pages
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    kq, ksc = quantize_rows(jax.random.normal(ks[1], (L, P, page, KV, D)))
    vq, vsc = quantize_rows(jax.random.normal(ks[2], (L, P, page, KV, D)))
    tbl = rng.permutation(np.arange(1, P))[:B * NB].reshape(B, NB)
    lens = rng.randint(1, NB * page + 1, size=B)
    layer = rng.randint(0, L)
    return (q, kq, vq, ksc, vsc, jnp.asarray(tbl, jnp.int32),
            jnp.asarray(lens, jnp.int32), layer)


@pytest.mark.parametrize("pps", [1, 2])
@pytest.mark.parametrize("B,H,KV,D,page,NB,L", [
    (2, 4, 2, 16, 8, 5, 2),       # GQA group 2; NB !| pps
    (3, 4, 1, 16, 8, 3, 1),       # MQA
    (1, 8, 8, 32, 8, 4, 2),       # MHA
    (2, 6, 2, 32, 16, 2, 2),      # group 3; trailing partial page
])
def test_quantized_paged_decode_matches_dequant_oracle(pps, B, H, KV, D,
                                                       page, NB, L):
    """The decode sweep dequantizes P scattered pages per grid step through
    the online softmax; with per-row scales threaded it must match the
    dequantizing jnp gather oracle."""
    from repro.kernels.decode_attention.ops import paged_decode_attention
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    q, kq, vq, ksc, vsc, tbl, lens, layer = _quantized_paged_case(
        B + H + pps, B, H, KV, D, page, NB, L)
    got = paged_decode_attention(q, kq, vq, tbl, lens, layer,
                                 pages_per_step=pps, k_scale=ksc,
                                 v_scale=vsc, interpret=True)
    want = paged_decode_attention_ref(q, kq, vq, tbl, lens, layer,
                                      k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,T,H,KV,D,page,NB,L", [
    (2, 6, 4, 2, 16, 8, 3, 2),    # GQA group 2; T !| page
    (3, 8, 4, 1, 16, 4, 5, 1),    # MQA; chunk spans 2+ pages
    (1, 5, 8, 8, 32, 8, 4, 3),    # MHA; odd T
])
def test_quantized_paged_prefill_matches_dequant_oracle(B, T, H, KV, D,
                                                        page, NB, L):
    """The ragged prefill sweep with quantized pools + per-row scales vs
    the dequantizing oracle: ragged bases/grants, chunks crossing page
    boundaries."""
    from repro.kernels.decode_attention.ops import paged_prefill_attention
    from repro.kernels.decode_attention.ref import paged_prefill_attention_ref
    rng = np.random.RandomState(B + T + H)
    P = B * NB + 3
    ks = jax.random.split(jax.random.key(B + T + H), 3)
    q = jax.random.normal(ks[0], (B, T, H, D), jnp.float32)
    kq, ksc = quantize_rows(jax.random.normal(ks[1], (L, P, page, KV, D)))
    vq, vsc = quantize_rows(jax.random.normal(ks[2], (L, P, page, KV, D)))
    tbl = jnp.asarray(rng.permutation(np.arange(1, P))[:B * NB]
                      .reshape(B, NB), jnp.int32)
    base = rng.randint(0, NB * page - T + 1, size=B)
    grants = rng.randint(1, T + 1, size=B)
    base = jnp.asarray(base, jnp.int32)
    new = base + jnp.asarray(grants, jnp.int32)
    layer = rng.randint(0, L)
    got = paged_prefill_attention(q, kq, vq, tbl, base, new, layer,
                                  k_scale=ksc, v_scale=vsc, interpret=True)
    want = paged_prefill_attention_ref(q, kq, vq, tbl, base, new, layer,
                                       k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_quantized_oracle_equals_oracle_on_dequantized_pool():
    """Oracle-of-oracle: the quantized gather oracle on (int8 pool, scales)
    must equal the plain oracle on the eagerly dequantized f32 pool —
    gather-then-dequantize and dequantize-then-gather are the same map, so
    the dequant's placement inside the sweep is a pure optimization."""
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    q, kq, vq, ksc, vsc, tbl, lens, layer = _quantized_paged_case(
        11, 2, 4, 2, 16, 8, 4, 2)
    got = paged_decode_attention_ref(q, kq, vq, tbl, lens, layer,
                                     k_scale=ksc, v_scale=vsc)
    want = paged_decode_attention_ref(q, dequantize_rows(kq, ksc),
                                      dequantize_rows(vq, vsc), tbl, lens,
                                      layer)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_quantized_attention_drift_bounded():
    """Drift bound vs unquantized pools: quantizing a random f32 pool
    perturbs the decode attention output by quantization noise only —
    bounded well under the logit scale, NOT zero (the test must actually
    exercise the quantizer)."""
    from repro.kernels.decode_attention.ref import paged_decode_attention_ref
    rng = np.random.RandomState(5)
    B, H, KV, D, page, NB, L, P = 2, 4, 2, 32, 8, 4, 2, 12
    ks = jax.random.split(jax.random.key(5), 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.float32)
    kp = jax.random.normal(ks[1], (L, P, page, KV, D), jnp.float32)
    vp = jax.random.normal(ks[2], (L, P, page, KV, D), jnp.float32)
    kq, ksc = quantize_rows(kp)
    vq, vsc = quantize_rows(vp)
    tbl = jnp.asarray(rng.permutation(np.arange(1, P))[:B * NB]
                      .reshape(B, NB), jnp.int32)
    lens = jnp.asarray(rng.randint(1, NB * page + 1, size=B), jnp.int32)
    exact = paged_decode_attention_ref(q, kp, vp, tbl, lens, 1)
    quant = paged_decode_attention_ref(q, kq, vq, tbl, lens, 1,
                                       k_scale=ksc, v_scale=vsc)
    drift = np.abs(np.asarray(exact) - np.asarray(quant)).max()
    assert 0 < drift < 0.15


# ---------------------------------------------------------------------------
# pool management: COW, retention, census
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def int8_harness():
    cfg = dataclasses.replace(get("qwen2-0.5b").reduced(), kv_dtype="int8")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return model, params


def test_int8_pool_decls_and_page_bytes(int8_harness):
    """The cache manager's pools come up int8 with f32 per-row scale pools,
    and page_bytes derives from the ACTUAL itemsizes: page x KV x (hd int8
    bytes + 4 scale bytes) x L x 2 (K and V)."""
    model, params = int8_harness
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=2, max_seq=32, page_size=4))
    kv = pe.kv
    assert kv.quantized
    assert kv.k.dtype == jnp.int8 and kv.v.dtype == jnp.int8
    assert kv.k_scale.dtype == jnp.float32
    assert kv.k_scale.shape == kv.k.shape[:-1]
    L, _, page, KV, hd = kv.k.shape
    assert kv.page_bytes == 2 * L * page * KV * (hd + 4)


def test_int8_cow_preserves_quantized_rows_and_scales(int8_harness):
    """COW on quantized pools: the shared rows of the original physical
    page — int8 content AND f32 scales — are bit-identical after both
    slots append into the shared page, and the two identical requests
    emit identical streams."""
    model, params = int8_harness
    sc = ServeConfig(max_batch=2, max_seq=32, max_new_tokens=4, page_size=4,
                     prefill_chunk=2, prefill_chunk_tokens=2)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(17)
    prompt = rng.randint(0, model.cfg.vocab_size, size=6).astype(np.int32)
    rid_a = pe.submit(prompt)             # donor
    pe.step()                             # donor at 2 tokens: page PARTIAL
    rid_b = pe.submit(prompt)             # sharer: same 6-token prompt
    pe._admit()                           # shares the partial page
    n_shared = pe.shared_tokens
    assert 0 < n_shared < pe.kv.page
    shared = [p for p in range(1, pe.kv.num_pages) if pe.kv.refcount[p] > 1]
    assert shared, "admission did not map a page into both tables"
    before = {p: (np.asarray(pe.kv.k)[:, p, :n_shared].copy(),
                  np.asarray(pe.kv.k_scale)[:, p, :n_shared].copy())
              for p in shared}
    pe.step()                             # both append into the shared page
    assert pe.kv.cow_copies > 0
    after_k = np.asarray(pe.kv.k)
    after_s = np.asarray(pe.kv.k_scale)
    for p, (rows, scales) in before.items():
        np.testing.assert_array_equal(
            rows, after_k[:, p, :n_shared],
            err_msg=f"write into shared page {p} reached shared int8 rows")
        np.testing.assert_array_equal(
            scales, after_s[:, p, :n_shared],
            err_msg=f"write into shared page {p} reached shared scales")
    res = pe.run()
    pe.kv.check()
    assert res[rid_a] == res[rid_b]       # same prompt, same budget


def test_int8_retained_adoption_carries_scales(int8_harness):
    """A follower adopting a DEAD donor's retained prefix re-shares the
    frozen int8 pages by reference with their scales untouched, and emits
    the donor's exact stream (same prompt, same budget — the retained rows
    are the donor's own bits, so retention is invisible in the tokens)."""
    model, params = int8_harness
    sc = ServeConfig(max_batch=2, max_seq=48, max_new_tokens=4, page_size=4,
                     prefill_chunk=2)
    pe = PagedEngine(model, params, sc)
    rng = np.random.RandomState(41)
    prompt = rng.randint(0, model.cfg.vocab_size, size=11).astype(np.int32)
    rid0 = pe.submit(prompt)
    res0 = pe.run()                       # donor finishes and is FREED
    assert not pe.busy and pe.kv.live_pages == 0
    assert pe.kv.retained, "finished donor left nothing retained"
    entry = pe.kv.retained[-1]
    ret_pages = list(entry.pages)
    k_before = np.asarray(pe.kv.k)[:, ret_pages].copy()
    s_before = np.asarray(pe.kv.k_scale)[:, ret_pages].copy()
    rid = pe.submit(prompt)               # donor DEAD; only digests match
    pe._admit()
    assert pe.kv.retained_hits == 1
    np.testing.assert_array_equal(
        k_before, np.asarray(pe.kv.k)[:, ret_pages],
        err_msg="adoption mutated frozen retained int8 rows")
    np.testing.assert_array_equal(
        s_before, np.asarray(pe.kv.k_scale)[:, ret_pages],
        err_msg="adoption mutated frozen retained scales")
    res = pe.run()
    pe.kv.check()
    assert res[rid] == res0[rid0]


def test_quantized_cow_copy_census_page_scaled():
    """The quantized COW copy (int8 pools + scale pools in ONE dispatch)
    stays page-scaled and pool-size independent in the census — the
    byte-accounting claim the paged cache makes, now per quantized page."""
    from repro.core.hlo_counters import census_from_compiled
    from repro.serve.cache import _copy_pages_quant
    L, page, KV, hd = 4, 16, 2, 16

    def census(P, n):
        pool = jax.ShapeDtypeStruct((L, P, page, KV, hd), jnp.int8)
        scale = jax.ShapeDtypeStruct((L, P, page, KV), jnp.float32)
        idx = jax.ShapeDtypeStruct((n,), jnp.int32)
        compiled = jax.jit(_copy_pages_quant,
                           donate_argnums=(0, 1, 2, 3)).lower(
            pool, pool, scale, scale, idx, idx).compile()
        return census_from_compiled(compiled)

    c2_small, c2_big = census(33, 2), census(65, 2)
    c4 = census(65, 4)
    assert c2_big.hbm_bytes == c2_small.hbm_bytes
    assert c4.hbm_bytes == pytest.approx(2 * c2_big.hbm_bytes, rel=0.01)
    # absolute sanity: int8 page + scale rows, both pools, read + write —
    # nowhere near a whole-pool convert's worth of traffic
    page_q = L * page * KV * (hd + 4)
    assert c2_big.hbm_bytes >= 2 * 2 * 2 * page_q  # rd+wr, K+V, 2 pages
    assert c2_big.hbm_bytes < 2 * 24 * page_q


# ---------------------------------------------------------------------------
# serving: the two quantized write paths agree token-for-token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_int8_lane_vs_decode_token_identical(int8_harness, seed):
    """Property-harness schedule on int8 pools, prefill lane ON vs OFF:
    both write paths quantize the same appended rows with the same per-row
    arithmetic, so the emitted streams must be EXACTLY token-identical —
    the within-dtype half of the correctness story (cross-dtype drift vs
    bf16 is bounded above and measured in serve_bench's ragged_int8)."""
    model, params = int8_harness
    rng = np.random.RandomState(seed)
    reqs = [(rng.randint(0, model.cfg.vocab_size,
                         size=rng.choice((3, 5, 8, 11))).astype(np.int32),
             int(rng.choice((3, 5))))
            for _ in range(8)]
    outs = {}
    for lane in (True, False):
        pe = PagedEngine(model, params,
                         ServeConfig(max_batch=3, max_seq=48,
                                     max_new_tokens=5, page_size=4,
                                     prefill_chunk=3, prefill_lane=lane))
        rids = []
        # staggered submissions: mid-flight joins exercise mixed
        # prefill/decode ticks on the quantized pools
        for i, (p, b) in enumerate(reqs):
            rids.append(pe.submit(p, b))
            if i % 3 == 2:
                pe.step()
                pe.kv.check()
        res = pe.run()
        pe.kv.check()
        assert pe.kv.live_pages == 0
        outs[lane] = [res[r] for r in rids]
    assert outs[True] == outs[False], \
        "prefill lane and prefill-by-decode diverged on int8 pools"


def test_int8_defrag_carries_scales(int8_harness):
    """Defrag permutes int8 pages and scale pages with the SAME
    permutation: mid-flight defrag on a quantized engine leaves every
    live slot's (content, scale) pairing intact — checked end-to-end by
    stream identity against a defrag-free run."""
    model, params = int8_harness
    rng = np.random.RandomState(9)
    reqs = [(rng.randint(0, model.cfg.vocab_size,
                         size=n).astype(np.int32), 4)
            for n in (6, 9, 5, 7)]
    outs = []
    for defrag in (False, True):
        pe = PagedEngine(model, params,
                         ServeConfig(max_batch=2, max_seq=48,
                                     max_new_tokens=4, page_size=4,
                                     prefill_chunk=3))
        rids = [pe.submit(p, b) for p, b in reqs]
        while pe.busy:
            pe.step()
            if defrag and pe.steps_run % 3 == 0:
                pe.defrag()
                pe.kv.check()
        res = pe.results
        outs.append([res[r] for r in rids])
    assert outs[0] == outs[1], "defrag perturbed quantized streams"
