"""BabelStream-TPU Pallas kernels vs jnp oracles: shape/dtype sweep +
hypothesis property test (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.stream import ref, stream

SHAPES = [(8, 128), (256, 512), (1024, 128), (64, 1024)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(shape, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    return tuple(jax.random.normal(k, shape, dtype) for k in ks)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_copy(shape, dtype):
    a, _, _ = _mk(shape, dtype)
    got = stream.copy(a, block_rows=min(64, shape[0]), interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.copy(a)))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_mul(shape, dtype):
    _, _, c = _mk(shape, dtype)
    got = stream.mul(c, block_rows=min(64, shape[0]), interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.mul(c), np.float32), rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_add(shape, dtype):
    a, b, _ = _mk(shape, dtype)
    got = stream.add(a, b, block_rows=min(64, shape[0]), interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.add(a, b), np.float32),
                               rtol=1e-6)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_triad(shape, dtype):
    _, b, c = _mk(shape, dtype)
    got = stream.triad(b, c, block_rows=min(64, shape[0]), interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref.triad(b, c), np.float32),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_dot(shape, dtype):
    a, b, _ = _mk(shape, dtype)
    got = stream.dot(a, b, block_rows=min(64, shape[0]), interpret=True)
    want = ref.dot(a, b)
    np.testing.assert_allclose(float(got), float(want), rtol=2e-3)


@settings(max_examples=12, deadline=None)
@given(rows=st.sampled_from([8, 32, 128]),
       cols=st.sampled_from([128, 384]),
       block=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 2**30))
def test_stream_property(rows, cols, block, seed):
    """Any (rows % block == 0) decomposition must be exact for copy/add and
    near-exact for dot."""
    if rows % block:
        block = rows
    a, b, c = _mk((rows, cols), jnp.float32, seed)
    np.testing.assert_array_equal(
        np.asarray(stream.copy(a, block_rows=block, interpret=True)),
        np.asarray(a))
    np.testing.assert_allclose(
        np.asarray(stream.add(a, b, block_rows=block, interpret=True)),
        np.asarray(a + b), rtol=1e-6)
    np.testing.assert_allclose(
        float(stream.dot(a, b, block_rows=block, interpret=True)),
        float(ref.dot(a, b)), rtol=2e-3)
