"""Serving micro-benchmark: batched decode throughput at smoke scale (the
decode_32k cells' runnable counterpart).

Scenarios
(``--scenario
smoke|ragged|shared-prefix|long-decode|long-prompt|overload|cold-prefix|
speculative|all``):

  * smoke — the fused device-resident ``decode_many`` loop against the
    legacy per-token host loop (both with donated caches), plus the paged
    continuous-batching engine's end-to-end tokens/s (2x batch requests
    over batch slots, mid-flight joins).
  * ragged — continuous batching under a RAGGED workload (mixed prompt and
    output lengths, mid-flight joins: 3x batch requests over batch slots):
    the non-lockstep paged engine (chunked prefill through the fused
    decode cell) against the DENSE LOCKSTEP baseline at equal ``max_seq``
    — the retired lockstep engine's discipline (one shared cache
    position, per-slot start windows, prompts prefilled BY DECODE one
    token per shared step), reconstructed here as a measurement-only
    driver so the ``ragged_paged_speedup`` trajectory stays comparable
    across PRs.  Page-pool utilization and row occupancy are recorded PER
    TICK from the engine's traces and reduced to mean/max across the
    drive's ticks (the old numbers sampled only the end state); the
    utilization stats come from a second, untimed drive with periodic
    defrag so they describe the compacted pool.
  * shared-prefix — a common system prompt across all requests (3x batch
    over batch slots): prefix-sharing paged vs the same engine with
    sharing disabled at EQUAL pool size, recording tokens/s and the
    logical-vs-physical token ratio (tokens resident by reference /
    tokens physically written) plus copy-on-write page-copy counts.
  * long-decode — few slots, LONG generations: the workload where per-tick
    host overhead (table re-uploads, forced-array builds, dispatch count)
    dominates if the tick is fat.  Measures end-to-end tokens/s plus the
    TICK_OVERHEAD metrics the instruction roofline cannot see — host ms
    per tick, device dispatches per tick, and bytes uploaded per tick —
    from the engine's per-tick traces (pool-walk traces disabled so the
    tick is the thin production tick).  A steady-state decode tick must
    run 1 dispatch and upload only the B-int feed/grant vectors: zero
    table bytes, zero forced-token bytes.
  * long-prompt — few slots x 256-token prompts x short outputs: the
    admission-latency showcase.  The ragged multi-token PREFILL LANE (one
    compiled kernel step appends and attends a 64-token chunk; a prompt
    costs ceil(256/64) = 4 dispatches) against the same engine with the
    lane disabled (prefill-by-decode: 256 sequential decode-cell steps),
    reporting PROMPT tokens/s for both and the lane's forced-upload bytes
    (must be 0: prompt traffic moves as one ragged (B, T) block per
    chunk).
  * overload — bursty submits REQUESTING ~4x the page pool: the engine
    survives on preempt-and-recompute.  Records goodput (tokens of
    requests that reached FINISHED/PREEMPTED_RESUMED per second), the
    preemption count, the recompute-token fraction, crashed ticks (gated
    to 0 — the pre-overload engine raised "page pool exhausted" here) and
    whether every request reached a typed terminal status.
  * cold-prefix — cross-lifetime prefix retention: a donor with a
    256-token system prompt drains COMPLETELY, then followers repeating
    the same prompt run one at a time (a live donor never exists, so
    every prefix hit must come from the retained pool's digest-keyed
    frozen pages) against the identical engine with retention off.
    Records the retained hit rate (gated to 1.0), re-shared tokens, a
    TTFT proxy (ticks per request) and the warm-vs-cold tokens/s speedup
    (gated >= 1.5).
  * speculative — draft-and-verify multi-token decode ticks on the
    long-decode workload: a 1-layer DRAFT proposes spec_k tokens per
    tick, a deepened target verifies the window in ONE ragged prefill-
    lane dispatch and keeps the accepted prefix + bonus token.  The
    target is doctored so every block past the first is a residual
    no-op, pinning the accept rate at 1.0 — the recorded speedup is the
    machinery's ceiling at the config's target/draft cost ratio, not a
    model-quality artifact.  Gates: tokens/s >= 1.3x the same engine
    speculating off, BIT-IDENTICAL token streams, zero crashed ticks.

``--json`` writes BENCH_serve.json so the perf trajectory is tracked across
PRs (scripts/verify.sh gates on it).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

SMOKE = dict(arch="granite-8b", batch=4, seq=128, steps=8)
# prefill_chunk 6 (was 4): the tick scheduler's partial grants removed the
# all-or-nothing stall risk of bigger chunks, and 6 amortizes the per-tick
# host turnaround best on the CPU smoke config
RAGGED = dict(arch="granite-8b", batch=4, max_seq=192, requests=12,
              prompt_lo=4, prompt_hi=24, out_lo=4, out_hi=16,
              page_size=16, prefill_chunk=6, defrag_every=8)
# sys_prompt 48 = 3 exact pages: a PAGE-ALIGNED shared prefix needs no
# copy-on-write at all (every shared page is full; the first fresh append
# opens a new block), so cow_copies records 0 here — measured guidance:
# align shared system prompts to page_size; a mid-page prefix (e.g. 50)
# copy-on-writes one page per sharer and costs ~15% tokens/s on this
# config (the COW path itself is census/property-tested in tier-1)
SHARED = dict(arch="granite-8b", batch=4, max_seq=96, requests=12,
              sys_prompt=48, tail_lo=4, tail_hi=12, out_lo=4, out_hi=10,
              page_size=16, prefill_chunk=4)
# few slots x long generations: ~90% of ticks are pure decode (no
# admission, no prompt in flight, no page-boundary crossing), so the
# device-resident table state and the forced-free twin cell show their
# largest win here — and the tick_overhead metrics are dominated by the
# steady-state tick the optimizations target
LONG_DECODE = dict(arch="granite-8b", batch=2, max_seq=256, requests=4,
                   prompt=8, out=96, page_size=16, prefill_chunk=8)
# few slots x LONG prompts x short outputs: the admission-latency
# showcase.  The ragged prefill lane appends a prompt in ceil(256/64) = 4
# kernel steps; the prefill-by-decode baseline pays 256 sequential
# decode-cell steps for the same rows.  chunk_tokens 64 = 4 exact pages
# (page-aligned chunks never leave a partially written page mid-prompt)
LONG_PROMPT = dict(arch="granite-8b", batch=2, max_seq=320, requests=4,
                   prompt=256, out=8, page_size=16, prefill_chunk=8,
                   prefill_chunk_tokens=64)
# overload: the workload REQUESTS ~4x the pool (16 requests x up to 40
# tokens each vs 12 allocatable pages x 8 tokens), submitted in bursts, so
# the engine must preempt-and-recompute to survive — the gate pins zero
# crashed ticks, at least one preemption, a goodput floor (tokens of
# requests that ran to completion per second) and a recompute-overhead
# ceiling (re-appended tokens / all appended tokens)
OVERLOAD = dict(arch="granite-8b", batch=4, max_seq=96, requests=16,
                prompt_lo=8, prompt_hi=24, out_lo=8, out_hi=16,
                page_size=8, num_pages=13, prefill_chunk=4,
                bursts=4, burst_gap=6)
# cold-prefix: cross-lifetime retention.  One donor carries a 256-token
# (16 exact pages) system prompt and drains COMPLETELY; followers with the
# same system prompt arrive strictly AFTER it finished — zero donors
# mid-flight, so live-slot prefix sharing can never fire and every hit
# must come from the RETAINED pool (digest-keyed frozen pages of the dead
# donor).  Requests run one at a time for the same reason.  The baseline
# is the identical engine with retention disabled: every follower pays the
# full 256-token prefill cold.
COLD_PREFIX = dict(arch="granite-8b", batch=2, max_seq=320, sys_prompt=256,
                   tail_lo=4, tail_hi=8, out=8, requests=6,
                   page_size=16, prefill_chunk=4, prefill_chunk_tokens=64)
# speculative decoding: the long-decode workload (few slots x long
# generations — the regime speculation targets: ~90% pure-decode ticks)
# with a 1-LAYER draft proposing spec_k tokens per tick and a deepened
# `layers`-block target verifying the window in one ragged prefill-lane
# dispatch.  The target is DOCTORED so every block past the first is a
# residual no-op (attn wo and ffn w_down zeroed): the draft (= the
# doctored target's first layer + shared embed/ln_f) then agrees with
# the target exactly, pinning accept_rate at 1.0 — the bench measures
# the SPECULATION MACHINERY's ceiling, not a model-quality artifact that
# would jitter across PRs.  The deepening matters for the same reason a
# real deployment drafts with a small model: speculation trades k cheap
# draft steps + one ragged verify against k+1 FULL target steps, so the
# win scales with the target/draft cost ratio — at the 2-layer smoke
# depth the plain engine's own 8-step fused decode ticks are already
# host-bound and there is nothing left to save (measured 0.74x), while
# the 6-layer doctored target is compute-bound and the same machinery
# clears ~1.9x (same measurement-config reasoning as the int8 census).
# The doctored blocks still burn full-depth FLOPs; they just cannot
# change the function, so both sides of the comparison decode the SAME
# weights and the gate pins bit-identical token streams.
SPECULATIVE = dict(arch="granite-8b", layers=6, batch=2, max_seq=256,
                   requests=4, prompt=8, out=96, page_size=16,
                   prefill_chunk=8, spec_k=4)
# int8 quantized KV pages (--scenario ragged --kv-dtype int8): the SAME
# ragged drive at kv_dtype=int8 vs bf16 pools (tokens/s floor 0.9x), the
# exact token identity of the TWO quantized write paths (prefill lane vs
# prefill-by-decode — identical appended rows quantize identically, so the
# streams must match token-for-token), and the census-pinned byte claim.
# The census runs on a d_head=64 / float32-compute measurement config: at
# the smoke d_head of 16 the f32 scale rows would blur the per-row byte
# advantage ((16+4)/32 = 0.63 best case), and f32 compute keeps the CPU
# backend from wrapping pool scatters in whole-pool converts (same hygiene
# as the tier-1 census tests).  hbm bytes are compared as the SLOPE over
# block-table width (nb 2 -> 8 at fixed pool) — the live-token-
# proportional traffic the page sweep moves, with weight/FFN traffic
# (constant in nb) subtracted out — and pool-size independence is
# re-asserted on the int8 program (pool 33 vs 65 at equal live tokens)
INT8 = dict(census_d_head=64, census_page=16, census_batch=2,
            census_nb_lo=2, census_nb_hi=8, census_pools=(33, 65))
# restart: crash consistency.  An overload-sized workload is submitted
# upfront, the engine writes a full-state snapshot every few ticks, and a
# fault-plan `kill` drops the live engine mid-drive; recovery restores
# the newest snapshot into a FRESH engine, resubmits whatever the
# snapshot predates (rids realign by construction), re-arms the plan
# without the fired kill, and drains.  Gates: final outputs BIT-IDENTICAL
# to the uninterrupted oracle, zero non-kill crashes, a recompute-
# fraction ceiling (appended K/V work beyond the oracle's / the oracle's
# — the lost snapshot->kill window), and a restore-latency ceiling.
RESTART = dict(arch="granite-8b", batch=4, max_seq=96, requests=12,
               prompt_lo=8, prompt_hi=24, out_lo=8, out_hi=16,
               page_size=8, num_pages=13, prefill_chunk=4,
               snapshot_every=3, kill_after=8)


def _model(arch):
    from repro.configs import get
    from repro.models import get_model
    cfg = get(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def run() -> Dict[str, float]:
    from repro.serve.engine import PagedEngine, ServeConfig, ServingEngine
    cfg, model, params = _model(SMOKE["arch"])
    eng = ServingEngine(model, params,
                        ServeConfig(max_batch=SMOKE["batch"],
                                    max_seq=SMOKE["seq"]))
    stats = dict(eng.benchmark_decode(batch=SMOKE["batch"], seq=SMOKE["seq"],
                                      steps=SMOKE["steps"]))

    # continuous batching end-to-end: 2x batch requests over batch slots
    # (chunk 8 amortizes the per-tick host turnaround at smoke scale)
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=SMOKE["batch"], max_seq=256,
                                 max_new_tokens=8, prefill_chunk=8,
                                 prefix_sharing=False))
    rng = np.random.RandomState(0)
    # warm drive to completion: compiles BOTH decode cells (forced-prefill
    # and the pure-decode twin) before timing (the dirty-row patcher is
    # pre-warmed by the engine itself)
    pe.submit(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32))
    pe.run()

    # best of two timed waves (same treatment as the ragged/shared
    # drives): single ~20ms waves swing >2x under container contention
    def wave():
        tok0, joins0 = pe.tokens_out, pe.joins
        for _ in range(2 * SMOKE["batch"]):
            pe.submit(rng.randint(0, cfg.vocab_size,
                                  size=6).astype(np.int32))
        t0 = time.perf_counter()
        pe.run()
        dt = time.perf_counter() - t0
        return (pe.tokens_out - tok0) / max(dt, 1e-9), \
            float(pe.joins - joins0)

    tps, joins = max(wave() for _ in range(2))
    stats["continuous_tokens_per_s"] = tps
    stats["continuous_joins"] = joins
    return stats


def _drive(engine, reqs, defrag_every: int = 0) -> Dict[str, float]:
    """Submit a workload against a warm engine and time the drain, with an
    optional periodic defrag.  Tokens/joins/utilization are counted for
    THIS drive's ticks only (counters and traces accumulate across drives
    — the warm-up run must not leak into the timed window)."""
    joins0, ticks0 = engine.joins, engine.steps_run
    stalls0 = engine.stalls
    appended0, shared0 = engine.tokens_appended, engine.shared_tokens
    cow0 = engine.kv.cow_copies
    rids = [engine.submit(p, mnt) for p, mnt in reqs]
    t0 = time.perf_counter()
    while engine.busy:
        engine.step()
        if defrag_every and (engine.steps_run - ticks0) % defrag_every == 0:
            engine.defrag()
    dt = time.perf_counter() - t0
    results = engine.results
    n_tok = sum(len(results[r]) for r in rids)
    util = engine.util_trace[ticks0:]
    occ = engine.occupancy_trace[ticks0:]
    appended = engine.tokens_appended - appended0
    shared = engine.shared_tokens - shared0
    # tick-overhead traces for THIS drive's ticks (the full traces index
    # by tick, matching steps_run, whether or not pool traces are on)
    host_ms = engine.host_ms_trace[ticks0:]
    disp = engine.dispatch_trace[ticks0:]
    upload = engine.upload_trace[ticks0:]
    # a STEADY tick is one dispatch AND only the irreducible B-int
    # feed/grant upload — a forced-prefill tick can also run one dispatch
    # but carries (chunk, B) forced arrays, so classify on both
    base_upload = 2 * engine.cfg.max_batch * 4
    steady = [i for i, (d, u) in enumerate(zip(disp, upload))
              if d == 1 and u == base_upload]
    return {"tokens": float(n_tok), "seconds": dt,
            "tokens_per_s": n_tok / max(dt, 1e-9),
            "joins": float(engine.joins - joins0),
            "stalls": float(engine.stalls - stalls0),
            "util_mean": float(np.mean(util)) if util else 0.0,
            "util_max": float(np.max(util)) if util else 0.0,
            "occupancy_mean": float(np.mean(occ)) if occ else 0.0,
            "cow_copies": float(engine.kv.cow_copies - cow0),
            "shared_tokens": float(shared),
            "logical_physical_ratio": (appended + shared) / max(1, appended),
            "ticks": float(len(disp)),
            "host_ms_per_tick": float(np.mean(host_ms)) if host_ms else 0.0,
            "dispatches_per_tick": float(np.mean(disp)) if disp else 0.0,
            "upload_bytes_per_tick": float(np.mean(upload)) if upload
            else 0.0,
            "steady_ticks_frac": len(steady) / max(1, len(disp))}


def _drive_dense_lockstep(model, params, reqs, batch: int,
                          max_seq: int) -> Dict[str, float]:
    """Dense lockstep continuous-batching baseline — the retired lockstep
    engine's discipline, reconstructed as a measurement-only driver: all
    slots advance in LOCKSTEP over one shared dense cache position,
    prompts are prefilled BY DECODE (one token per shared step through
    the same compiled decode step), a joining request's ``start`` window
    masks the previous occupant's rows, and burned rows are never
    reclaimed (the workload must fit ``max_seq`` — exactly the limitation
    that retired the engine; the paged engine has no such bound)."""
    import jax.numpy as jnp
    from repro.models.model import sample_token

    def step_fn(params, tok, cache, key, forced_tok, forced_mask):
        logits, cache = model.decode_step(params, tok[:, None], cache)
        s, key = sample_token(logits, key, 0.0)
        return jnp.where(forced_mask, forced_tok, s), cache, key

    step = jax.jit(step_fn, donate_argnums=(2, 3))

    def drive():
        cache = model.init_cache(batch, max_seq)
        key = jax.random.key(0)
        queue = [([int(t) for t in p], mnt) for p, mnt in reqs]
        slots = [None] * batch            # [forced tokens, emitted, budget]
        feed = np.zeros((batch,), np.int32)
        pos, total = 0, 0
        t0 = time.perf_counter()
        while queue or any(slots):
            for i in range(batch):
                if slots[i] is None and queue:
                    toks, mnt = queue.pop(0)
                    slots[i] = [list(toks[1:]), 0, mnt]
                    cache["start"] = cache["start"].at[i].set(pos)
                    feed[i] = toks[0]
            ftok = np.zeros((batch,), np.int32)
            fmask = np.zeros((batch,), bool)
            for i, s in enumerate(slots):
                if s and s[0]:
                    ftok[i] = s[0].pop(0)
                    fmask[i] = True
            nxt, cache, key = step(params, jnp.asarray(feed), cache, key,
                                   jnp.asarray(ftok), jnp.asarray(fmask))
            pos += 1
            if pos + 1 >= max_seq:
                raise RuntimeError("dense baseline exhausted max_seq")
            nxt_np = np.asarray(nxt)
            for i, s in enumerate(slots):
                if not s:
                    continue
                if fmask[i]:
                    feed[i] = nxt_np[i]
                    continue
                s[1] += 1
                total += 1
                if s[1] >= s[2]:
                    slots[i] = None
                else:
                    feed[i] = nxt_np[i]
        return total, time.perf_counter() - t0

    drive()                               # compile
    total, dt = min((drive() for _ in range(2)), key=lambda r: r[1])
    return {"tokens": float(total), "seconds": dt,
            "tokens_per_s": total / max(dt, 1e-9)}


def _ragged_requests(cfg, rng) -> List:
    r = RAGGED
    return [(rng.randint(0, cfg.vocab_size,
                         size=rng.randint(r["prompt_lo"], r["prompt_hi"] + 1)
                         ).astype(np.int32),
             int(rng.randint(r["out_lo"], r["out_hi"] + 1)))
            for _ in range(r["requests"])]


def run_ragged() -> Dict[str, float]:
    """Ragged continuous batching: paged (non-lockstep, chunked prefill)
    vs the dense lockstep baseline at equal max_seq."""
    from repro.serve.engine import PagedEngine, ServeConfig
    r = RAGGED
    cfg, model, params = _model(r["arch"])
    rng = np.random.RandomState(0)
    reqs = _ragged_requests(cfg, rng)
    warm = [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 4)]

    d = _drive_dense_lockstep(model, params, reqs, r["batch"], r["max_seq"])

    paged = PagedEngine(
        model, params, ServeConfig(max_batch=r["batch"],
                                   max_seq=r["max_seq"],
                                   page_size=r["page_size"],
                                   prefill_chunk=r["prefill_chunk"]))
    _drive(paged, warm)                              # compile
    # best of two timed drives (both sides of the comparison get the same
    # treatment inside their drivers): container contention swings single
    # CPU-smoke drives by ~15%, which would jitter the tracked trajectory
    p = max((_drive(paged, reqs) for _ in range(2)),
            key=lambda s: s["tokens_per_s"])
    # untimed pass WITH periodic defrag: the utilization/occupancy stats
    # describe the compacted pool
    u = _drive(paged, reqs, defrag_every=r["defrag_every"])

    return {
        "ragged_tokens": p["tokens"],
        "ragged_tokens_per_s_paged": p["tokens_per_s"],
        "ragged_tokens_per_s_dense": d["tokens_per_s"],
        "ragged_paged_speedup": p["tokens_per_s"] / max(d["tokens_per_s"],
                                                        1e-9),
        "ragged_joins_paged": p["joins"],
        "ragged_page_util_mean": u["util_mean"],
        "ragged_page_util_max": u["util_max"],
        "ragged_page_occupancy_mean": u["occupancy_mean"],
        "ragged_paged_stalls": p["stalls"],
    }


def _census_hbm(kv_dtype: str):
    """Compiled-program HBM byte census of one paged decode step at the
    int8-measurement config (d_head=64, f32 compute — see the INT8 config
    comment).  Returns (slope, pool_independent): slope is the live-token-
    proportional byte traffic hbm(nb_hi) - hbm(nb_lo) at the big pool;
    pool_independent re-asserts that doubling the POOL at fixed nb moves
    zero extra bytes on this program."""
    import dataclasses
    import jax.numpy as jnp
    from repro.configs import get
    from repro.core.hlo_counters import census_from_compiled
    from repro.models import get_model
    c = INT8
    cfg = dataclasses.replace(get(RAGGED["arch"]).reduced(),
                              dtype="float32", d_head=c["census_d_head"],
                              kv_dtype=kv_dtype)
    model = get_model(cfg)
    B, page = c["census_batch"], c["census_page"]

    def hbm(nb, pool):
        cache = model.abstract_paged_cache(B, nb, page, pool)
        compiled = jax.jit(lambda p, t, cc: model.decode_step_paged(p, t, cc),
                           donate_argnums=(2,)).lower(
            model.abstract_params(),
            jax.ShapeDtypeStruct((B, 1), jnp.int32), cache).compile()
        cen = census_from_compiled(compiled)
        return cen.hbm_bytes, cen.irregular_bytes

    pool_lo, pool_hi = c["census_pools"]
    base, base_irr = hbm(c["census_nb_lo"], pool_hi)
    hi_all, hi_irr = hbm(c["census_nb_hi"], pool_hi)
    small, _ = hbm(c["census_nb_lo"], pool_lo)
    # the POOL-resident traffic is the irregular (gather) slice of the
    # slope: the CPU backend materializes the dequantized f32 pages as a
    # regular intermediate, which dilutes the total-HBM ratio without
    # touching a single extra pool byte
    return hi_all - base, hi_irr - base_irr, small == base


def run_ragged_int8() -> Dict[str, float]:
    """Quantized KV pages: the ragged drive on int8 pools vs bf16 pools
    (same weights, same workload), the exact token identity of the two
    quantized WRITE paths (prefill lane vs prefill-by-decode), the
    census-pinned per-live-token byte ratio, and the resident-token
    capacity ratio from the engines' own page_bytes."""
    from repro.serve.engine import PagedEngine, ServeConfig
    r = RAGGED
    cfg, model, params = _model(r["arch"])
    import dataclasses
    from repro.models import get_model
    cfg8 = dataclasses.replace(cfg, kv_dtype="int8")
    model8 = get_model(cfg8)          # same weights: kv_dtype only touches
    rng = np.random.RandomState(0)    # the cache decls, never the params
    reqs = _ragged_requests(cfg, rng)
    warm = [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 4)]

    def scfg(**over):
        return ServeConfig(max_batch=r["batch"], max_seq=r["max_seq"],
                           page_size=r["page_size"],
                           prefill_chunk=r["prefill_chunk"], **over)

    engines = {}
    drives = {}
    for name, m in (("bf16", model), ("int8", model8)):
        pe = PagedEngine(m, params, scfg())
        _drive(pe, warm)                             # compile
        drives[name] = max((_drive(pe, reqs) for _ in range(2)),
                           key=lambda s: s["tokens_per_s"])
        engines[name] = pe

    # write-path identity: the prefill LANE quantizes a whole ragged chunk
    # of rows at once, prefill-by-decode quantizes the same rows one tick
    # at a time — per-row scales make those bit-identical, so the emitted
    # streams must match token-for-token
    def emitted(lane):
        pe = PagedEngine(model8, params, scfg(prefill_lane=lane))
        rids = [pe.submit(p, mnt) for p, mnt in reqs]
        pe.run()
        return [[int(t) for t in pe.results[i]] for i in rids]

    identity = emitted(True) == emitted(False)

    slope8, pool8, indep8 = _census_hbm("int8")
    slope_wide, pool_wide, indep_wide = _census_hbm("bf16")

    p8, pb = drives["int8"], drives["bf16"]
    return {
        "int8_tokens": p8["tokens"],
        "int8_tokens_per_s": p8["tokens_per_s"],
        "int8_tokens_per_s_bf16": pb["tokens_per_s"],
        "int8_bf16_tokens_ratio": (p8["tokens_per_s"]
                                   / max(pb["tokens_per_s"], 1e-9)),
        "int8_token_identity": float(identity),
        "int8_hbm_slope": float(slope8),
        "int8_hbm_slope_wide": float(slope_wide),
        "int8_hbm_ratio": slope8 / max(slope_wide, 1),
        "int8_pool_bytes_slope": float(pool8),
        "int8_pool_bytes_slope_wide": float(pool_wide),
        "int8_pool_bytes_ratio": pool8 / max(pool_wide, 1),
        "int8_pool_independent": float(indep8 and indep_wide),
        "int8_page_bytes": float(engines["int8"].kv.page_bytes),
        "int8_page_bytes_bf16": float(engines["bf16"].kv.page_bytes),
        "int8_capacity_ratio": (engines["bf16"].kv.page_bytes
                                / engines["int8"].kv.page_bytes),
    }


def run_long_decode() -> Dict[str, float]:
    """Long-decode serving: few slots, long generations — the tick-
    overhead showcase.  Tokens/s plus per-tick host cost, dispatch count
    and upload bytes from the engine traces (pool-walk traces off: this
    is the thin production tick)."""
    from repro.serve.engine import PagedEngine, ServeConfig
    L = LONG_DECODE
    cfg, model, params = _model(L["arch"])
    rng = np.random.RandomState(0)
    reqs = [(rng.randint(0, cfg.vocab_size,
                         size=L["prompt"]).astype(np.int32), L["out"])
            for _ in range(L["requests"])]
    warm = [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 4)]
    pe = PagedEngine(
        model, params, ServeConfig(max_batch=L["batch"],
                                   max_seq=L["max_seq"],
                                   page_size=L["page_size"],
                                   prefill_chunk=L["prefill_chunk"],
                                   trace_pool=False))
    _drive(pe, warm)                                 # compile both cells
    p = max((_drive(pe, reqs) for _ in range(2)),
            key=lambda s: s["tokens_per_s"])
    return {
        "long_decode_tokens": p["tokens"],
        "long_decode_tokens_per_s": p["tokens_per_s"],
        "long_decode_ticks": p["ticks"],
        "tick_host_ms": p["host_ms_per_tick"],
        "tick_dispatches": p["dispatches_per_tick"],
        "tick_upload_bytes": p["upload_bytes_per_tick"],
        "tick_steady_frac": p["steady_ticks_frac"],
    }


def run_long_prompt() -> Dict[str, float]:
    """Long-prompt serving: few slots, 256-token prompts, short outputs —
    the admission-latency showcase.  The ragged multi-token prefill lane
    (one compiled kernel step per 64-token chunk) against the SAME engine
    with the lane disabled (prefill-by-decode: one decode step per prompt
    token), at equal pool/page/chunk config.  Reports PROMPT tokens/s and
    pins the lane's zero-forced-upload claim (prompt traffic moves as one
    ragged (B, T) block per chunk, never as per-step forced arrays)."""
    from repro.serve.engine import PagedEngine, ServeConfig
    L = LONG_PROMPT
    cfg, model, params = _model(L["arch"])
    rng = np.random.RandomState(0)
    reqs = [(rng.randint(0, cfg.vocab_size,
                         size=L["prompt"]).astype(np.int32), L["out"])
            for _ in range(L["requests"])]
    warm = [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 4)]
    prompt_tokens = sum(len(p) for p, _ in reqs)

    stats = {}
    for name, lane in (("lane", True), ("decode", False)):
        pe = PagedEngine(
            model, params,
            ServeConfig(max_batch=L["batch"], max_seq=L["max_seq"],
                        page_size=L["page_size"],
                        prefill_chunk=L["prefill_chunk"],
                        prefill_lane=lane,
                        prefill_chunk_tokens=L["prefill_chunk_tokens"],
                        trace_pool=False))
        _drive(pe, warm)                             # compile all cells
        best = max((_drive(pe, reqs) for _ in range(2)),
                   key=lambda s: s["tokens_per_s"])
        best["prompt_tokens_per_s"] = prompt_tokens / best["seconds"]
        best["forced_upload_bytes"] = float(pe.forced_upload_bytes)
        stats[name] = best

    lane, dec = stats["lane"], stats["decode"]
    return {
        "long_prompt_tokens": float(prompt_tokens),
        "long_prompt_tokens_per_s_lane": lane["prompt_tokens_per_s"],
        "long_prompt_tokens_per_s_decode": dec["prompt_tokens_per_s"],
        "long_prompt_speedup": (lane["prompt_tokens_per_s"]
                                / max(dec["prompt_tokens_per_s"], 1e-9)),
        "long_prompt_ticks_lane": lane["ticks"],
        "long_prompt_ticks_decode": dec["ticks"],
        "long_prompt_forced_upload_bytes": lane["forced_upload_bytes"],
    }


def _shared_requests(cfg, rng) -> List:
    s = SHARED
    sys_prompt = rng.randint(0, cfg.vocab_size,
                             size=s["sys_prompt"]).astype(np.int32)
    return [(np.concatenate(
                [sys_prompt,
                 rng.randint(0, cfg.vocab_size,
                             size=rng.randint(s["tail_lo"], s["tail_hi"] + 1)
                             ).astype(np.int32)]),
             int(rng.randint(s["out_lo"], s["out_hi"] + 1)))
            for _ in range(s["requests"])]


def run_shared() -> Dict[str, float]:
    """Shared-prefix serving: a common system prompt across 3x batch
    requests — prefix-sharing paged engine vs sharing disabled at equal
    pool size."""
    from repro.serve.engine import PagedEngine, ServeConfig
    s = SHARED
    cfg, model, params = _model(s["arch"])
    rng = np.random.RandomState(0)
    reqs = _shared_requests(cfg, rng)
    warm = [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 4)]

    stats = {}
    for name, sharing in (("shared", True), ("unshared", False)):
        pe = PagedEngine(
            model, params, ServeConfig(max_batch=s["batch"],
                                       max_seq=s["max_seq"],
                                       page_size=s["page_size"],
                                       prefill_chunk=s["prefill_chunk"],
                                       prefix_sharing=sharing))
        _drive(pe, warm)                             # compile
        stats[name] = max((_drive(pe, reqs) for _ in range(2)),
                          key=lambda r: r["tokens_per_s"])

    sh, un = stats["shared"], stats["unshared"]
    return {
        "shared_tokens_per_s": sh["tokens_per_s"],
        "shared_tokens_per_s_unshared": un["tokens_per_s"],
        "shared_speedup": sh["tokens_per_s"] / max(un["tokens_per_s"], 1e-9),
        "shared_logical_physical_ratio": sh["logical_physical_ratio"],
        "shared_prefix_tokens": sh["shared_tokens"],
        "shared_cow_copies": sh["cow_copies"],
        "shared_unshared_cow_copies": un["cow_copies"],
        "shared_joins": sh["joins"],
    }


def run_overload() -> Dict[str, float]:
    """Overload serving: bursty submits oversubscribing the page pool ~4x.
    The engine survives on preempt-and-recompute (no crashed ticks, every
    request reaches a typed terminal status); the tracked metrics are
    GOODPUT (tokens of completed — FINISHED or PREEMPTED_RESUMED —
    requests per second), the preemption count, and the recompute-token
    fraction (re-appended K/V rows / all appended rows — the price of
    surviving the burst)."""
    from repro.serve.engine import (PagedEngine, RequestStatus, ServeConfig,
                                    TERMINAL_STATUSES)
    o = OVERLOAD
    cfg, model, params = _model(o["arch"])
    rng = np.random.RandomState(0)
    reqs = [(rng.randint(0, cfg.vocab_size,
                         size=rng.randint(o["prompt_lo"], o["prompt_hi"] + 1)
                         ).astype(np.int32),
             int(rng.randint(o["out_lo"], o["out_hi"] + 1)))
            for _ in range(o["requests"])]
    demand = sum(len(p) + mnt for p, mnt in reqs)
    pool = (o["num_pages"] - 1) * o["page_size"]
    pe = PagedEngine(
        model, params, ServeConfig(max_batch=o["batch"],
                                   max_seq=o["max_seq"],
                                   page_size=o["page_size"],
                                   num_pages=o["num_pages"],
                                   prefill_chunk=o["prefill_chunk"],
                                   trace_pool=False))
    _drive(pe, [(rng.randint(0, cfg.vocab_size,
                             size=6).astype(np.int32), 4)])   # compile

    def drive():
        burst = -(-len(reqs) // o["bursts"])
        appended0 = pe.tokens_appended
        recompute0 = pe.recompute_tokens
        preempt0 = pe.preemptions
        rids, crashed, k = [], 0, 0
        next_burst = pe.ticks
        t0 = time.perf_counter()
        while k < len(reqs) or pe.busy:
            if k < len(reqs) and pe.ticks >= next_burst:
                for p, mnt in reqs[k:k + burst]:    # bursty submit order
                    rids.append(pe.submit(p, mnt))
                k += burst
                next_burst = pe.ticks + o["burst_gap"]
            try:
                pe.step()
            except Exception:
                crashed += 1                        # gated to stay 0
                break
        dt = time.perf_counter() - t0
        done = (RequestStatus.FINISHED, RequestStatus.PREEMPTED_RESUMED)
        good = sum(len(pe.results[r]) for r in rids
                   if pe.status[r] in done)
        appended = pe.tokens_appended - appended0
        return {
            "goodput_tokens": float(good),
            "goodput_tokens_per_s": good / max(dt, 1e-9),
            "preemptions": float(pe.preemptions - preempt0),
            "recompute_fraction": (pe.recompute_tokens - recompute0)
            / max(1, appended),
            "crashed_ticks": float(crashed),
            "all_terminal": float(all(pe.status[r] in TERMINAL_STATUSES
                                      for r in rids)),
        }

    best = max((drive() for _ in range(2)),
               key=lambda s: s["goodput_tokens_per_s"])
    return {
        "overload_oversubscription": demand / pool,
        "overload_goodput_tokens": best["goodput_tokens"],
        "overload_goodput_tokens_per_s": best["goodput_tokens_per_s"],
        "overload_preemptions": best["preemptions"],
        "overload_recompute_fraction": best["recompute_fraction"],
        "overload_crashed_ticks": best["crashed_ticks"],
        "overload_all_terminal": best["all_terminal"],
    }


def run_restart() -> Dict[str, float]:
    """Crash-consistent serving: kill-and-restore mid-drive vs the
    uninterrupted oracle (see the RESTART config comment).  The restore
    path is the real one end-to-end — ``latest_snapshot`` picks the
    newest checksum-valid file, ``restore_engine`` rebuilds a fresh
    engine, requests the snapshot predates are resubmitted in original
    order, and the re-armed plan replays the recoverable window
    deterministically."""
    import shutil
    import tempfile
    from repro.serve import snapshot as snap
    from repro.serve.engine import (PagedEngine, ServeConfig,
                                    TERMINAL_STATUSES)
    from repro.serve.faults import EngineKilled, FaultEvent, FaultPlan
    r = RESTART
    cfg, model, params = _model(r["arch"])
    rng = np.random.RandomState(0)
    reqs = [(rng.randint(0, cfg.vocab_size,
                         size=rng.randint(r["prompt_lo"], r["prompt_hi"] + 1)
                         ).astype(np.int32),
             int(rng.randint(r["out_lo"], r["out_hi"] + 1)))
            for _ in range(r["requests"])]
    warm = [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 4)]

    def mk(snap_dir=""):
        return PagedEngine(
            model, params,
            ServeConfig(max_batch=r["batch"], max_seq=r["max_seq"],
                        page_size=r["page_size"],
                        num_pages=r["num_pages"],
                        prefill_chunk=r["prefill_chunk"],
                        trace_pool=False,
                        snapshot_every_ticks=r["snapshot_every"]
                        if snap_dir else 0,
                        snapshot_dir=snap_dir))

    # ORACLE: identical engine + workload, never killed
    pe = mk()
    _drive(pe, warm)                                 # compile all cells
    orids = [pe.submit(p, mnt) for p, mnt in reqs]
    a0 = pe.tokens_appended
    while pe.busy:
        pe.step()
    oracle = {rid: [int(t) for t in pe.results[rid]] for rid in orids}
    oracle_appended = max(1, pe.tokens_appended - a0)

    snap_dir = tempfile.mkdtemp(prefix="serve-restart-")
    try:
        pe = mk(snap_dir)
        _drive(pe, warm)
        plan = FaultPlan([FaultEvent(pe.ticks + r["kill_after"], "kill")])
        pe.install_faults(plan)
        submitted = []
        for p, mnt in reqs:
            submitted.append((pe.submit(p, mnt), p, mnt))
        rids = [rid for rid, _, _ in submitted]
        work = 0
        base = pe.tokens_appended
        kills = crashed = replayed = 0
        restore_ms = 0.0
        while pe.busy:
            try:
                pe.step()
            except EngineKilled as e:
                kills += 1
                work += pe.tokens_appended - base    # incl. the lost window
                latest = snap.latest_snapshot(snap_dir)
                fresh = mk(snap_dir)
                t0 = time.perf_counter()
                if latest is not None:
                    snap.restore_engine(fresh, latest)
                restore_ms += (time.perf_counter() - t0) * 1e3
                # requests the snapshot predates resubmit in original
                # order — the rid counter was snapshotted, so they
                # realign exactly
                for rid, p, mnt in submitted:
                    if rid >= fresh._next_rid:
                        assert fresh.submit(p, mnt) == rid
                plan = plan.without_kills_through(e.tick)
                fresh.install_faults(plan)
                replayed += max(0, e.tick - fresh.ticks)
                pe = fresh
                base = pe.tokens_appended
            except Exception:
                crashed += 1                         # gated to stay 0
                break
        work += pe.tokens_appended - base
        got = {rid: [int(t) for t in pe.results.get(rid, [])]
               for rid in rids}
        identity = got == oracle
        all_terminal = all(pe.status.get(rid) in TERMINAL_STATUSES
                           for rid in rids)
        # snapshot write cost + size, measured on the drained engine (a
        # busier snapshot is the same pools + a longer queue JSON)
        t0 = time.perf_counter()
        path = snap.save_snapshot(
            pe, snap.snapshot_path(snap_dir, pe.ticks + 1))
        write_ms = (time.perf_counter() - t0) * 1e3
        snapshot_bytes = os.path.getsize(path)
        snapshots = pe.snapshots_written
    finally:
        shutil.rmtree(snap_dir, ignore_errors=True)
    return {
        "restart_token_identity": float(identity and all_terminal),
        "restart_crashed_ticks": float(crashed),
        "restart_kills": float(kills),
        "restart_restore_ms": restore_ms,
        "restart_snapshot_write_ms": write_ms,
        "restart_snapshot_bytes": float(snapshot_bytes),
        "restart_snapshots_written": float(snapshots),
        "restart_ticks_replayed": float(replayed),
        "restart_recompute_fraction": (work - oracle_appended)
        / oracle_appended,
    }


def run_speculative() -> Dict[str, float]:
    """Speculative decoding: draft-and-verify multi-token decode ticks on
    the long-decode workload, against the SAME engine with speculation
    off.  Every target block past the first is doctored into a residual
    no-op so the 1-layer draft slice agrees with the deepened target
    exactly (accept_rate pinned at 1.0 — see the SPECULATIVE config
    comment); both engines decode the doctored weights, so the comparison
    isolates the machinery.  Gates: bit-identical token streams, zero
    crashed ticks, and the tokens/s speedup floor (verify.sh pins
    >= 1.3x)."""
    import dataclasses
    from repro.configs import get
    from repro.models import get_model
    from repro.serve.engine import PagedEngine, ServeConfig
    S = SPECULATIVE
    cfg = dataclasses.replace(get(S["arch"]).reduced(),
                              n_layers=S["layers"])
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    # doctor blocks 1..L-1 into residual no-ops: attn output proj and ffn
    # down proj zeroed -> those blocks contribute nothing to the residual
    # stream (but still cost full-depth compute on the target side)
    blocks = dict(params["blocks"])
    blocks["attn"] = dict(blocks["attn"],
                          wo=blocks["attn"]["wo"].at[1:].set(0))
    blocks["ffn"] = dict(blocks["ffn"],
                         w_down=blocks["ffn"]["w_down"].at[1:].set(0))
    params = dict(params, blocks=blocks)
    dcfg = dataclasses.replace(cfg, n_layers=1)
    dmodel = get_model(dcfg)
    dparams = dict(params)
    dparams["blocks"] = jax.tree.map(lambda x: x[:1], params["blocks"])

    rng = np.random.RandomState(0)
    reqs = [(rng.randint(0, cfg.vocab_size,
                         size=S["prompt"]).astype(np.int32), S["out"])
            for _ in range(S["requests"])]
    warm = [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 4)]

    def mk(spec_k):
        return PagedEngine(
            model, params,
            ServeConfig(max_batch=S["batch"], max_seq=S["max_seq"],
                        page_size=S["page_size"],
                        prefill_chunk=S["prefill_chunk"],
                        spec_k=spec_k, trace_pool=False),
            draft_model=dmodel if spec_k else None,
            draft_params=dparams if spec_k else None)

    stats, outs, engines = {}, {}, {}
    crashed = 0
    for name, k in (("spec", S["spec_k"]), ("plain", 0)):
        pe = mk(k)
        try:
            _drive(pe, warm)                         # compile all cells
            stats[name] = max((_drive(pe, reqs) for _ in range(2)),
                              key=lambda s: s["tokens_per_s"])
            # untimed identity drive on the same engine (results persist)
            rids = [pe.submit(p, mnt) for p, mnt in reqs]
            pe.run()
            outs[name] = [[int(t) for t in pe.results[r]] for r in rids]
        except Exception:
            crashed += 1                             # gated to stay 0
            stats[name] = {"tokens": 0.0, "tokens_per_s": 0.0, "ticks": 0.0}
            outs[name] = None
        engines[name] = pe

    sp, pl = stats["spec"], stats["plain"]
    pe = engines["spec"]
    identity = outs["spec"] is not None and outs["spec"] == outs["plain"]
    ddisp = pe.draft_dispatch_trace
    vdisp = pe.verify_dispatch_trace
    return {
        "speculative_tokens": sp["tokens"],
        "speculative_tokens_per_s": sp["tokens_per_s"],
        "speculative_tokens_per_s_plain": pl["tokens_per_s"],
        "speculative_speedup": (sp["tokens_per_s"]
                                / max(pl["tokens_per_s"], 1e-9)),
        "speculative_accept_rate": pe.accept_rate,
        "speculative_token_identity": float(identity),
        "speculative_crashed_ticks": float(crashed),
        "speculative_ticks": sp["ticks"],
        "speculative_ticks_plain": pl["ticks"],
        "speculative_tokens_per_tick": sp["tokens"] / max(sp["ticks"], 1.0),
        "speculative_draft_dispatches_per_tick": (float(np.mean(ddisp))
                                                  if ddisp else 0.0),
        "speculative_verify_dispatches_per_tick": (float(np.mean(vdisp))
                                                   if vdisp else 0.0),
        "speculative_trunc_tokens": float(pe.spec_trunc_tokens),
    }


def run_cold_prefix() -> Dict[str, float]:
    """Cross-lifetime prefix retention: followers repeating a dead donor's
    256-token system prompt, submitted strictly AFTER the donor drained
    and run one at a time (no live donor can ever exist), against the
    identical engine with retention off.  Tracks the retained hit rate
    (every follower must adopt), re-shared tokens, a TTFT proxy (engine
    ticks per request — the prefill ticks retention skips), and the
    warm-vs-cold tokens/s speedup."""
    from repro.serve.engine import PagedEngine, ServeConfig
    c = COLD_PREFIX
    cfg, model, params = _model(c["arch"])
    rng = np.random.RandomState(0)
    sys_prompt = rng.randint(0, cfg.vocab_size,
                             size=c["sys_prompt"]).astype(np.int32)
    reqs = [np.concatenate(
                [sys_prompt,
                 rng.randint(0, cfg.vocab_size,
                             size=rng.randint(c["tail_lo"], c["tail_hi"] + 1)
                             ).astype(np.int32)])
            for _ in range(1 + c["requests"])]       # [0] is the donor
    warm_req = [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 4)]

    stats = {}
    for name, retain in (("warm", True), ("cold", False)):
        pe = PagedEngine(
            model, params,
            ServeConfig(max_batch=c["batch"], max_seq=c["max_seq"],
                        page_size=c["page_size"],
                        prefill_chunk=c["prefill_chunk"],
                        prefill_chunk_tokens=c["prefill_chunk_tokens"],
                        retain_prefixes=retain, trace_pool=False))
        _drive(pe, warm_req)                         # compile both cells
        pe.submit(reqs[0], c["out"])
        pe.run()                                     # donor drains; slot FREED
        assert pe.kv.live_pages == 0

        def followers():
            hits, ticks0, tok0 = 0, pe.steps_run, pe.tokens_out
            t0 = time.perf_counter()
            for p in reqs[1:]:
                h0 = pe.kv.retained_hits
                pe.submit(p, c["out"])
                pe.run()                             # one request at a time
                hits += int(pe.kv.retained_hits > h0)
            dt = time.perf_counter() - t0
            n = len(reqs) - 1
            return {"hit_rate": hits / n,
                    "tokens_per_s": (pe.tokens_out - tok0) / max(dt, 1e-9),
                    "ticks_per_req": (pe.steps_run - ticks0) / n}

        stats[name] = max((followers() for _ in range(2)),
                          key=lambda s: s["tokens_per_s"])
        stats[name]["retained_hit_tokens"] = float(pe.kv.retained_hit_tokens)

    w, cold = stats["warm"], stats["cold"]
    return {
        "cold_prefix_hit_rate": w["hit_rate"],
        "cold_prefix_retained_tokens": w["retained_hit_tokens"],
        "cold_prefix_tokens_per_s": w["tokens_per_s"],
        "cold_prefix_tokens_per_s_cold": cold["tokens_per_s"],
        "cold_prefix_speedup": w["tokens_per_s"] / max(cold["tokens_per_s"],
                                                       1e-9),
        "cold_prefix_ticks_per_req": w["ticks_per_req"],
        "cold_prefix_ticks_per_req_cold": cold["ticks_per_req"],
        "cold_prefix_cold_hit_rate": cold["hit_rate"],   # must stay 0
    }


def bench_lines_from(stats: Dict[str, float]) -> List[str]:
    name = f"serve/{SMOKE['arch']}-reduced-decode"
    lines = []
    if "s_per_step_fused" in stats:
        lines += [
            f"{name},{stats['s_per_step_fused']*1e6:.0f},"
            f"tokens_per_s={stats['tokens_per_s_fused']:.1f}",
            f"{name}-legacy-loop,{stats['s_per_step_loop']*1e6:.0f},"
            f"tokens_per_s={stats['tokens_per_s_loop']:.1f}",
            f"{name}-fused-speedup,0,x{stats['fused_speedup']:.2f}",
            f"serve/continuous-batching,0,"
            f"tokens_per_s={stats['continuous_tokens_per_s']:.1f}",
        ]
    if "ragged_tokens_per_s_paged" in stats:
        lines += [
            f"serve/ragged-paged,0,"
            f"tokens_per_s={stats['ragged_tokens_per_s_paged']:.1f}",
            f"serve/ragged-dense,0,"
            f"tokens_per_s={stats['ragged_tokens_per_s_dense']:.1f}",
            f"serve/ragged-paged-speedup,0,"
            f"x{stats['ragged_paged_speedup']:.2f}",
            f"serve/ragged-page-util,0,"
            f"mean={stats['ragged_page_util_mean']:.2f}"
            f"/max={stats['ragged_page_util_max']:.2f}",
        ]
    if "int8_tokens_per_s" in stats:
        lines += [
            f"serve/ragged-int8,0,"
            f"tokens_per_s={stats['int8_tokens_per_s']:.1f}",
            f"serve/ragged-int8-vs-bf16,0,"
            f"x{stats['int8_bf16_tokens_ratio']:.2f}",
            f"serve/int8-pool-bytes,0,"
            f"ratio={stats['int8_pool_bytes_ratio']:.2f}"
            f"/hbm_ratio={stats['int8_hbm_ratio']:.2f}"
            f"/pool_independent={stats['int8_pool_independent']:.0f}",
            f"serve/int8-capacity,0,"
            f"x{stats['int8_capacity_ratio']:.2f}"
            f"/token_identity={stats['int8_token_identity']:.0f}",
        ]
    if "long_decode_tokens_per_s" in stats:
        lines += [
            f"serve/long-decode,0,"
            f"tokens_per_s={stats['long_decode_tokens_per_s']:.1f}",
            f"serve/tick-overhead,{stats['tick_host_ms']*1e3:.0f},"
            f"host_ms={stats['tick_host_ms']:.3f}"
            f"/dispatches={stats['tick_dispatches']:.2f}"
            f"/upload_B={stats['tick_upload_bytes']:.0f}",
            f"serve/tick-steady,0,frac={stats['tick_steady_frac']:.2f}",
        ]
    if "long_prompt_tokens_per_s_lane" in stats:
        lines += [
            f"serve/long-prompt-lane,0,"
            f"prompt_tokens_per_s={stats['long_prompt_tokens_per_s_lane']:.1f}",
            f"serve/long-prompt-decode,0,"
            f"prompt_tokens_per_s="
            f"{stats['long_prompt_tokens_per_s_decode']:.1f}",
            f"serve/long-prompt-speedup,0,"
            f"x{stats['long_prompt_speedup']:.2f}",
        ]
    if "shared_tokens_per_s" in stats:
        lines += [
            f"serve/shared-prefix,0,"
            f"tokens_per_s={stats['shared_tokens_per_s']:.1f}",
            f"serve/shared-prefix-unshared,0,"
            f"tokens_per_s={stats['shared_tokens_per_s_unshared']:.1f}",
            f"serve/shared-prefix-speedup,0,"
            f"x{stats['shared_speedup']:.2f}",
            f"serve/shared-prefix-ratio,0,"
            f"logical/physical={stats['shared_logical_physical_ratio']:.2f}",
        ]
    if "overload_goodput_tokens_per_s" in stats:
        lines += [
            f"serve/overload-goodput,0,"
            f"tokens_per_s={stats['overload_goodput_tokens_per_s']:.1f}",
            f"serve/overload-preemptions,0,"
            f"n={stats['overload_preemptions']:.0f}"
            f"/recompute_frac={stats['overload_recompute_fraction']:.2f}",
            f"serve/overload-safety,0,"
            f"crashed_ticks={stats['overload_crashed_ticks']:.0f}"
            f"/all_terminal={stats['overload_all_terminal']:.0f}",
        ]
    if "speculative_tokens_per_s" in stats:
        lines += [
            f"serve/speculative,0,"
            f"tokens_per_s={stats['speculative_tokens_per_s']:.1f}",
            f"serve/speculative-plain,0,"
            f"tokens_per_s={stats['speculative_tokens_per_s_plain']:.1f}",
            f"serve/speculative-speedup,0,"
            f"x{stats['speculative_speedup']:.2f}",
            f"serve/speculative-accept,0,"
            f"rate={stats['speculative_accept_rate']:.2f}"
            f"/tokens_per_tick={stats['speculative_tokens_per_tick']:.2f}",
            f"serve/speculative-safety,0,"
            f"token_identity={stats['speculative_token_identity']:.0f}"
            f"/crashed_ticks={stats['speculative_crashed_ticks']:.0f}",
            f"serve/speculative-dispatches,0,"
            f"draft={stats['speculative_draft_dispatches_per_tick']:.2f}"
            f"/verify={stats['speculative_verify_dispatches_per_tick']:.2f}",
        ]
    if "restart_restore_ms" in stats:
        lines += [
            f"serve/restart-restore,{stats['restart_restore_ms']*1e3:.0f},"
            f"restore_ms={stats['restart_restore_ms']:.1f}"
            f"/write_ms={stats['restart_snapshot_write_ms']:.1f}"
            f"/bytes={stats['restart_snapshot_bytes']:.0f}",
            f"serve/restart-recompute,0,"
            f"frac={stats['restart_recompute_fraction']:.2f}"
            f"/ticks_replayed={stats['restart_ticks_replayed']:.0f}",
            f"serve/restart-safety,0,"
            f"token_identity={stats['restart_token_identity']:.0f}"
            f"/crashed_ticks={stats['restart_crashed_ticks']:.0f}"
            f"/kills={stats['restart_kills']:.0f}",
        ]
    if "cold_prefix_tokens_per_s" in stats:
        lines += [
            f"serve/cold-prefix,0,"
            f"tokens_per_s={stats['cold_prefix_tokens_per_s']:.1f}",
            f"serve/cold-prefix-cold,0,"
            f"tokens_per_s={stats['cold_prefix_tokens_per_s_cold']:.1f}",
            f"serve/cold-prefix-speedup,0,"
            f"x{stats['cold_prefix_speedup']:.2f}",
            f"serve/cold-prefix-hits,0,"
            f"hit_rate={stats['cold_prefix_hit_rate']:.2f}"
            f"/retained_tokens={stats['cold_prefix_retained_tokens']:.0f}",
            f"serve/cold-prefix-ttft-proxy,0,"
            f"ticks_per_req={stats['cold_prefix_ticks_per_req']:.1f}"
            f"/cold={stats['cold_prefix_ticks_per_req_cold']:.1f}",
        ]
    return lines


def bench() -> List[str]:
    stats = run()
    stats.update(run_ragged())
    stats.update(run_shared())
    stats.update(run_long_decode())
    stats.update(run_long_prompt())
    return bench_lines_from(stats)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_serve.json next to the repo root")
    ap.add_argument("--scenario",
                    choices=("smoke", "ragged", "shared-prefix",
                             "long-decode", "long-prompt", "overload",
                             "cold-prefix", "speculative", "restart",
                             "all"),
                    default="all",
                    help="smoke: fused-vs-loop decode; ragged: paged vs "
                         "dense waves under mixed lengths; shared-prefix: "
                         "prefix sharing vs no sharing at equal pool; "
                         "long-decode: few slots x long generations with "
                         "per-tick host-overhead metrics; long-prompt: "
                         "few slots x 256-token prompts — the ragged "
                         "prefill lane vs prefill-by-decode; overload: "
                         "bursty submits ~4x oversubscribing the pool — "
                         "goodput under preempt-and-recompute; cold-prefix: "
                         "repeated system prompt whose donor fully drained "
                         "before the followers arrive — cross-lifetime "
                         "retained-page sharing vs a retention-off engine; "
                         "speculative: draft-and-verify multi-token decode "
                         "ticks (accept rate pinned at 1.0 by a doctored "
                         "target) vs the same engine speculating off — "
                         "bit-identical streams gated, speedup floor "
                         "gated in verify.sh; restart: kill-and-restore "
                         "crash drill — snapshot every few ticks, kill "
                         "mid-drive, restore into a fresh engine and "
                         "drain; bit-identical to the uninterrupted "
                         "oracle, restore latency and recompute "
                         "fraction gated")
    ap.add_argument("--kv-dtype", choices=("bf16", "int8"), default="bf16",
                    help="int8 + --scenario ragged runs the quantized-KV "
                         "comparison (int8 vs bf16 pools on the ragged "
                         "workload, write-path token identity, census byte "
                         "ratio) and writes the ragged_int8 section instead "
                         "of re-measuring the bf16 ragged section")
    args = ap.parse_args()
    int8_run = args.kv_dtype == "int8" and args.scenario in ("ragged", "all")
    stats: Dict[str, float] = {}
    if args.scenario in ("smoke", "all"):
        stats.update(run())
    if int8_run:
        stats.update(run_ragged_int8())
    elif args.scenario in ("ragged", "all"):
        stats.update(run_ragged())
    if args.scenario in ("shared-prefix", "all"):
        stats.update(run_shared())
    if args.scenario in ("long-decode", "all"):
        stats.update(run_long_decode())
    if args.scenario in ("long-prompt", "all"):
        stats.update(run_long_prompt())
    if args.scenario in ("overload", "all"):
        stats.update(run_overload())
    if args.scenario in ("cold-prefix", "all"):
        stats.update(run_cold_prefix())
    if args.scenario in ("speculative", "all"):
        stats.update(run_speculative())
    if args.scenario in ("restart", "all"):
        stats.update(run_restart())
    for line in bench_lines_from(stats):
        print(line)
    if args.json:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serve.json")
        # merge over any existing record so a partial --scenario run never
        # erases the other scenarios' tracked trajectories
        record: Dict[str, object] = {}
        try:
            with open(os.path.abspath(path)) as f:
                record = json.load(f)
        except (OSError, ValueError):
            pass
        record["backend"] = jax.default_backend()
        if args.scenario in ("smoke", "all"):
            record.update({
                "config": SMOKE,
                "s_per_step_fused": stats["s_per_step_fused"],
                "s_per_step_loop": stats["s_per_step_loop"],
                "tokens_per_s_fused": stats["tokens_per_s_fused"],
                "tokens_per_s_loop": stats["tokens_per_s_loop"],
                "fused_speedup": stats["fused_speedup"],
                "continuous_tokens_per_s": stats["continuous_tokens_per_s"],
            })
        if int8_run:
            record["ragged_int8"] = dict(
                config=dict(RAGGED, **INT8),
                **{k: stats[k] for k in stats if k.startswith("int8_")})
        elif args.scenario in ("ragged", "all"):
            record["ragged"] = dict(
                config=RAGGED,
                **{k: stats[k] for k in stats if k.startswith("ragged_")})
        if args.scenario in ("shared-prefix", "all"):
            record["shared_prefix"] = dict(
                config=SHARED,
                **{k: stats[k] for k in stats
                   if k.startswith("shared_")})
        if args.scenario in ("long-decode", "all"):
            record["long_decode"] = dict(
                config=LONG_DECODE,
                **{k: stats[k] for k in stats
                   if k.startswith("long_decode_")})
            record["tick_overhead"] = {
                k: stats[k] for k in stats if k.startswith("tick_")}
        if args.scenario in ("long-prompt", "all"):
            record["long_prompt"] = dict(
                config=LONG_PROMPT,
                **{k: stats[k] for k in stats
                   if k.startswith("long_prompt_")})
        if args.scenario in ("overload", "all"):
            record["overload"] = dict(
                config=OVERLOAD,
                **{k: stats[k] for k in stats
                   if k.startswith("overload_")})
        if args.scenario in ("cold-prefix", "all"):
            record["cold_prefix"] = dict(
                config=COLD_PREFIX,
                **{k: stats[k] for k in stats
                   if k.startswith("cold_prefix_")})
        if args.scenario in ("speculative", "all"):
            record["speculative"] = dict(
                config=SPECULATIVE,
                **{k: stats[k] for k in stats
                   if k.startswith("speculative_")})
        if args.scenario in ("restart", "all"):
            record["restart"] = dict(
                config=RESTART,
                **{k: stats[k] for k in stats
                   if k.startswith("restart_")})
        with open(os.path.abspath(path), "w") as f:
            json.dump(record, f, indent=1)
        print(f"[serve_bench] wrote {os.path.abspath(path)}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
