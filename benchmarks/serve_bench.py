"""Serving micro-benchmark: batched decode throughput at smoke scale (the
decode_32k cells' runnable counterpart).

Reports the fused device-resident ``decode_many`` loop against the legacy
per-token host loop (both with donated caches), plus the continuous-batching
engine's end-to-end tokens/s.  ``--json`` writes BENCH_serve.json so the
perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

SMOKE = dict(arch="granite-8b", batch=4, seq=128, steps=8)


def _engine():
    from repro.configs import get
    from repro.models import get_model
    from repro.serve.engine import ServeConfig, ServingEngine
    cfg = get(SMOKE["arch"]).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params,
                        ServeConfig(max_batch=SMOKE["batch"],
                                    max_seq=SMOKE["seq"]))
    return cfg, model, params, eng


def run() -> Dict[str, float]:
    cfg, model, params, eng = _engine()
    stats = dict(eng.benchmark_decode(batch=SMOKE["batch"], seq=SMOKE["seq"],
                                      steps=SMOKE["steps"]))

    # continuous batching end-to-end: 2x batch requests over batch slots
    from repro.serve.engine import ContinuousBatchingEngine, ServeConfig
    cbe = ContinuousBatchingEngine(
        model, params, ServeConfig(max_batch=SMOKE["batch"], max_seq=256,
                                   max_new_tokens=8))
    rng = np.random.RandomState(0)
    for _ in range(2 * SMOKE["batch"]):
        cbe.submit(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32))
    cbe.step()                                   # compile
    t0 = time.perf_counter()
    results = cbe.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in results.values())
    stats["continuous_tokens_per_s"] = n_tok / max(dt, 1e-9)
    stats["continuous_joins"] = float(cbe.joins)
    return stats


def bench_lines_from(stats: Dict[str, float]) -> List[str]:
    name = f"serve/{SMOKE['arch']}-reduced-decode"
    return [
        f"{name},{stats['s_per_step_fused']*1e6:.0f},"
        f"tokens_per_s={stats['tokens_per_s_fused']:.1f}",
        f"{name}-legacy-loop,{stats['s_per_step_loop']*1e6:.0f},"
        f"tokens_per_s={stats['tokens_per_s_loop']:.1f}",
        f"{name}-fused-speedup,0,x{stats['fused_speedup']:.2f}",
        f"serve/continuous-batching,0,"
        f"tokens_per_s={stats['continuous_tokens_per_s']:.1f}",
    ]


def bench() -> List[str]:
    return bench_lines_from(run())


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_serve.json next to the repo root")
    args = ap.parse_args()
    stats = run()
    for line in bench_lines_from(stats):
        print(line)
    if args.json:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serve.json")
        record = {
            "config": SMOKE,
            "backend": jax.default_backend(),
            "s_per_step_fused": stats["s_per_step_fused"],
            "s_per_step_loop": stats["s_per_step_loop"],
            "tokens_per_s_fused": stats["tokens_per_s_fused"],
            "tokens_per_s_loop": stats["tokens_per_s_loop"],
            "fused_speedup": stats["fused_speedup"],
            "continuous_tokens_per_s": stats["continuous_tokens_per_s"],
        }
        with open(os.path.abspath(path), "w") as f:
            json.dump(record, f, indent=1)
        print(f"[serve_bench] wrote {os.path.abspath(path)}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
