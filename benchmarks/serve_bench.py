"""Serving micro-benchmark: batched decode throughput at smoke scale (the
decode_32k cells' runnable counterpart).

Two scenarios (``--scenario smoke|ragged|all``):

  * smoke — the fused device-resident ``decode_many`` loop against the
    legacy per-token host loop (both with donated caches), plus the
    lockstep continuous-batching engine's end-to-end tokens/s.
  * ragged — continuous batching under a RAGGED workload (mixed prompt and
    output lengths, mid-flight joins: 3x batch requests over batch slots):
    the non-lockstep paged engine (chunked prefill through the fused decode
    cell) against the lockstep dense engine at equal ``max_seq``, reporting
    tokens/s and page-pool utilization.

``--json`` writes BENCH_serve.json so the perf trajectory is tracked across
PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

SMOKE = dict(arch="granite-8b", batch=4, seq=128, steps=8)
RAGGED = dict(arch="granite-8b", batch=4, max_seq=192, requests=12,
              prompt_lo=4, prompt_hi=24, out_lo=4, out_hi=16,
              page_size=16, prefill_chunk=4)


def _engine():
    from repro.configs import get
    from repro.models import get_model
    from repro.serve.engine import ServeConfig, ServingEngine
    cfg = get(SMOKE["arch"]).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params,
                        ServeConfig(max_batch=SMOKE["batch"],
                                    max_seq=SMOKE["seq"]))
    return cfg, model, params, eng


def run() -> Dict[str, float]:
    cfg, model, params, eng = _engine()
    stats = dict(eng.benchmark_decode(batch=SMOKE["batch"], seq=SMOKE["seq"],
                                      steps=SMOKE["steps"]))

    # continuous batching end-to-end: 2x batch requests over batch slots
    from repro.serve.engine import ContinuousBatchingEngine, ServeConfig
    cbe = ContinuousBatchingEngine(
        model, params, ServeConfig(max_batch=SMOKE["batch"], max_seq=256,
                                   max_new_tokens=8))
    rng = np.random.RandomState(0)
    for _ in range(2 * SMOKE["batch"]):
        cbe.submit(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32))
    cbe.step()                                   # compile
    t0 = time.perf_counter()
    results = cbe.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in results.values())
    stats["continuous_tokens_per_s"] = n_tok / max(dt, 1e-9)
    stats["continuous_joins"] = float(cbe.joins)
    return stats


def _ragged_requests(cfg, rng) -> List:
    r = RAGGED
    return [(rng.randint(0, cfg.vocab_size,
                         size=rng.randint(r["prompt_lo"], r["prompt_hi"] + 1)
                         ).astype(np.int32),
             int(rng.randint(r["out_lo"], r["out_hi"] + 1)))
            for _ in range(r["requests"])]


def _drive(engine, reqs) -> Dict[str, float]:
    """Submit the ragged workload against a warm engine and time the drain.
    Tokens/joins are counted for THIS drive's requests only (engine.results
    and the join counter accumulate across drives — the warm-up run must
    not leak into the timed window)."""
    joins0 = engine.joins
    rids = [engine.submit(p, mnt) for p, mnt in reqs]
    t0 = time.perf_counter()
    results = engine.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(results[r]) for r in rids)
    return {"tokens": float(n_tok), "seconds": dt,
            "tokens_per_s": n_tok / max(dt, 1e-9),
            "joins": float(engine.joins - joins0)}


def run_ragged() -> Dict[str, float]:
    """Ragged continuous batching: paged (non-lockstep, chunked prefill)
    vs dense lockstep engine at equal max_seq."""
    from repro.configs import get
    from repro.models import get_model
    from repro.serve.engine import (
        ContinuousBatchingEngine, PagedEngine, ServeConfig)
    r = RAGGED
    cfg = get(r["arch"]).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.RandomState(0)
    reqs = _ragged_requests(cfg, rng)
    warm = [(rng.randint(0, cfg.vocab_size, size=6).astype(np.int32), 4)]

    dense = ContinuousBatchingEngine(
        model, params, ServeConfig(max_batch=r["batch"],
                                   max_seq=r["max_seq"]))
    _drive(dense, warm)                              # compile
    wraps0 = dense.wraps
    d = _drive(dense, reqs)

    paged = PagedEngine(
        model, params, ServeConfig(max_batch=r["batch"],
                                   max_seq=r["max_seq"],
                                   page_size=r["page_size"],
                                   prefill_chunk=r["prefill_chunk"]))
    _drive(paged, warm)                              # compile
    util0, ticks0 = paged.util_sum, paged.steps_run  # exclude warm-up ticks
    stalls0 = paged.stalls
    paged.util_max = 0.0
    p = _drive(paged, reqs)

    return {
        "ragged_tokens": p["tokens"],
        "ragged_tokens_per_s_paged": p["tokens_per_s"],
        "ragged_tokens_per_s_dense": d["tokens_per_s"],
        "ragged_paged_speedup": p["tokens_per_s"] / max(d["tokens_per_s"],
                                                        1e-9),
        "ragged_joins_paged": p["joins"],
        "ragged_page_util_mean": (paged.util_sum - util0)
        / max(1, paged.steps_run - ticks0),
        "ragged_page_util_max": paged.util_max,
        "ragged_dense_wraps": float(dense.wraps - wraps0),
        "ragged_paged_stalls": float(paged.stalls - stalls0),
    }


def bench_lines_from(stats: Dict[str, float]) -> List[str]:
    name = f"serve/{SMOKE['arch']}-reduced-decode"
    lines = []
    if "s_per_step_fused" in stats:
        lines += [
            f"{name},{stats['s_per_step_fused']*1e6:.0f},"
            f"tokens_per_s={stats['tokens_per_s_fused']:.1f}",
            f"{name}-legacy-loop,{stats['s_per_step_loop']*1e6:.0f},"
            f"tokens_per_s={stats['tokens_per_s_loop']:.1f}",
            f"{name}-fused-speedup,0,x{stats['fused_speedup']:.2f}",
            f"serve/continuous-batching,0,"
            f"tokens_per_s={stats['continuous_tokens_per_s']:.1f}",
        ]
    if "ragged_tokens_per_s_paged" in stats:
        lines += [
            f"serve/ragged-paged,0,"
            f"tokens_per_s={stats['ragged_tokens_per_s_paged']:.1f}",
            f"serve/ragged-dense,0,"
            f"tokens_per_s={stats['ragged_tokens_per_s_dense']:.1f}",
            f"serve/ragged-paged-speedup,0,"
            f"x{stats['ragged_paged_speedup']:.2f}",
            f"serve/ragged-page-util,0,"
            f"mean={stats['ragged_page_util_mean']:.2f}"
            f"/max={stats['ragged_page_util_max']:.2f}",
        ]
    return lines


def bench() -> List[str]:
    stats = run()
    stats.update(run_ragged())
    return bench_lines_from(stats)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_serve.json next to the repo root")
    ap.add_argument("--scenario", choices=("smoke", "ragged", "all"),
                    default="all",
                    help="smoke: fused-vs-loop decode; ragged: paged vs "
                         "dense continuous batching under mixed lengths")
    args = ap.parse_args()
    stats: Dict[str, float] = {}
    if args.scenario in ("smoke", "all"):
        stats.update(run())
    if args.scenario in ("ragged", "all"):
        stats.update(run_ragged())
    for line in bench_lines_from(stats):
        print(line)
    if args.json:
        path = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_serve.json")
        # merge over any existing record so a partial --scenario run never
        # erases the other scenario's tracked trajectory
        record: Dict[str, object] = {}
        try:
            with open(os.path.abspath(path)) as f:
                record = json.load(f)
        except (OSError, ValueError):
            pass
        record["backend"] = jax.default_backend()
        if args.scenario in ("smoke", "all"):
            record.update({
                "config": SMOKE,
                "s_per_step_fused": stats["s_per_step_fused"],
                "s_per_step_loop": stats["s_per_step_loop"],
                "tokens_per_s_fused": stats["tokens_per_s_fused"],
                "tokens_per_s_loop": stats["tokens_per_s_loop"],
                "fused_speedup": stats["fused_speedup"],
                "continuous_tokens_per_s": stats["continuous_tokens_per_s"],
            })
        if args.scenario in ("ragged", "all"):
            record["ragged"] = dict(
                config=RAGGED,
                **{k: stats[k] for k in stats if k.startswith("ragged_")})
        with open(os.path.abspath(path), "w") as f:
            json.dump(record, f, indent=1)
        print(f"[serve_bench] wrote {os.path.abspath(path)}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
