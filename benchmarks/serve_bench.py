"""Serving micro-benchmark: batched decode throughput at smoke scale (the
decode_32k cells' runnable counterpart)."""
from __future__ import annotations

import time
from typing import List

import jax

from repro.configs import get
from repro.models import get_model
from repro.serve.engine import ServeConfig, ServingEngine


def bench() -> List[str]:
    cfg = get("granite-8b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServingEngine(model, params, ServeConfig(max_batch=4, max_seq=128))
    stats = eng.benchmark_decode(batch=4, seq=128, steps=8)
    return [f"serve/granite-8b-reduced-decode,{stats['s_per_step']*1e6:.0f},"
            f"tokens_per_s={stats['tokens_per_s']:.1f}"]


if __name__ == "__main__":
    for line in bench():
        print(line)
