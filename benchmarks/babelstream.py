"""BabelStream (section 6.2): measures attainable memory bandwidth with the
five STREAM kernels.

Two modes, reported together:
  * host wall-clock MB/s of the jnp oracle on THIS machine (CPU in this
    container) — a true measured bandwidth, exactly what the paper does
    with HIP BabelStream on the MI60/MI100;
  * the Pallas-TPU kernels validated in interpret mode (correctness), with
    the v5e ceiling taken from the hardware spec for the IRM plots (the
    container cannot execute TPU code — DESIGN.md section 2).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.stream import ref

SHAPE = (4096, 2048)                 # 32 MiB fp32 per array
DTYPE = jnp.float32


def _timeit(fn, *args, iters: int = 10) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench() -> List[str]:
    nbytes = SHAPE[0] * SHAPE[1] * 4
    ks = jax.random.split(jax.random.key(0), 3)
    a = jax.random.normal(ks[0], SHAPE, DTYPE)
    b = jax.random.normal(ks[1], SHAPE, DTYPE)
    c = jax.random.normal(ks[2], SHAPE, DTYPE)

    cases = [
        ("copy", jax.jit(ref.copy), (a,), 2 * nbytes),
        ("mul", jax.jit(ref.mul), (c,), 2 * nbytes),
        ("add", jax.jit(ref.add), (a, b), 3 * nbytes),
        ("triad", jax.jit(ref.triad), (b, c), 3 * nbytes),
        ("dot", jax.jit(ref.dot), (a, b), 2 * nbytes),
    ]
    lines = []
    for name, fn, args, moved in cases:
        dt = _timeit(fn, *args)
        mbs = moved / dt / 1e6
        lines.append(f"babelstream/{name},{dt*1e6:.0f},host_MBps={mbs:.0f}")
    # Pallas kernel equivalence check (interpret mode) on a small shape
    from repro.kernels.stream import stream
    sa = a[:256, :512]
    sb = b[:256, :512]
    ok = bool(np.allclose(np.asarray(stream.add(sa, sb, interpret=True)),
                          np.asarray(ref.add(sa, sb)), rtol=1e-6))
    lines.append(f"babelstream/pallas_validate,0,allclose={ok}")
    return lines


if __name__ == "__main__":
    for line in bench():
        print(line)
