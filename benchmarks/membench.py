"""gpumembench analogue (section 6.2): on-chip / instruction-throughput
microbenchmarks.

Measures host wall-clock instruction throughput for VPU-class (elementwise)
and MXU-class (matmul) work at several working-set sizes, and reports the
modeled TPU v5e instruction ceilings from the issue model (Eq. 3 analogue) —
those are the horizontal roofs on the TPU IRM plots."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.core.hardware import TPU_V5E


def _timeit(fn, *args, iters: int = 20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench() -> List[str]:
    lines = []
    # VPU-class: fused multiply-add chains
    for n in (1 << 16, 1 << 20, 1 << 22):
        x = jnp.ones((n,), jnp.float32)

        @jax.jit
        def vpu(x):
            for _ in range(8):
                x = x * 1.000001 + 1e-6
            return x

        dt = _timeit(vpu, x)
        gops = 16 * n / dt / 1e9
        lines.append(f"membench/vpu_n{n},{dt*1e6:.0f},host_GFLOPs={gops:.2f}")
    # MXU-class: square matmuls
    for d in (256, 512, 1024):
        m = jnp.ones((d, d), jnp.float32)

        @jax.jit
        def mxu(m):
            return m @ m

        dt = _timeit(mxu, m)
        gf = 2 * d ** 3 / dt / 1e9
        lines.append(f"membench/mxu_d{d},{dt*1e6:.0f},host_GFLOPs={gf:.1f}")
    hw = TPU_V5E
    lines.append(
        f"membench/tpu_ceilings,0,"
        f"mxu_GIPS={hw.peak_mxu_issues_per_s()/1e9:.4f};"
        f"vpu_GIPS={hw.peak_vpu_issues_per_s()/1e9:.3f};"
        f"bf16_TFLOPs={hw.peak_flops_bf16/1e12:.0f}")
    return lines


if __name__ == "__main__":
    for line in bench():
        print(line)
