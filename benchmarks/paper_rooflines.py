"""Paper Figures 5-7 reproduction: instruction roofline plots (inst/byte) for
the V100 / MI60 / MI100 on the LWFA and TWEAC ComputeCurrent kernels,
written as PNGs under benchmarks/results/plots/."""
from __future__ import annotations

import os
import time
from typing import List

from repro.core import paper_data
from repro.core.hardware import MI100, MI60, V100
from repro.core.irm import gpu_irm
from repro.core.plotting import plot_irm

PLOT_DIR = os.path.join(os.path.dirname(__file__), "results", "plots")


def make_plots() -> List[str]:
    os.makedirs(PLOT_DIR, exist_ok=True)
    out = []
    cases = [
        ("fig5_v100_lwfa", V100, [paper_data.LWFA_V100]),
        ("fig6_amd_lwfa_mi60", MI60, [paper_data.LWFA_MI60]),
        ("fig6_amd_lwfa_mi100", MI100, [paper_data.LWFA_MI100]),
        ("fig7_amd_tweac_mi60", MI60, [paper_data.TWEAC_MI60]),
        ("fig7_amd_tweac_mi100", MI100, [paper_data.TWEAC_MI100]),
        ("v100_tweac", V100, [paper_data.TWEAC_V100]),
    ]
    for name, hw, ms in cases:
        model = gpu_irm(hw, ms, title=f"{name} — {hw.name}")
        path = os.path.join(PLOT_DIR, f"{name}.png")
        plot_irm(model, path)
        out.append(path)
    return out


def bench() -> List[str]:
    t0 = time.perf_counter()
    paths = make_plots()
    us = (time.perf_counter() - t0) * 1e6 / len(paths)
    return [f"paper/rooflines,{us:.0f},plots={len(paths)}"]


if __name__ == "__main__":
    for line in bench():
        print(line)
