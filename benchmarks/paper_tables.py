"""Paper Tables 1 & 2 reproduction (LWFA / TWEAC ComputeCurrent kernel).

Recomputes Peak GIPS (Eq. 3), Achieved GIPS (Eq. 4) and Instruction Intensity
(Eq. 2) from the paper's raw counter values and reports them next to the
published numbers.  This is the faithfulness gate: EXPERIMENTS.md quotes the
deltas."""
from __future__ import annotations

import time
from typing import List

from repro.core import paper_data


def rows() -> List[dict]:
    out = []
    for tname, table, published in (
            ("table1_lwfa", paper_data.TABLE1, paper_data.LWFA_PUBLISHED),
            ("table2_tweac", paper_data.TABLE2, paper_data.TWEAC_PUBLISHED)):
        for gpu, m in table.items():
            pub = published[gpu]
            out.append({
                "table": tname,
                "gpu": gpu,
                "peak_gips": m.peak_gips(),
                "peak_gips_published": pub["peak_gips"],
                "achieved_gips": m.achieved_gips(),
                "achieved_gips_published": pub["achieved_gips"],
                "intensity": m.intensity_performance(),
                "intensity_published": pub["intensity"],
                "bound": m.bound(),
            })
    return out


def bench() -> List[str]:
    """CSV lines: name,us_per_call,derived."""
    t0 = time.perf_counter()
    rs = rows()
    n = 200
    for _ in range(n):
        rs = rows()
    us = (time.perf_counter() - t0) / (n + 1) * 1e6
    lines = []
    for r in rs:
        err = abs(r["achieved_gips"] - r["achieved_gips_published"]) \
            / r["achieved_gips_published"]
        lines.append(
            f"paper/{r['table']}/{r['gpu']},{us:.1f},"
            f"achieved={r['achieved_gips']:.3f};published="
            f"{r['achieved_gips_published']:.3f};rel_err={err:.4f};"
            f"bound={r['bound']}")
    return lines


if __name__ == "__main__":
    for line in bench():
        print(line)
