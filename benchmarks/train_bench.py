"""End-to-end CPU training micro-run (loss must decrease) — the runnable
counterpart of the train_4k cells, at smoke scale."""
from __future__ import annotations

import time
from typing import List

from repro.configs import get
from repro.data.pipeline import DataConfig
from repro.models import get_model
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import linear_warmup
from repro.train.trainer import Trainer, TrainerConfig


def bench() -> List[str]:
    cfg = get("qwen2-0.5b").reduced()
    model = get_model(cfg)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8)
    tr = Trainer(model, AdamWConfig(lr=linear_warmup(3e-3, 10)),
                 data, TrainerConfig(steps=30, checkpoint_dir=None,
                                     log_every=1000))
    out = tr.run()
    us = out["wall_s"] / 30 * 1e6
    improved = out["last_loss"] < out["first_loss"]
    return [f"train/qwen2-0.5b-reduced,{us:.0f},"
            f"first={out['first_loss']:.3f};last={out['last_loss']:.3f};"
            f"improved={improved}"]


if __name__ == "__main__":
    for line in bench():
        print(line)
