"""Emits the EXPERIMENTS.md roofline tables (markdown) from the dry-run
JSON records.  Usage:
    PYTHONPATH=src python -m benchmarks.emit_roofline_md [results_dir]
"""
from __future__ import annotations

import glob
import json
import os
import sys


def emit(results_dir: str) -> str:
    lines = []
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))

    lines.append("| cell | mesh | comp ms | mem ms | coll ms | dominant | "
                 "modeled ms | useful | MFU | MXU pad | GiB/dev | fits |")
    lines.append("|---|---|---|---|---|---|---|---|---|---|---|---|")
    skips = []
    for r in recs:
        if "skipped" in r:
            skips.append(r["cell"])
            continue
        if "error" in r:
            lines.append(f"| {r['cell']} | — | ERROR: {r['error'][:60]} |")
            continue
        rl, irm, mem = r["roofline"], r["irm"], r["memory"]
        gib = mem["device_total_bytes"] / 2 ** 30
        arch, shape, mesh = r["arch"], r["shape"], r["mesh"]
        lines.append(
            f"| {arch}/{shape} | {mesh} "
            f"| {rl['compute_s']*1e3:.0f} | {rl['memory_s']*1e3:.0f} "
            f"| {rl['collective_s']*1e3:.0f} | {rl['dominant']} "
            f"| {rl['modeled_time_s']*1e3:.0f} "
            f"| {rl['useful_flops_ratio'] or 0:.2f} "
            f"| {rl['mfu_vs_peak']*100:.1f}% "
            f"| {irm['mxu_padding_efficiency']*100:.0f}% "
            f"| {gib:.1f} | {'Y' if gib <= 16 else 'OVER'} |")
    lines.append("")
    lines.append(f"Skipped cells ({len(skips)}): long_500k on pure "
                 "full-attention archs (DESIGN.md section 'Shape skips').")
    return "\n".join(lines)


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "results", "dryrun")
    print(emit(d))
