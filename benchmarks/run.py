# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV lines for: Tables 1-2 (paper_tables), Figs 5-7 (paper_rooflines),
# BabelStream + gpumembench (section 6.2), the roofline sweep over every
# (arch x shape x mesh) dry-run cell, and the runnable train/serve micro
# -benchmarks.
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (babelstream, census_bench, kernel_adjusted,
                            membench, paper_rooflines, paper_tables,
                            roofline_sweep, serve_bench, train_bench)
    modules = [
        ("paper_tables", paper_tables),
        ("paper_rooflines", paper_rooflines),
        ("babelstream", babelstream),
        ("membench", membench),
        ("roofline_sweep", roofline_sweep),
        ("kernel_adjusted", kernel_adjusted),
        ("census_bench", census_bench),
        ("train_bench", train_bench),
        ("serve_bench", serve_bench),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        try:
            for line in mod.bench():
                print(line)
        except Exception as e:                        # noqa: BLE001
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    sys.stdout.flush()
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
