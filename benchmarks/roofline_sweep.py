"""Aggregates the dry-run JSON records into the EXPERIMENTS.md roofline
table: per (arch x shape x mesh) the three roofline terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness, modeled MFU, memory fit."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")
V5E_HBM_GIB = 16.0


def load_records() -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table_rows(recs=None) -> List[Dict]:
    rows = []
    for r in recs if recs is not None else load_records():
        if "skipped" in r or "error" in r:
            rows.append({"cell": r.get("cell", "?"),
                         "status": r.get("skipped", r.get("error"))})
            continue
        rl = r["roofline"]
        mem = r["memory"]
        census = r["census"]
        fits = mem["device_total_bytes"] / 2 ** 30 <= V5E_HBM_GIB
        rows.append({
            "cell": r["cell"],
            "status": "ok",
            "devices": rl["devices"],
            "compute_ms": rl["compute_s"] * 1e3,
            "memory_ms": rl["memory_s"] * 1e3,
            "collective_ms": rl["collective_s"] * 1e3,
            "dominant": rl["dominant"],
            "modeled_ms": rl["modeled_time_s"] * 1e3,
            "useful_flops": rl.get("useful_flops_ratio"),
            "mfu": rl["mfu_vs_peak"],
            "dev_gib": mem["device_total_bytes"] / 2 ** 30,
            "fits_v5e": fits,
            "mxu_pad_eff": r["irm"]["mxu_padding_efficiency"],
            "collective_gb": census["collective_wire_bytes"] / 1e9,
        })
    return rows


def bench() -> List[str]:
    lines = []
    for row in table_rows():
        if row.get("status") != "ok":
            lines.append(f"roofline/{row['cell']},0,{row['status']}")
            continue
        lines.append(
            f"roofline/{row['cell']},{row['modeled_ms']*1e3:.0f},"
            f"dominant={row['dominant']};mfu={row['mfu']*100:.1f}%;"
            f"useful={row['useful_flops'] or 0:.2f};"
            f"dev_GiB={row['dev_gib']:.1f};fits={row['fits_v5e']}")
    if not lines:
        lines = ["roofline/none,0,no dryrun records — run repro.launch.dryrun"]
    return lines


if __name__ == "__main__":
    for line in bench():
        print(line)
