"""Kernel-adjusted roofline: substitute the Pallas kernels' analytic HBM
traffic for the XLA-jnp interior traffic of the hot regions.

Why: the container cannot LOWER TPU Pallas kernels (XLA:CPU), so the dry-run
censuses the pure-jnp model path — whose attention / selective-scan
interiors materialize every block tensor at fusion boundaries.  On a real
TPU those regions run as the validated Pallas kernels
(repro/kernels/flash_attention, repro/kernels/ssm_scan) whose HBM traffic
is exactly kernel inputs + outputs (state/softmax blocks stay in VMEM).

Method (per cell):
  1. lower + census the jnp region function ALONE at the cell's per-device
     local shapes (forward, and its VJP for train cells);
  2. region_total = census x (#applications: layers x microbatches, with
     the remat forward recompute counted);
  3. adjusted_hbm = cell_hbm - region_jnp + region_kernel_analytic;
     recompute the three terms and the bottleneck.

This mirrors the paper's own move: when the toolchain cannot measure a
quantity directly, substitute a validated model of it and say so
(BabelStream ceilings, section 6.2).
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get as get_arch
from repro.core.hardware import TPU_V5E
from repro.core.hlo_counters import census_from_compiled

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _census_fn(fn, *specs):
    compiled = jax.jit(fn).lower(*specs).compile()
    return census_from_compiled(compiled)


def flash_region(arch: str, shape_name: str, n_model: int = 16,
                 n_dp: int = 16, microbatches: int = 1) -> Dict[str, float]:
    """jnp-flash vs Pallas-kernel traffic for one cell's attention stack."""
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    B_loc = max(1, shape.global_batch // n_dp // microbatches)
    S = shape.seq_len
    H_loc = max(1, math.ceil(cfg.n_heads / n_model))
    D = cfg.head_dim
    from repro.models.flash import flash_attention_ref
    sds = jax.ShapeDtypeStruct((B_loc, S, H_loc, D), jnp.bfloat16)

    def fwd(q, k, v):
        return flash_attention_ref(q, k, v, True, cfg.attn_chunk_q,
                                   cfg.attn_chunk_kv)

    def bwd(q, k, v):
        out, vjp = jax.vjp(fwd, q, k, v)
        return vjp(out)

    c_fwd = _census_fn(fwd, sds, sds, sds)
    c_bwd = _census_fn(bwd, sds, sds, sds)

    qkv_bytes = B_loc * S * H_loc * D * 2.0
    kern_fwd = 4 * qkv_bytes + B_loc * S * H_loc * 4           # q,k,v,o + L
    kern_bwd = 10 * qkv_bytes + 2 * B_loc * S * H_loc * 4      # 2-pass
    apps = cfg.n_layers * microbatches
    if cfg.family == "hybrid":
        apps = (cfg.n_layers // max(1, cfg.attn_every)) * microbatches
    train = shape.kind == "train"
    jnp_bytes = apps * (c_fwd.hbm_bytes * (2 if train else 1)
                        + (c_bwd.hbm_bytes if train else 0))
    kern_bytes = apps * (kern_fwd * (2 if train else 1)
                         + (kern_bwd if train else 0))
    return {"jnp_bytes": jnp_bytes, "kernel_bytes": kern_bytes,
            "applications": apps}


def ssm_region(arch: str, shape_name: str, n_model: int = 16,
               n_dp: int = 16, microbatches: int = 1) -> Dict[str, float]:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    B_loc = max(1, shape.global_batch // n_dp // microbatches)
    S = shape.seq_len
    d_loc = max(128, cfg.d_model * cfg.ssm_expand // n_model)
    N = cfg.ssm_state
    from repro.models.ssm import mamba1_scan
    from repro.kernels.ssm_scan.scan import analytic_hbm_bytes

    x = jax.ShapeDtypeStruct((B_loc, S, d_loc), jnp.float32)
    dt = jax.ShapeDtypeStruct((B_loc, S, d_loc), jnp.float32)
    A = jax.ShapeDtypeStruct((d_loc, N), jnp.float32)
    bc = jax.ShapeDtypeStruct((B_loc, S, N), jnp.float32)

    def fwd(x, dt, A, Bc, Cc):
        return mamba1_scan(x, dt, A, Bc, Cc, cfg.ssm_chunk)[0]

    def bwd(x, dt, A, Bc, Cc):
        out, vjp = jax.vjp(fwd, x, dt, A, Bc, Cc)
        return vjp(out)

    c_fwd = _census_fn(fwd, x, dt, A, bc, bc)
    c_bwd = _census_fn(bwd, x, dt, A, bc, bc)
    kern_fwd = analytic_hbm_bytes(B_loc, S, d_loc, N)
    kern_bwd = 3 * kern_fwd                    # recompute + grads streamed
    apps = cfg.n_layers * microbatches
    train = shape.kind == "train"
    jnp_bytes = apps * (c_fwd.hbm_bytes * (2 if train else 1)
                        + (c_bwd.hbm_bytes if train else 0))
    kern_bytes = apps * (kern_fwd * (2 if train else 1)
                         + (kern_bwd if train else 0))
    return {"jnp_bytes": jnp_bytes, "kernel_bytes": kern_bytes,
            "applications": apps}


def adjust_cell(arch: str, shape_name: str,
                mesh_name: str = "pod16x16") -> Optional[Dict]:
    path = os.path.join(RESULTS, f"{arch}__{shape_name}__{mesh_name}.json")
    if not os.path.exists(path):
        return None
    rec = json.load(open(path))
    if "roofline" not in rec:
        return None
    mb = rec.get("build_info", {}).get("microbatches", 1) or 1
    cfg = get_arch(arch)
    regions = []
    if cfg.mamba_version == 1:
        regions.append(ssm_region(arch, shape_name, microbatches=mb))
    if not cfg.is_attention_free:
        regions.append(flash_region(arch, shape_name, microbatches=mb))
    hbm = rec["census"]["hbm_bytes"]
    adj = hbm
    for r in regions:
        adj = adj - min(r["jnp_bytes"], adj) + r["kernel_bytes"]
    hw = TPU_V5E
    mem_s = adj / (hw.memory_ceiling_gbs() * 1e9)
    comp_s = rec["roofline"]["compute_s"]
    coll_s = rec["roofline"]["collective_s"]
    modeled = max(mem_s, comp_s, coll_s)
    mf = rec["roofline"].get("useful_flops_ratio")
    model_flops_dev = (mf or 0) * rec["roofline"]["flops_per_dev"]
    return {
        "cell": rec["cell"],
        "hbm_before": hbm, "hbm_after": adj,
        "modeled_before_s": rec["roofline"]["modeled_time_s"],
        "modeled_after_s": modeled,
        "dominant_after": max((("memory", mem_s), ("compute", comp_s),
                               ("collective", coll_s)),
                              key=lambda kv: kv[1])[0],
        "mfu_after": (model_flops_dev / (modeled * hw.peak_flops_bf16)
                      if modeled else 0.0),
    }


CELLS = [
    ("llama4-scout-17b-a16e", "train_4k", "pod16x16"),
    ("falcon-mamba-7b", "train_4k", "pod16x16"),
    ("granite-8b", "prefill_32k", "pod16x16"),
]


def bench():
    lines = []
    for arch, shape, mesh in CELLS:
        try:
            r = adjust_cell(arch, shape, mesh)
        except Exception as e:                       # noqa: BLE001
            lines.append(f"kernel_adjusted/{arch}/{shape},0,"
                         f"{type(e).__name__}:{e}")
            continue
        if r is None:
            continue
        lines.append(
            f"kernel_adjusted/{arch}/{shape},{r['modeled_after_s']*1e6:.0f},"
            f"before_ms={r['modeled_before_s']*1e3:.0f};"
            f"after_ms={r['modeled_after_s']*1e3:.0f};"
            f"dominant={r['dominant_after']};"
            f"mfu_after={r['mfu_after']*100:.1f}%")
    return lines


if __name__ == "__main__":
    for line in bench():
        print(line)
