"""HLO-census micro-benchmark: time ``census_from_text`` on a large
post-optimization module (a reduced-model fused decode program — hundreds of
fusions, scan bodies, dynamic-slice cache traffic).

The census is on the dry-run critical path (every (arch x shape x mesh) cell
parses its HLO text), so its throughput is tracked here like any kernel."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp


def _large_hlo_text() -> str:
    from repro.configs import get
    from repro.models import get_model
    cfg = get("granite-8b").reduced()
    model = get_model(cfg)
    B, T, steps = 4, 64, 8

    def fused(params, tok, cache, key):
        return model.decode_many(params, tok, cache, key, num_steps=steps)

    key = jax.random.key(0)
    lowered = jax.jit(fused).lower(
        model.abstract_params(),
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
        model.abstract_cache(B, T),
        jax.ShapeDtypeStruct(key.shape, key.dtype))
    return lowered.compile().as_text()


def stats() -> dict:
    from repro.core.hlo_counters import census_from_text
    text = _large_hlo_text()
    census_from_text(text)                       # warm (regex caches)
    reps = 5
    t0 = time.perf_counter()
    for _ in range(reps):
        census = census_from_text(text)
    dt = (time.perf_counter() - t0) / reps
    n_lines = text.count("\n")
    return {"s_per_census": dt, "lines": float(n_lines),
            "instructions": float(census.total_instructions),
            "lines_per_s": n_lines / dt}


def _line(s: dict) -> str:
    return (f"hlo_census/decode_many-{s['lines']:.0f}l,"
            f"{s['s_per_census']*1e6:.0f},"
            f"insts={s['instructions']:.0f},"
            f"lines_per_s={s['lines_per_s']:.0f}")


def bench() -> List[str]:
    return [_line(stats())]


def main() -> int:
    import argparse
    import json
    import os
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="merge a 'census' section into BENCH_serve.json "
                         "so scripts/verify.sh gates census throughput "
                         "alongside the serving floors")
    args = ap.parse_args()
    s = stats()
    print(_line(s))
    if args.json:
        path = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                            "BENCH_serve.json"))
        record = {}
        try:
            with open(path) as f:
                record = json.load(f)
        except (OSError, ValueError):
            pass
        record["census"] = s
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"[census_bench] wrote {path}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
