"""HLO-census micro-benchmark: time ``census_from_text`` on a large
post-optimization module (a reduced-model fused decode program — hundreds of
fusions, scan bodies, dynamic-slice cache traffic).

The census is on the dry-run critical path (every (arch x shape x mesh) cell
parses its HLO text), so its throughput is tracked here like any kernel."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp


def _large_hlo_text() -> str:
    from repro.configs import get
    from repro.models import get_model
    cfg = get("granite-8b").reduced()
    model = get_model(cfg)
    B, T, steps = 4, 64, 8

    def fused(params, tok, cache, key):
        return model.decode_many(params, tok, cache, key, num_steps=steps)

    key = jax.random.key(0)
    lowered = jax.jit(fused).lower(
        model.abstract_params(),
        jax.ShapeDtypeStruct((B, 1), jnp.int32),
        model.abstract_cache(B, T),
        jax.ShapeDtypeStruct(key.shape, key.dtype))
    return lowered.compile().as_text()


def bench() -> List[str]:
    from repro.core.hlo_counters import census_from_text
    text = _large_hlo_text()
    census_from_text(text)                       # warm (regex caches)
    reps = 5
    t0 = time.perf_counter()
    for _ in range(reps):
        census = census_from_text(text)
    dt = (time.perf_counter() - t0) / reps
    n_lines = text.count("\n")
    return [f"hlo_census/decode_many-{n_lines}l,{dt*1e6:.0f},"
            f"insts={census.total_instructions:.0f},"
            f"lines_per_s={n_lines/dt:.0f}"]


if __name__ == "__main__":
    for line in bench():
        print(line)
