"""Logical-axis sharding: one place maps logical names to mesh axes.

Parameters, caches and activations declare LOGICAL axes ("batch", "model",
"fsdp", "cache_seq", "ep", "moe_fsdp"); ``MeshRules`` resolves them to the
physical mesh axes of the active mesh.  The same model code then runs
unsharded on one CPU device (no mesh -> every constraint is a no-op) and
SPMD-partitioned on a production mesh (dryrun.py picks rules per cell).

``constrain`` is the only sharding primitive model code uses: it applies
``with_sharding_constraint`` with the resolved spec, silently replicating
any dimension a mesh axis does not divide (reduced smoke shapes).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[str, ...]


def _entry(axes: Axes):
    """PartitionSpec entry for a (possibly empty / multi) axis tuple."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Logical-axis -> mesh-axis mapping for one (shape x mesh) cell."""

    batch_axes: Axes = ()            # data-parallel axes for batch dims
    fsdp_axes: Axes = ()             # weight-shard axes (ZeRO-3 style)
    cache_seq_axes: Axes = ()        # KV-cache sequence sharding (decode)
    ep_axes: Axes = ("model",)       # expert-parallel axes (MoE blocks)
    model_axis: str = "model"        # tensor-parallel axis
    use_fsdp: bool = True

    def resolve(self, name: Optional[str]):
        if name is None:
            return None
        if name == "batch":
            return _entry(self.batch_axes)
        if name == "model":
            return self.model_axis
        if name == "fsdp":
            return _entry(self.fsdp_axes) if self.use_fsdp else None
        if name == "cache_seq":
            return _entry(self.cache_seq_axes)
        if name == "ep":
            return _entry(self.ep_axes)
        if name == "moe_fsdp":
            # fsdp axes not already consumed by expert parallelism
            if not self.use_fsdp:
                return None
            return _entry(tuple(a for a in self.fsdp_axes
                                if a not in self.ep_axes))
        raise ValueError(f"unknown logical axis {name!r}")


# --- active context -----------------------------------------------------------
# Thread-local so parallel compiles (e.g. pytest-xdist style runners) cannot
# race each other's mesh.

class _Context(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[MeshRules] = None


_CTX = _Context()


def set_context(mesh: Optional[Mesh], rules: Optional[MeshRules]) -> None:
    """Install mesh + rules for the rest of the process (launchers)."""
    _CTX.mesh = mesh
    _CTX.rules = rules


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def active_rules() -> Optional[MeshRules]:
    return _CTX.rules


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: MeshRules):
    """Scoped mesh + rules (dryrun cells, multi-device tests)."""
    prev = (_CTX.mesh, _CTX.rules)
    set_context(mesh, rules)
    try:
        with mesh:
            yield mesh
    finally:
        set_context(*prev)


# --- the one sharding primitive model code uses --------------------------------

def _validated(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Replicate dims a mesh axis does not divide (reduced smoke shapes) —
    with_sharding_constraint requires exact divisibility."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape.get(a, 1)
        out.append(entry if size and shape[i] % size == 0 else None)
    return P(*out)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names; no-op without an
    active mesh.  Axis names missing from the mesh or not dividing the dim
    fall back to replicated."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None or rules is None:
        return x
    entries = []
    for name in logical_axes:
        e = rules.resolve(name)
        if isinstance(e, tuple):
            e = _entry(tuple(a for a in e if a in mesh.shape))
        elif e is not None and e not in mesh.shape:
            e = None
        entries.append(e)
    spec = _validated(P(*entries), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
