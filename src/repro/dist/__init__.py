from repro.dist.sharding import (  # noqa: F401
    MeshRules, active_rules, constrain, current_mesh, set_context, use_mesh)
