"""qwen2-vl-72b — VLM backbone with M-RoPE.  [arXiv:2409.12191; hf]
80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
Backbone only: the vision frontend is a STUB — ``input_specs()`` provides
precomputed patch/token embeddings plus (3, batch, seq) M-RoPE position ids
(temporal / height / width sections over the rotary half-dim).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    embed_inputs=False,
    mrope_sections=(16, 24, 24),     # sums to head_dim/2 = 64
    qkv_bias=True,
))
