"""Imports every assigned architecture config (side effect: registration)."""
from repro.configs import (  # noqa: F401
    falcon_mamba_7b, granite_20b, granite_8b, grok_1_314b,
    llama4_scout_17b_a16e, phi4_mini_3_8b, qwen2_0_5b, qwen2_vl_72b,
    whisper_large_v3, zamba2_7b)

ALL_ARCHS = [
    "llama4-scout-17b-a16e",
    "grok-1-314b",
    "zamba2-7b",
    "granite-8b",
    "granite-20b",
    "qwen2-0.5b",
    "phi4-mini-3.8b",
    "whisper-large-v3",
    "qwen2-vl-72b",
    "falcon-mamba-7b",
]
