"""whisper-large-v3 — encoder-decoder audio backbone.  [arXiv:2212.04356]
32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
The conv frontend is a STUB per assignment: ``input_specs()`` provides
precomputed frame embeddings (batch, frames, d_model) to the encoder.
LayerNorm + GELU (original Whisper recipe), bidirectional encoder,
causal decoder with cross-attention.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,                     # decoder layers
    encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    is_encoder_decoder=True,
    embed_inputs=False,              # frontend stub feeds embeddings
    ffn_kind="gelu",
    norm_kind="layernorm",
    rope_theta=0.0,                  # learned absolute positions
    tie_embeddings=True,
))
