from repro.configs.base import (  # noqa: F401
    SHAPES, ArchConfig, ShapeConfig, get, register, registry,
    shape_applicable)
