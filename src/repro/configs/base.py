"""ArchConfig / ShapeConfig — the configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every benchmark shape
is a ``ShapeConfig``.  ``registry()`` maps ``--arch`` ids to configs, and each
config knows how to produce a REDUCED variant for CPU smoke tests (same
family and wiring, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    # --- attention flavor --------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) halves
    causal: bool = True
    # --- ffn ---------------------------------------------------------------
    ffn_kind: str = "swiglu"         # swiglu | gelu
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    ep_shards: int = 16              # expert weight blocks (G); == prod TP
    # --- SSM (mamba) --------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64           # mamba2 only
    mamba_version: int = 0           # 0 none | 1 | 2
    attn_every: int = 0              # hybrid: shared attn block every k layers
    # --- encoder-decoder -----------------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    # --- frontend -------------------------------------------------------------
    embed_inputs: bool = True        # False: input_specs provides embeddings
    # --- norms / numerics -----------------------------------------------------
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # --- implementation switches (hillclimb levers) -----------------------------
    attention_impl: str = "reference"     # reference | pallas
    pages_per_step: int = 1          # paged decode kernel: pages swept per
                                     # grid step (page-list blocking; cuts
                                     # grid steps by P for long slots)
    prefill_chunk_tokens: int = 0    # ragged paged-prefill lane: prompt
                                     # tokens per chunked-prefill kernel
                                     # step (0 = auto: 2x the serving page
                                     # size; keep it a MULTIPLE of the page
                                     # size so chunk grants stay page-
                                     # aligned)
    kv_dtype: str = "bf16"           # paged KV page pools: "bf16" (pools in
                                     # the model compute dtype) | "int8"
                                     # (quantized pools + per-row-per-head
                                     # f32 scales, dequantized inside the
                                     # page sweep)
    draft_arch: str = ""             # speculative decoding: registry id of
                                     # the DRAFT model ("" = none); the
                                     # draft must share this arch's
                                     # tokenizer (equal vocab_size) — its
                                     # paged KV pool rides next to the
                                     # target's
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    ssm_chunk: int = 256
    remat: str = "block"             # none | block | full
    remat_group: int = 1             # checkpoint every g layers: the saved
                                     # residual stack shrinks g x, each layer
                                     # still recomputed exactly once
    scan_layers: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def kv_quantized(self) -> bool:
        if self.kv_dtype not in ("bf16", "int8"):
            raise ValueError(f"kv_dtype must be 'bf16' or 'int8', "
                             f"got {self.kv_dtype!r}")
        return self.kv_dtype == "int8"

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> float:
        """Approximate parameter count (embedding + blocks), for 6ND."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.ffn_kind == "swiglu":
            ffn = 3 * d * ff
        else:
            ffn = 2 * d * ff
        if self.n_experts:
            ffn = ffn * self.n_experts + d * self.n_experts
        block = attn + ffn + 2 * d
        if self.mamba_version:
            d_in = d * self.ssm_expand
            if self.mamba_version == 1:
                dt_rank = max(1, d // 16)
                ssm_blk = (d * 2 * d_in + d_in * self.ssm_conv
                           + d_in * (dt_rank + 2 * self.ssm_state)
                           + dt_rank * d_in + d_in * self.ssm_state
                           + d_in + d_in * d)
            else:
                n_heads = d_in // self.ssm_head_dim
                ssm_blk = (d * (2 * d_in + 2 * self.ssm_state * 1 + n_heads)
                           + d_in * self.ssm_conv + d_in * d + n_heads)
            if self.family == "hybrid" and self.attn_every:
                n_attn = self.n_layers // self.attn_every
                block = ssm_blk + 2 * d
                total_blocks = self.n_layers * block + (attn + 2 * d)  # shared
                return float(total_blocks + v * d * (1 if self.tie_embeddings else 2))
            block = ssm_blk + 2 * d
        total = self.n_layers * block
        if self.is_encoder_decoder:
            # encoder blocks + decoder cross-attn
            enc_block = attn + ffn + 2 * d
            total += self.encoder_layers * enc_block + self.n_layers * (attn + d)
        total += v * d * (1 if self.tie_embeddings else 2)
        return float(total)

    def active_params(self) -> float:
        """Params touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        expert = 3 * d * ff if self.ffn_kind == "swiglu" else 2 * d * ff
        inactive = (self.n_experts - self.experts_per_token) * expert
        return self.n_params() - self.n_layers * inactive

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        scale = dict(
            n_layers=min(self.n_layers, 2 if not self.attn_every
                         else self.attn_every),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 1,
            d_ff=128,
            vocab_size=256,
            d_head=16,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            ep_shards=min(self.n_experts, 4) if self.n_experts else 16,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_head_dim=16,
            encoder_layers=min(self.encoder_layers, 2),
            mrope_sections=(4, 2, 2) if self.mrope_sections else (),
            attn_chunk_q=32,
            attn_chunk_kv=32,
            ssm_chunk=16,
            name=self.name + "-reduced",
        )
        return dataclasses.replace(self, **scale)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    """None if the (arch, shape) cell runs; else a skip reason (DESIGN.md
    section 'Shape skips')."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return "long_500k requires sub-quadratic attention (skip: pure full-attention arch)"
    return None


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def registry() -> Dict[str, ArchConfig]:
    if not _REGISTRY:
        import repro.configs.all_archs  # noqa: F401  (populates)
    return _REGISTRY


def get(name: str) -> ArchConfig:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; have {sorted(reg)}")
    return reg[name]
