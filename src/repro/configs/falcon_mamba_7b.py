"""falcon-mamba-7b — pure Mamba1, attention-free.  [arXiv:2410.05355]
64L d_model=4096 (attn-free) vocab=65024, ssm_state=16.
No KV cache: decode carries a constant-size (conv, ssm) state per layer —
which is why this arch runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,                       # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_expand=2,
    mamba_version=1,
    tie_embeddings=True,
))
