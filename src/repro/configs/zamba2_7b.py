"""zamba2-7b — hybrid Mamba2 trunk + shared attention block.
[arXiv:2411.15242; unverified]
81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
The shared attention block (single weight set) is applied every
``attn_every`` mamba layers — Zamba's parameter-sharing trick.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    mamba_version=2,
    attn_every=6,
    tie_embeddings=True,
))
