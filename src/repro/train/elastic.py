"""Elastic scaling: reshard a checkpointed train state onto a different
mesh / world size.

Checkpoints store mesh-agnostic full arrays (see checkpoint.py), so elastic
resize = restore + re-placement under the new mesh rules.  What this module
adds on top:

  * ``replan_batch``: keep the GLOBAL batch constant across world sizes by
    recomputing per-host batch + gradient-accumulation factor (so loss
    scale/optimizer hyperparameters are unchanged when nodes join/leave);
  * ``reshard``: place a restored state onto a new mesh via the schema's
    partition specs (dropping axes that no longer divide — e.g. shrinking
    16-way TP to 8-way);
  * failure-recovery flow used by the trainer: on a detected node loss,
    rebuild the mesh from surviving hosts, replan, restore from the newest
    commit, continue (exercised in tests with host-device submeshes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import MeshRules


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    global_batch: int
    n_data_shards: int
    per_shard_batch: int
    grad_accum: int

    @property
    def per_step_batch(self) -> int:
        return self.per_shard_batch * self.n_data_shards * self.grad_accum


def replan_batch(global_batch: int, n_data_shards: int,
                 max_per_shard: int = 64) -> BatchPlan:
    """Keep global batch fixed while the data-parallel world resizes."""
    assert global_batch % n_data_shards == 0, (global_batch, n_data_shards)
    per = global_batch // n_data_shards
    accum = 1
    while per > max_per_shard:
        assert per % 2 == 0, per
        per //= 2
        accum *= 2
    return BatchPlan(global_batch, n_data_shards, per, accum)


def _validated(spec: P, shape, mesh) -> P:
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if shape[i] % size == 0 else None)
    return P(*out)


def reshard(state, pspecs, mesh) -> Any:
    """Place a (host-resident) state pytree onto ``mesh`` per ``pspecs``,
    replicating any dim the new mesh no longer divides."""
    def place(x, spec):
        sh = NamedSharding(mesh, _validated(spec, x.shape, mesh))
        return jax.device_put(x, sh)
    return jax.tree.map(place, state, pspecs)
