"""Train step: loss -> grads -> AdamW update, with optional gradient
accumulation (microbatching) and int8 error-feedback gradient compression of
the data-parallel all-reduce.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim.adamw import AdamW


def make_train_step(model: Model, optimizer: AdamW,
                    microbatches: int = 1,
                    accum_dtype=jnp.float32) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` splits the batch on the leading axis and accumulates
    grads in a scan (activation memory / compile-size lever).
    ``accum_dtype`` is the gradient-accumulator dtype — bf16 halves the
    accumulator footprint for >200B-param models (stochastic error is
    bounded by 1/sqrt(microbatches) of the bf16 ulp)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            B = batch["labels"].shape[0]
            assert B % microbatches == 0, (B, microbatches)

            def split(x):
                # split along the batch axis — axis 0 for ordinary leaves,
                # axis 1 for M-RoPE positions shaped (3, B, S)
                ax = 0 if x.shape[0] == B else 1
                assert x.shape[ax] == B, (x.shape, B)
                per = B // microbatches
                shape = (x.shape[:ax] + (microbatches, per)
                         + x.shape[ax + 1:])
                return jnp.moveaxis(x.reshape(shape), ax, 0)
            mb = jax.tree.map(split, batch)

            def body(acc, mbatch):
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree.map(lambda a, b: a + b.astype(accum_dtype),
                                     acc_g, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        new_params, new_opt, metrics = optimizer.update(grads, opt_state,
                                                        params)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    return step
