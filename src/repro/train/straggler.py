"""Straggler detection: per-step wall-time EWMA with outlier flagging.

At real multi-pod scale the trainer feeds per-host step times in; here the
monitor is exercised by unit tests and the trainer loop.  Design for >1k
nodes (documented in DESIGN.md section 7): hosts whose EWMA exceeds
``threshold`` x the fleet median for ``patience`` consecutive windows get
their data shard re-assigned to a hot spare (see
``data.pipeline.SyntheticTokenPipeline.reassign``) and are queued for
drain/replacement; training never blocks on a single host.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class StragglerConfig:
    alpha: float = 0.2               # EWMA smoothing
    threshold: float = 1.5           # x fleet median
    patience: int = 3


class StragglerMonitor:
    def __init__(self, n_hosts: int, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.ewma: Dict[int, Optional[float]] = {h: None
                                                 for h in range(n_hosts)}
        self.strikes: Dict[int, int] = {h: 0 for h in range(n_hosts)}

    def update(self, times: Dict[int, float]) -> List[int]:
        """Feed one step's per-host wall times; returns hosts flagged as
        stragglers this step."""
        a = self.cfg.alpha
        for h, t in times.items():
            prev = self.ewma[h]
            self.ewma[h] = t if prev is None else (1 - a) * prev + a * t
        vals = sorted(v for v in self.ewma.values() if v is not None)
        if not vals:
            return []
        median = vals[len(vals) // 2]
        flagged = []
        for h, v in self.ewma.items():
            if v is not None and v > self.cfg.threshold * median:
                self.strikes[h] += 1
                if self.strikes[h] >= self.cfg.patience:
                    flagged.append(h)
            else:
                self.strikes[h] = 0
        return flagged

    def reset(self, host: int) -> None:
        self.ewma[host] = None
        self.strikes[host] = 0
