"""Training loop with checkpoint/restart, straggler monitoring, and metrics.

This is the CPU-runnable end-to-end driver (examples/train_100m.py uses it);
the same loop structure is what launch/train.py runs per host at scale.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.models.model import Model
from repro.optim.adamw import AdamW, AdamWConfig
from repro.train import checkpoint as ckpt
from repro.train.straggler import StragglerMonitor
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    log_every: int = 10
    microbatches: int = 1
    seed: int = 0
    async_checkpoint: bool = True


class Trainer:
    def __init__(self, model: Model, opt_cfg: AdamWConfig,
                 data_cfg: DataConfig, cfg: TrainerConfig):
        self.model = model
        self.optimizer = AdamW(opt_cfg)
        self.pipeline = SyntheticTokenPipeline(data_cfg)
        self.cfg = cfg
        self.step_fn = jax.jit(make_train_step(
            model, self.optimizer, microbatches=cfg.microbatches),
            donate_argnums=(0, 1))
        self.monitor = StragglerMonitor(data_cfg.num_hosts)
        self.checkpointer = ckpt.AsyncCheckpointer()
        self.history: List[Dict[str, float]] = []

    # -- state ----------------------------------------------------------------
    def init_state(self):
        params = self.model.init(jax.random.key(self.cfg.seed))
        opt_state = self.optimizer.init(params)
        return {"params": params, "opt": opt_state}

    def _maybe_restore(self, state):
        d = self.cfg.checkpoint_dir
        if not d:
            return 0, state
        got = ckpt.restore(d, state)
        if got is None:
            return 0, state
        step, state, extra = got
        print(f"[trainer] restored checkpoint at step {step}")
        return int(extra.get("data_step", step)), state

    # -- loop ----------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        state = self.init_state()
        start_step, state = self._maybe_restore(state)
        params, opt_state = state["params"], state["opt"]
        losses = []
        t_start = time.time()
        for step in range(start_step, self.cfg.steps):
            t0 = time.time()
            batch_np = self.pipeline.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if "embeds" in batch:
                batch["embeds"] = batch["embeds"].astype(
                    self.model.cfg.param_dtype)
            if "frames" in batch:
                batch["frames"] = batch["frames"].astype(
                    self.model.cfg.param_dtype)
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            self.monitor.update({self.pipeline.cfg.host_index: dt})
            self.history.append({"step": step, "loss": loss, "time_s": dt,
                                 "grad_norm": float(metrics["grad_norm"])})
            if step % self.cfg.log_every == 0:
                print(f"[trainer] step {step} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f} ms")
            if (self.cfg.checkpoint_dir
                    and (step + 1) % self.cfg.checkpoint_every == 0):
                st = {"params": params, "opt": opt_state}
                if self.cfg.async_checkpoint:
                    self.checkpointer.save(self.cfg.checkpoint_dir, step + 1,
                                           st, {"data_step": step + 1})
                else:
                    ckpt.save(self.cfg.checkpoint_dir, step + 1, st,
                              {"data_step": step + 1})
        self.checkpointer.wait()
        return {
            "losses": losses,
            "first_loss": losses[0] if losses else float("nan"),
            "last_loss": losses[-1] if losses else float("nan"),
            "wall_s": time.time() - t_start,
            "params": params,
            "opt": opt_state,
        }
