"""Checkpoint/restart: step-addressed, atomic, corruption-tolerant.

Format: one directory per step —
    <dir>/step_000123/
        manifest.json     # tree structure + shapes/dtypes + data step + rng
        arrays.npz        # flattened leaves (np.savez, keyed by index)
        COMMIT            # written LAST; a checkpoint without it is partial

Restore scans for the newest COMMITted step and validates shapes; partial or
corrupted checkpoints are skipped (tested).  Save can run in a background
thread (async checkpointing) so the train loop is not blocked.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize ml_dtypes (bfloat16, fp8...); store them as uint
# views and restore via the manifest's logical dtype
_UINT_VIEW = {1: np.uint8, 2: np.uint16, 4: np.uint32}


def _to_storable(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    dt = str(arr.dtype)
    try:
        np.dtype(dt)
        is_native = arr.dtype.kind != "V" and not dt.startswith(
            ("bfloat16", "float8", "float4", "int4", "uint4"))
    except TypeError:
        is_native = False
    if is_native:
        return arr, dt
    return arr.view(_UINT_VIEW[arr.dtype.itemsize]), dt


def _from_storable(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(arr.dtype) == dtype_str:
        return arr
    return arr.view(np.dtype(getattr(ml_dtypes, dtype_str)))


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, state: Dict[str, Any],
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Blocking save.  ``state`` is any pytree of arrays."""
    path = os.path.join(directory, f"step_{step:09d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(state)
    arrays = {}
    meta = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        stored, logical_dtype = _to_storable(arr)
        arrays[f"a{i}"] = stored
        meta.append({"shape": list(arr.shape), "dtype": logical_dtype})
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": meta,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    return path


class AsyncCheckpointer:
    """Fire-and-forget save on a background thread; at most one in flight
    (a second save waits — checkpointing never corrupts by overlap)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None

    def save(self, directory: str, step: int, state, extra=None):
        state_host = jax.tree.map(np.asarray, state)   # snapshot now

        def work():
            with self._lock:
                save(directory, step, state_host, extra)

        self.wait()
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _is_valid(path: str) -> bool:
    return (os.path.isdir(path)
            and os.path.exists(os.path.join(path, "COMMIT"))
            and os.path.exists(os.path.join(path, "manifest.json"))
            and os.path.exists(os.path.join(path, "arrays.npz")))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and _is_valid(
                os.path.join(directory, name)):
            try:
                steps.append(int(name[5:]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore(directory: str, like: Dict[str, Any],
            step: Optional[int] = None
            ) -> Optional[Tuple[int, Dict[str, Any], Dict[str, Any]]]:
    """Restore the newest valid checkpoint into the structure of ``like``.
    Returns (step, state, extra) or None if nothing restorable."""
    if step is None:
        step = latest_step(directory)
    if step is None:
        return None
    path = os.path.join(directory, f"step_{step:09d}")
    if not _is_valid(path):
        return None
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = _flatten(like)
    if manifest["n_leaves"] != len(leaves_like):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(leaves_like)} — incompatible tree")
    leaves = []
    for i, ref in enumerate(leaves_like):
        arr = _from_storable(data[f"a{i}"], manifest["leaves"][i]["dtype"])
        want = tuple(ref.shape) if hasattr(ref, "shape") else None
        if want is not None and tuple(arr.shape) != want:
            raise ValueError(f"leaf {i}: shape {arr.shape} != {want}")
        leaves.append(arr)
    state = jax.tree.unflatten(treedef, leaves)
    return manifest["step"], state, manifest.get("extra", {})
