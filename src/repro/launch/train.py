"""Production training launcher: builds the mesh + rules for an assigned
architecture, restores the newest checkpoint, and runs the training loop.

On the real cluster each host runs:
    python -m repro.launch.train --arch grok-1-314b --shape train_4k \
        --coordinator <addr> --num-hosts N --host-id i
(jax.distributed wiring included).  On this CPU container, run with
--local-smoke to execute a reduced config end-to-end through the same code
path.
"""
import argparse
import os
import sys


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--local-smoke", action="store_true")
    ap.add_argument("--coordinator", default="")
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator:
        import jax
        jax.distributed.initialize(args.coordinator, args.num_hosts,
                                   args.host_id)

    from repro import configs
    from repro.configs import SHAPES
    from repro.data.pipeline import DataConfig
    from repro.models import get_model
    from repro.optim.adamw import AdamWConfig
    from repro.optim.schedule import cosine_with_warmup
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = configs.get(args.arch)
    if args.local_smoke:
        cfg = cfg.reduced()
        seq_len, global_batch = 64, 4
    else:
        shape = SHAPES[args.shape]
        seq_len, global_batch = shape.seq_len, shape.global_batch
        # production mesh + sharding context
        from repro.dist.sharding import set_context
        from repro.launch.dryrun import rules_for
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh()
        model_tmp = get_model(cfg)
        set_context(mesh, rules_for(model_tmp, shape, multi_pod=False))

    model = get_model(cfg)
    trainer = Trainer(
        model,
        AdamWConfig(lr=cosine_with_warmup(3e-4, 100, args.steps),
                    moment_dtype="bfloat16" if cfg.n_params() > 2e11
                    else "float32"),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                   global_batch=global_batch,
                   num_hosts=args.num_hosts, host_index=args.host_id,
                   emit_embeddings=not cfg.embed_inputs
                   and not cfg.is_encoder_decoder,
                   emit_frames=cfg.is_encoder_decoder,
                   d_model=cfg.d_model),
        TrainerConfig(steps=args.steps, checkpoint_dir=args.ckpt or None,
                      checkpoint_every=50),
    )
    out = trainer.run()
    print(f"[launch.train] done: loss {out['first_loss']:.4f} -> "
          f"{out['last_loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
