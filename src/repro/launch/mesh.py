"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The single-pod mesh is 16x16 = 256 chips
("data", "model"); the multi-pod mesh is 2x16x16 = 512 chips
("pod", "data", "model").
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(n_devices: int = 1):
    """Small local mesh for tests: (n, 1) ("data", "model")."""
    import numpy as np
    devs = jax.devices()[:n_devices]
    return jax.sharding.Mesh(
        np.asarray(devs).reshape(len(devs), 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
