"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The single-pod mesh is 16x16 = 256 chips
("data", "model"); the multi-pod mesh is 2x16x16 = 512 chips
("pod", "data", "model").
"""
from __future__ import annotations

import jax


def _auto_axis_types(n: int):
    """jax >= 0.5 wants explicit AxisType.Auto; older jax has no AxisType
    (every axis is implicitly auto)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_axis_types(len(axes)))


def make_host_mesh(n_devices: int = 1):
    """Small local mesh for tests: (n, 1) ("data", "model")."""
    import numpy as np
    devs = jax.devices()[:n_devices]
    return jax.sharding.Mesh(
        np.asarray(devs).reshape(len(devs), 1), ("data", "model"),
        **_auto_axis_types(2))
