"""Serving launcher: production mesh + the paged continuous-batching engine.

On this container run --local-smoke (reduced config, real engine).  The
production path is the ``PagedEngine`` (refcounted page pool with prefix
sharing + copy-on-write, tick scheduler with partial grants, chunked
prefill through the one fused decode cell); --whole-batch falls back to
lockstep whole-batch generation (``ServingEngine``), --legacy-loop to the
per-token host loop, both kept for measured comparison.
"""
import argparse
import sys

import numpy as np


class SpecCapacityError(ValueError):
    """``--spec-k`` asks the draft pool for more rows than its block
    tables can hold: the draft cache must fit every request's full span
    PLUS k in-flight proposals, and ``PagedKVCache.ensure`` raising
    mid-run (after minutes of serving) is the failure mode this
    startup check replaces."""


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--local-smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--whole-batch", action="store_true",
                    help="lockstep whole-batch generation instead of the "
                         "paged continuous-batching engine")
    ap.add_argument("--legacy-loop", action="store_true",
                    help="with --whole-batch: per-token host loop instead "
                         "of fused decode_many")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable prompt-prefix page sharing on admission "
                         "(implies no cross-lifetime retention)")
    ap.add_argument("--no-retain-prefixes", action="store_true",
                    help="disable cross-lifetime prefix retention: a "
                         "finished/evicted request's page-aligned prefix "
                         "pages return to the free list immediately "
                         "instead of staying adoptable by digest after "
                         "the donor is gone")
    ap.add_argument("--retain-policy", default="lru",
                    choices=("lru", "popularity"),
                    help="retained-pool reclamation order under pool "
                         "pressure: least-recently-touched entries first "
                         "(lru) or fewest-adoptions first (popularity — "
                         "keeps hot system prompts alive longest)")
    ap.add_argument("--retain-pool-pages", type=int, default=0,
                    help="cap on retained-ONLY pages held idle (0 = "
                         "pool-bounded: retention uses whatever the free "
                         "list spares and pressure reclaims it lazily)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=4)
    ap.add_argument("--prefill-chunk-tokens", type=int, default=0,
                    help="ragged prefill lane: prompt tokens per chunked-"
                         "prefill kernel step (0 = auto: 2x --page-size; "
                         "a prompt costs ceil(prompt/T) dispatches "
                         "instead of one decode step per token)")
    ap.add_argument("--no-prefill-lane", action="store_true",
                    help="route prompts through the decode cell one "
                         "token per step (legacy prefill-by-decode, kept "
                         "for measured comparison)")
    ap.add_argument("--pages-per-step", type=int, default=1,
                    help="paged decode kernel page-list blocking: pages "
                         "swept per grid step (cuts grid steps by P for "
                         "long slots; only meaningful with the pallas "
                         "attention impl)")
    ap.add_argument("--kv-dtype", default="bf16", choices=("bf16", "int8"),
                    help="paged KV page pool storage: bf16 (model compute "
                         "dtype) or int8 (quantized pools + per-row f32 "
                         "scales dequantized inside the page sweep — "
                         "~halves the sweep's HBM bytes and ~doubles "
                         "resident tokens per HBM byte, at a bounded "
                         "logit drift)")
    ap.add_argument("--draft-arch", default="",
                    help="speculative decoding: registry id of the DRAFT "
                         "model (must share the target's tokenizer / "
                         "vocab size); proposes --spec-k tokens per "
                         "decode tick from its own paged KV pool, the "
                         "target verifies the window in ONE ragged "
                         "prefill-lane dispatch")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="draft proposals per decode tick (0 = off). A "
                         "tick keeps the accepted prefix plus one bonus "
                         "token, so a slot advances 1..k+1 tokens per "
                         "verify dispatch; greedy output is BIT-IDENTICAL "
                         "to plain decode regardless of accept rate")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="bounded admission: reject submits once this many "
                         "requests are waiting (0 = unbounded); rejected "
                         "requests get a typed REJECTED status, never an "
                         "engine crash")
    ap.add_argument("--deadline-ticks", type=int, default=0,
                    help="per-request tick deadline (0 = none): a request "
                         "still unfinished this many engine ticks after "
                         "submit is retired DEADLINE_EXCEEDED with its "
                         "partial output")
    ap.add_argument("--preempt-policy", default="fewest-tokens",
                    choices=("fewest-tokens", "most-pages"),
                    help="victim choice when the page pool wedges: evict "
                         "the request with the fewest generated tokens "
                         "(least recompute work lost) or the one holding "
                         "the most pages (frees the most pool per "
                         "eviction); preempted requests requeue and "
                         "recompute to a token-identical result")
    ap.add_argument("--no-preempt", action="store_true",
                    help="disable preempt-and-recompute: a wedged page "
                         "pool raises 'page pool exhausted' (the "
                         "pre-overload-safety behavior, kept for measured "
                         "comparison)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="crash consistency: write a full-state snapshot "
                         "(both page pools verbatim, tables, queue, "
                         "request lifecycle, RNG keys) every N engine "
                         "ticks (0 = off); atomic write-then-rename, "
                         "checksummed, pruned to the newest few files")
    ap.add_argument("--snapshot-dir", default="",
                    help="directory for snap-<tick>.bin files (required "
                         "with --snapshot-every)")
    ap.add_argument("--restore-from", default="",
                    help="restore the engine from a snapshot file and "
                         "resume its in-flight work instead of submitting "
                         "the demo workload; the snapshot's config "
                         "fingerprint must match the launch flags (typed "
                         "fast-fail BEFORE the engine builds)")
    ap.add_argument("--wedge-ticks", type=int, default=10_000,
                    help="consecutive idle-but-busy ticks before the "
                         "engine declares itself wedged and raises (a "
                         "bookkeeping-bug tripwire, not a tuning knob)")
    ap.add_argument("--sys-prompt-tokens", type=int, default=16,
                    help="shared system-prompt length for the demo "
                         "workload; keep it a MULTIPLE of --page-size — a "
                         "page-aligned shared prefix needs zero "
                         "copy-on-write (every shared page is full), a "
                         "mid-page prefix copies one page per sharer "
                         "(measured ~15%% tokens/s on the smoke config)")
    args = ap.parse_args()
    if args.legacy_loop and not args.whole_batch:
        ap.error("--legacy-loop only applies to --whole-batch generation "
                 "(the paged engine always runs the fused decode cell)")
    if args.page_size < 1:
        ap.error("--page-size must be >= 1 (tokens per KV page)")
    if args.pages_per_step < 1:
        ap.error("--pages-per-step must be >= 1 (pages swept per grid "
                 "step)")
    if args.prefill_chunk_tokens < 0:
        ap.error("--prefill-chunk-tokens must be >= 0 (0 = auto)")
    if args.max_queue < 0:
        ap.error("--max-queue must be >= 0 (0 = unbounded admission)")
    if args.deadline_ticks < 0:
        ap.error("--deadline-ticks must be >= 0 (0 = no deadline)")
    if args.retain_pool_pages < 0:
        ap.error("--retain-pool-pages must be >= 0 (0 = pool-bounded)")
    if args.spec_k < 0:
        ap.error("--spec-k must be >= 0 (draft proposals per decode tick)")
    if args.spec_k and not args.draft_arch:
        ap.error("--spec-k needs --draft-arch (a draft model proposes the "
                 "tokens the target verifies)")
    if args.draft_arch and not args.spec_k:
        ap.error("--draft-arch without --spec-k does nothing; pass "
                 "--spec-k >= 1 to enable speculative decoding")
    if args.spec_k and args.whole_batch:
        ap.error("speculative decoding is a paged-engine mode (draft pages "
                 "+ ragged verify); drop --whole-batch")
    if args.spec_k and args.no_prefill_lane:
        ap.error("speculative verify rides the ragged prefill lane; drop "
                 "--no-prefill-lane")
    if args.spec_k and args.temperature != 0.0:
        ap.error("speculative decoding is greedy-only (acceptance compares "
                 "argmax tokens); use --temperature 0")
    if args.snapshot_every < 0:
        ap.error("--snapshot-every must be >= 0 (0 = no snapshots)")
    if args.snapshot_every and not args.snapshot_dir:
        ap.error("--snapshot-every needs --snapshot-dir (where the "
                 "snap-<tick>.bin files land)")
    if args.restore_from and args.whole_batch:
        ap.error("--restore-from restores the PAGED engine's state; the "
                 "whole-batch path has no snapshot format — drop "
                 "--whole-batch")
    if args.wedge_ticks < 1:
        ap.error("--wedge-ticks must be >= 1 (idle ticks before the "
                 "wedge tripwire fires)")
    if args.kv_dtype == "int8" and args.whole_batch:
        ap.error("--kv-dtype int8 quantizes the PAGED page pools (the "
                 "Pallas/reference paged attention path); the whole-batch "
                 "dense cache has no page pool to quantize — drop "
                 "--whole-batch or use --kv-dtype bf16")
    if args.no_prefix_sharing and not args.no_retain_prefixes:
        print("[launch.serve] NOTE: --no-prefix-sharing disables the "
              "donor index, so cross-lifetime retention is off too "
              "(retention is digest-keyed prefix sharing)")
    if args.deadline_ticks and args.deadline_ticks < args.new_tokens:
        print(f"[launch.serve] NOTE: --deadline-ticks "
              f"({args.deadline_ticks}) is below --new-tokens "
              f"({args.new_tokens}) — a decode tick emits at most one "
              f"token per request, so most requests will retire "
              f"DEADLINE_EXCEEDED with partial output")
    if args.no_preempt and args.max_queue == 0:
        print("[launch.serve] NOTE: --no-preempt with unbounded admission "
              "restores the crashing overload behavior — an oversubscribed "
              "pool raises 'page pool exhausted' instead of preempting")
    if not args.no_prefill_lane and args.prefill_chunk_tokens % args.page_size:
        print(f"[launch.serve] NOTE: --prefill-chunk-tokens "
              f"({args.prefill_chunk_tokens}) is not a multiple of "
              f"--page-size ({args.page_size}) — prefill chunk grants are "
              f"clipped to page boundaries, so a non-aligned chunk wastes "
              f"its tail rows on every mid-prompt chunk; pick a multiple "
              f"of the page size (the same alignment guidance as "
              f"--sys-prompt-tokens below)")

    import jax
    from repro import configs
    from repro.models import get_model
    from repro.serve.engine import PagedEngine, ServeConfig, ServingEngine

    cfg = configs.get(args.arch)
    if args.local_smoke:
        cfg = cfg.reduced()
    if (args.pages_per_step != 1 or args.kv_dtype != "bf16"
            or args.draft_arch):
        import dataclasses
        cfg = dataclasses.replace(cfg, pages_per_step=args.pages_per_step,
                                  kv_dtype=args.kv_dtype,
                                  draft_arch=args.draft_arch)
    if args.sys_prompt_tokens % args.page_size:
        print(f"[launch.serve] NOTE: sys prompt ({args.sys_prompt_tokens} "
              f"tokens) is not page-aligned (page {args.page_size}) — every "
              f"sharer will copy-on-write the partial trailing page; align "
              f"shared system prompts to the page size for zero-copy "
              f"sharing")
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    draft_model = draft_params = None
    if args.spec_k:
        dcfg = configs.get(args.draft_arch)
        if args.local_smoke:
            dcfg = dcfg.reduced()
        if dcfg.vocab_size != cfg.vocab_size:
            ap.error(f"--draft-arch {args.draft_arch!r} has vocab "
                     f"{dcfg.vocab_size}, target has {cfg.vocab_size} — "
                     f"speculation needs a shared tokenizer")
        draft_model = get_model(dcfg)
        draft_params = draft_model.init(jax.random.key(1))
    # 2x batch requests of (prompt<=16 + new_tokens) tokens each; the paged
    # engine recycles pages across requests so max_seq only bounds ONE
    # request's span, not the engine's lifetime
    # worst-case request span: prompt (sys + tail <= 8) + the largest
    # staggered budget (new_tokens + 2*(batch-1)) + chunk-overshoot margin
    max_seq = max(64, args.sys_prompt_tokens + 8 + args.new_tokens
                  + 2 * (args.batch - 1) + 16)
    if args.spec_k:
        # DRAFT-POOL CAPACITY FAST-FAIL: the draft cache must hold a
        # request's full span plus k in-flight proposals — past this
        # bound ``dkv.ensure`` raises deep inside a tick, potentially
        # minutes into a run.  Same block-table geometry as the target
        # (max_seq rows), so the check is pure arithmetic.
        span_max = (args.sys_prompt_tokens + 8 + args.new_tokens
                    + 2 * (args.batch - 1))
        if span_max + args.spec_k > max_seq:
            raise SpecCapacityError(
                f"--spec-k {args.spec_k} overflows the draft pool: the "
                f"worst-case request span is {span_max} tokens and the "
                f"draft block tables hold max_seq={max_seq} rows, so up "
                f"to {max_seq - span_max} proposals fit in flight; "
                f"lower --spec-k or --new-tokens/--sys-prompt-tokens")
    if args.restore_from:
        # FINGERPRINT FAST-FAIL: compare the snapshot header against the
        # launch flags BEFORE paying for engine construction — a
        # mismatched restore must die with a typed error naming the
        # divergent knob, not a shape error mid-restore
        from repro.serve.snapshot import SnapshotMismatchError, load_header
        fp = load_header(args.restore_from)["fingerprint"]
        want = {"arch": cfg.name, "kv_dtype": cfg.kv_dtype,
                "max_batch": args.batch, "max_seq": max_seq,
                "page_size": args.page_size, "spec_k": args.spec_k,
                "temperature": args.temperature,
                "prefill_lane": not args.no_prefill_lane}
        diff = {k: (fp.get(k), v) for k, v in want.items()
                if fp.get(k) != v}
        if diff:
            raise SnapshotMismatchError(
                f"{args.restore_from}: snapshot was taken from a "
                f"different serving config (snapshot vs launch flags): "
                f"{diff}")
    scfg = ServeConfig(max_batch=args.batch, max_seq=max_seq,
                       max_new_tokens=args.new_tokens,
                       temperature=args.temperature,
                       fused=not args.legacy_loop,
                       page_size=args.page_size,
                       prefill_chunk=args.prefill_chunk,
                       prefill_lane=not args.no_prefill_lane,
                       prefill_chunk_tokens=args.prefill_chunk_tokens,
                       prefix_sharing=not args.no_prefix_sharing,
                       retain_prefixes=not args.no_retain_prefixes,
                       retain_pool_pages=args.retain_pool_pages,
                       retain_policy=args.retain_policy,
                       preempt=not args.no_preempt,
                       preempt_policy=args.preempt_policy,
                       max_queue=args.max_queue,
                       deadline_ticks=args.deadline_ticks,
                       wedge_ticks=args.wedge_ticks,
                       snapshot_every_ticks=args.snapshot_every,
                       snapshot_dir=args.snapshot_dir,
                       spec_k=args.spec_k)
    rng = np.random.RandomState(0)

    if args.whole_batch:
        engine = ServingEngine(model, params, scfg)
        prompts = [rng.randint(0, cfg.vocab_size, size=rng.randint(4, 16)
                               ).astype(np.int32) for _ in range(args.batch)]
        outs = engine.generate_batch(prompts)
        mode = ("legacy per-token loop" if args.legacy_loop
                else "fused decode_many")
        print(f"[launch.serve] generated {sum(len(o) for o in outs)} tokens "
              f"across {len(outs)} requests ({mode})")
        return 0

    engine = PagedEngine(model, params, scfg,
                         draft_model=draft_model, draft_params=draft_params)
    # pool capacity banner: resident tokens per HBM byte is the quantized-
    # pool payoff (int8 + per-row f32 scales vs 2-byte bf16 rows); a draft
    # pool, when speculating, is extra HBM the speedup has to pay for
    tok_bytes = engine.kv.page_bytes / engine.kv.page
    pool_bytes = engine.kv.num_pages * engine.kv.page_bytes
    draft_bytes = (engine.dkv.num_pages * engine.dkv.page_bytes
                   if engine.dkv is not None else 0)
    print(f"[launch.serve] pool: kv_dtype={args.kv_dtype}, "
          f"{engine.kv.num_pages} pages x {args.page_size} tokens, "
          f"{engine.kv.page_bytes} B/page ({tok_bytes:.1f} B/token, "
          f"{1.0 / tok_bytes:.4f} resident tokens per HBM byte, "
          f"{pool_bytes / 1e6:.2f} MB pool"
          + (f" + {draft_bytes / 1e6:.2f} MB draft pool" if draft_bytes
             else "") + ")")
    if args.spec_k:
        print(f"[launch.serve] speculative: draft={args.draft_arch} "
              f"k={args.spec_k} (a decode tick verifies k+1 = "
              f"{args.spec_k + 1} positions in one ragged dispatch)")
    if args.restore_from:
        # resume the snapshot's in-flight work instead of submitting the
        # demo workload: queued requests re-admit through the prefill
        # lane, running slots keep decoding from their restored feed
        import time
        from repro.serve.snapshot import restore_engine
        t0 = time.perf_counter()
        restore_engine(engine, args.restore_from)
        restore_ms = (time.perf_counter() - t0) * 1e3
        rids = sorted(engine.status)
        print(f"[launch.serve] restored tick {engine.ticks} from "
              f"{args.restore_from} in {restore_ms:.1f} ms "
              f"({len(engine.queue)} queued, "
              f"{sum(s.active for s in engine.slots)} running, "
              f"{sum(1 for r in rids if engine.status[r].value in ('finished', 'preempted_resumed'))} "
              f"already terminal)")
    else:
        # shared system prompt + per-request tail: the prefix-sharing
        # showcase.  Budgets are STAGGERED so early slots outlive late
        # admissions — a joiner only shares pages while a donor is still
        # resident
        sys_prompt = rng.randint(0, cfg.vocab_size,
                                 size=args.sys_prompt_tokens
                                 ).astype(np.int32)
        rids = [engine.submit(
            np.concatenate(
                [sys_prompt,
                 rng.randint(0, cfg.vocab_size, size=rng.randint(2, 8)
                             ).astype(np.int32)]),
            max_new_tokens=args.new_tokens + (i % args.batch) * 2)
            for i in range(2 * args.batch)]
    results = engine.run()
    util = engine.util_trace
    print(f"[launch.serve] paged: {len(results)} requests, "
          f"{sum(len(results[r]) for r in rids)} tokens, "
          f"{engine.joins} joins over {args.batch} slots in "
          f"{engine.steps_run} ticks; "
          f"shared {engine.shared_tokens} prefix tokens "
          f"(logical/physical x{engine.logical_physical_ratio:.2f}, "
          f"{engine.kv.cow_copies} COW copies), page util "
          f"mean={np.mean(util) if util else 0:.2f} "
          f"max={np.max(util) if util else 0:.2f}")
    print(f"[launch.serve] retention: {engine.kv.retained_hits} retained "
          f"adoptions ({engine.kv.retained_hit_tokens} tokens re-shared "
          f"from dead donors), {engine.kv.retained_pages} pages retained, "
          f"{engine.kv.retained_reclaimed_pages} reclaimed under pressure")
    from repro.serve.engine import RequestStatus
    n_status = {s.value: sum(1 for r in rids if engine.status[r] == s)
                for s in RequestStatus}
    print(f"[launch.serve] overload: {engine.preemptions} preemptions "
          f"({engine.recompute_tokens} recomputed tokens), "
          f"{engine.rejected} rejected, "
          f"{engine.deadline_exceeded} deadline-exceeded, "
          f"{engine.no_progress_ticks} no-progress ticks; statuses "
          + ", ".join(f"{k}={v}" for k, v in n_status.items() if v))
    if args.snapshot_every:
        print(f"[launch.serve] crash consistency: "
              f"{engine.snapshots_written} snapshots written to "
              f"{args.snapshot_dir} (every {args.snapshot_every} ticks, "
              f"newest at tick {engine._last_snapshot_tick})")
    if args.spec_k:
        print(f"[launch.serve] speculative: accept rate "
              f"{engine.accept_rate:.2f} ({engine.spec_accepted}/"
              f"{engine.spec_proposed} proposals), "
              f"{engine.draft_dispatches} draft + "
              f"{engine.verify_dispatches} verify dispatches, "
              f"{engine.spec_trunc_tokens} rejected K/V rows truncated")
    return 0


if __name__ == "__main__":
    sys.exit(main())
