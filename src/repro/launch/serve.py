"""Serving launcher: production mesh + batched engine.

On this container run --local-smoke (reduced config, real engine).  The
decode hot path is the fused device-resident ``decode_many`` loop
(--legacy-loop falls back to the per-token host loop for comparison);
--continuous exercises the slot-scheduled continuous-batching engine.
"""
import argparse
import sys

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--local-smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--legacy-loop", action="store_true",
                    help="per-token host loop instead of fused decode_many")
    ap.add_argument("--continuous", action="store_true",
                    help="slot-scheduled continuous batching demo "
                         "(submits 2x batch requests over batch slots)")
    ap.add_argument("--paged", action="store_true",
                    help="with --continuous: the non-lockstep paged engine "
                         "(per-slot positions, page free list, chunked "
                         "prefill through the fused decode cell)")
    args = ap.parse_args()

    import jax
    from repro import configs
    from repro.models import get_model
    from repro.serve.engine import (
        ContinuousBatchingEngine, PagedEngine, ServeConfig, ServingEngine)

    cfg = configs.get(args.arch)
    if args.local_smoke:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    # continuous mode runs 2x batch requests through batch slots in
    # lockstep: two admission waves of (prompt<=16 + new_tokens) shared
    # cache positions each — size max_seq for the requested workload
    # instead of crashing on cache exhaustion for large --new-tokens
    max_seq = max(128, 2 * (16 + args.new_tokens) + 16)
    scfg = ServeConfig(max_batch=args.batch, max_seq=max_seq,
                       max_new_tokens=args.new_tokens,
                       temperature=args.temperature,
                       fused=not args.legacy_loop)
    rng = np.random.RandomState(0)

    if args.continuous:
        cls = PagedEngine if args.paged else ContinuousBatchingEngine
        engine = cls(model, params, scfg)
        rids = [engine.submit(
            rng.randint(0, cfg.vocab_size, size=rng.randint(4, 16)
                        ).astype(np.int32)) for _ in range(2 * args.batch)]
        results = engine.run()
        extra = (f", page util mean="
                 f"{engine.util_sum / max(1, engine.steps_run):.2f} "
                 f"max={engine.util_max:.2f}" if args.paged else "")
        print(f"[launch.serve] continuous[{'paged' if args.paged else 'dense'}"
              f"]: {len(results)} requests, "
              f"{sum(len(results[r]) for r in rids)} tokens, "
              f"{engine.joins} joins over {args.batch} slots in "
              f"{engine.steps_run} steps{extra}")
        return 0

    engine = ServingEngine(model, params, scfg)
    prompts = [rng.randint(0, cfg.vocab_size, size=rng.randint(4, 16)
                           ).astype(np.int32) for _ in range(args.batch)]
    outs = engine.generate_batch(prompts)
    mode = "legacy per-token loop" if args.legacy_loop else "fused decode_many"
    print(f"[launch.serve] generated {sum(len(o) for o in outs)} tokens "
          f"across {len(outs)} requests ({mode})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
