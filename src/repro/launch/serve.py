"""Serving launcher: production mesh + batched engine.

On this container run --local-smoke (reduced config, real engine).
"""
import argparse
import sys

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--local-smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    import jax
    from repro import configs
    from repro.models import get_model
    from repro.serve.engine import ServeConfig, ServingEngine

    cfg = configs.get(args.arch)
    if args.local_smoke:
        cfg = cfg.reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, ServeConfig(
        max_batch=args.batch, max_seq=128,
        max_new_tokens=args.new_tokens))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=rng.randint(4, 16)
                           ).astype(np.int32) for _ in range(args.batch)]
    outs = engine.generate_batch(prompts)
    print(f"[launch.serve] generated {sum(len(o) for o in outs)} tokens "
          f"across {len(outs)} requests")
    return 0


if __name__ == "__main__":
    sys.exit(main())
