import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines, before any jax import: jax locks the device
# count on first init, and the production meshes below need 512 placeholder
# host devices (2 pods x 16 x 16).  Do not set this anywhere global — smoke
# tests and benches must see the single real CPU device.

"""Multi-pod dry-run driver.

For every live (architecture x input-shape) cell this lowers + compiles the
real step function (train_step with optimizer, or serve prefill/decode) for
the single-pod 16x16 mesh and the 2x16x16 multi-pod mesh, prints
``memory_analysis()`` / ``cost_analysis()``, runs the HLO instruction census
and emits the roofline record (EXPERIMENTS.md sections Dry-run / Roofline read
these JSON files).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--resume]
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import SHAPES, ShapeConfig, shape_applicable
from repro.configs.all_archs import ALL_ARCHS
from repro.core.hardware import TPU_V5E
from repro.dist.sharding import MeshRules, use_mesh
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.models.model import Model
from repro.optim.adamw import AdamW, AdamWConfig
from repro.profiler.session import profile_compiled
from repro.train.train_step import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

# params above this per-TP-shard size keep FSDP sharding even for serving
_SERVE_FSDP_BYTES = 8e9


def _params_bytes(model: Model) -> float:
    total = 0
    for leaf in jax.tree.leaves(model.abstract_params()):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n * leaf.dtype.itemsize
    return float(total)


def rules_for(model: Model, shape: ShapeConfig,
              multi_pod: bool) -> MeshRules:
    pod = ("pod",) if multi_pod else ()
    if shape.kind == "train" or shape.kind == "prefill":
        return MeshRules(batch_axes=pod + ("data",),
                         fsdp_axes=pod + ("data",),
                         cache_seq_axes=("model",),
                         use_fsdp=True)
    # decode
    tp = 16
    big = _params_bytes(model) / tp > _SERVE_FSDP_BYTES
    if shape.global_batch == 1:                      # long_500k
        return MeshRules(batch_axes=(),
                         fsdp_axes=("data",),
                         cache_seq_axes=pod + ("data", "model"),
                         use_fsdp=big)
    if big:
        # PERF(it.1, grok decode): 2D weight-stationary serving.  Sharding
        # the batch over the same axes that FSDP-shard the weights forces
        # GSPMD to all-gather the WEIGHTS every step (measured 54 GB/step
        # wire on grok).  Instead: batch on 'pod' only, weights stay sharded
        # 2D (data x model), matmuls emit tiny activation psums, expert
        # blocks are EP-sharded across data x model, and the KV cache is
        # sequence-sharded across data x model.
        return MeshRules(batch_axes=pod,
                         fsdp_axes=("data",),
                         cache_seq_axes=("data", "model"),
                         ep_axes=("data", "model"),
                         use_fsdp=True)
    return MeshRules(batch_axes=pod + ("data",),
                     fsdp_axes=pod + ("data",),
                     cache_seq_axes=("model",),
                     use_fsdp=False)


def _validated(spec: P, shape: Tuple[int, ...], mesh) -> P:
    """Drop mesh axes from dims they don't divide (replicate instead) —
    jit input shardings require exact divisibility."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(entry if shape[i] % size == 0 else None)
    return P(*out)


def _shardings(tree_specs, tree_abstract, mesh):
    def mk(spec, ab):
        return NamedSharding(mesh, _validated(spec, ab.shape, mesh))
    return jax.tree.map(mk, tree_specs, tree_abstract)


def _microbatches(model: Model, shape: ShapeConfig, n_dp: int,
                  budget_bytes: float = 3e9) -> int:
    """Gradient-accumulation factor keeping the per-device residual stack
    (L x B_loc x S x d x 2B, the scan-carry remat checkpoint) under budget."""
    cfg = model.cfg
    b_loc = max(1, shape.global_batch // n_dp)
    stack = cfg.n_layers * b_loc * shape.seq_len * cfg.d_model * 2.0
    if cfg.is_encoder_decoder:
        stack *= 2
    mb = 1
    while stack / mb > budget_bytes and mb < b_loc:
        mb *= 2
    while b_loc % mb != 0:
        mb *= 2
    return min(mb, b_loc)


def build_cell(model: Model, shape: ShapeConfig, rules: MeshRules, mesh):
    """Returns (fn, args_abstract, in_shardings, out_shardings,
    donate_argnums, info)."""
    cfg = model.cfg
    info = {}

    def named(tree_specs, tree_like=None):
        if tree_specs is None:
            return None
        if tree_like is None:
            return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs)
        return _shardings(tree_specs, tree_like, mesh)

    if shape.kind == "train":
        # PERF(it.2): >50B-param archs use bf16 moments (int8 blockwise
        # moments exist and converge — tests — but their dequant reshape
        # replicates under GSPMD; sharding them needs a shard_map optimizer,
        # recorded as future work in EXPERIMENTS.md)
        n = cfg.n_params()
        moment_dtype = "bfloat16" if n > 5e10 else "float32"
        n_dp = 1
        for a in rules.batch_axes:
            n_dp *= mesh.shape[a]
        mb = _microbatches(model, shape, n_dp)
        # layer-grouped remat when the residual stack is still over budget
        # at the max microbatch count (see transformer.lm_forward)
        b_loc = max(1, shape.global_batch // n_dp // mb)
        stack = cfg.n_layers * b_loc * shape.seq_len * cfg.d_model * 2.0
        if stack > 3e9 and not cfg.mamba_version \
                and not cfg.is_encoder_decoder:
            import dataclasses as _dc
            for g in (2, 4, 8):
                if cfg.n_layers % g == 0 and stack / g <= 3e9:
                    break
            cfg = _dc.replace(cfg, remat_group=g)
            model = Model(cfg)
            info.update(remat_group=g)
        info.update(moment_dtype=moment_dtype, microbatches=mb)
        opt = AdamW(AdamWConfig(moment_dtype=moment_dtype))
        accum_dtype = jnp.bfloat16 if n > 2e11 else jnp.float32
        step = make_train_step(model, opt, microbatches=mb,
                               accum_dtype=accum_dtype)
        a_params = model.abstract_params()
        a_opt = opt.abstract_state(a_params)
        a_batch = model.input_specs(shape)
        p_specs = model.param_pspecs(rules)
        p_sh = _shardings(p_specs, a_params, mesh)
        o_sh = _shardings(opt.state_pspecs(p_specs), a_opt, mesh)
        in_sh = (p_sh, o_sh,
                 _shardings(model.batch_pspecs(shape, rules), a_batch, mesh))
        metrics_sh = {k: NamedSharding(mesh, P())
                      for k in ("grad_norm", "lr", "loss")}
        out_sh = (p_sh, o_sh, metrics_sh)
        return step, (a_params, a_opt, a_batch), in_sh, out_sh, (0, 1), info

    if shape.kind == "prefill":
        def prefill(params, batch):
            return model.prefill(params, batch)
        a_params = model.abstract_params()
        a_batch = model.input_specs(shape)
        p_specs = model.param_pspecs(rules)
        in_sh = (_shardings(p_specs, a_params, mesh),
                 _shardings(model.batch_pspecs(shape, rules), a_batch, mesh))
        logits_sh = NamedSharding(mesh, _validated(
            P(rules.resolve("batch"), "model"),
            (shape.global_batch, cfg.vocab_size), mesh))
        cache_sh = named(model.prefill_cache_pspecs(shape, rules))
        out_sh = (logits_sh, cache_sh)
        return prefill, (a_params, a_batch), in_sh, out_sh, (), info

    # decode
    def decode(params, tokens, cache):
        return model.decode_step(params, tokens, cache)
    a_params = model.abstract_params()
    specs = model.input_specs(shape)
    a_tokens, a_cache = specs["tokens"], specs["cache"]
    p_specs = model.param_pspecs(rules)
    b_specs = model.batch_pspecs(shape, rules)
    cache_sh = _shardings(b_specs["cache"], a_cache, mesh)
    in_sh = (_shardings(p_specs, a_params, mesh),
             _shardings(b_specs["tokens"], a_tokens, mesh),
             cache_sh)
    logits_sh = NamedSharding(mesh, _validated(
        P(rules.resolve("batch"), "model"),
        (shape.global_batch, cfg.vocab_size), mesh))
    out_sh = (logits_sh, cache_sh)
    return decode, (a_params, a_tokens, a_cache), in_sh, out_sh, (2,), info


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}/{shape_name}/{mesh_name}"
    skip = shape_applicable(cfg, shape)
    if skip:
        return {"cell": cell, "skipped": skip}

    model = get_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(model, shape, multi_pod)
    if cfg.n_experts and len(rules.ep_axes) > 1:
        # 2D expert parallelism: expert-weight block count follows the EP
        # axes product (checkpoint resharding is a reshape — elastic.py)
        g = 1
        for a in rules.ep_axes:
            g *= mesh.shape[a]
        import dataclasses as _dc
        model = get_model(_dc.replace(cfg, ep_shards=g))
    n_dev = mesh.size
    t0 = time.time()
    with use_mesh(mesh, rules):
        fn, args, in_sh, out_sh, donate, info = build_cell(model, shape,
                                                           rules, mesh)
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        record = profile_compiled(cell, compiled, n_devices=n_dev,
                                  model_flops=model.model_flops(shape))
    record.update({
        "cell": cell, "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "multi_pod": multi_pod, "lower_s": t_lower, "compile_s": t_compile,
        "build_info": info,
        "rules": {
            "batch_axes": rules.batch_axes, "fsdp_axes": rules.fsdp_axes,
            "cache_seq_axes": rules.cache_seq_axes,
            "use_fsdp": rules.use_fsdp},
    })
    if verbose:
        mem = record.get("memory", {})
        rl = record.get("roofline", {})
        print(f"[dryrun] {cell}: compile {t_compile:.1f}s | "
              f"dev bytes {mem.get('device_total_bytes', 0)/2**30:.2f} GiB | "
              f"{rl.get('dominant')}-bound | modeled "
              f"{float(rl.get('modeled_time_s') or 0)*1e3:.2f} ms | "
              f"MFU {float(rl.get('mfu_vs_peak') or 0)*100:.1f}%")
        sys.stdout.flush()
    return record


def _out_path(arch: str, shape_name: str, multi_pod: bool) -> str:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh_name}.json")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose JSON already exists")
    args = ap.parse_args()

    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for mp in meshes:
                path = _out_path(arch, shape_name, mp)
                if args.resume and os.path.exists(path):
                    continue
                try:
                    rec = run_cell(arch, shape_name, mp)
                except Exception as e:                      # noqa: BLE001
                    rec = {"cell": f"{arch}/{shape_name}/mp={mp}",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                    failures.append(rec["cell"])
                    print(f"[dryrun] FAILED {rec['cell']}: {rec['error']}")
                    sys.stdout.flush()
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1, default=str)
    if failures:
        print(f"[dryrun] {len(failures)} failures: {failures}")
        return 1
    print("[dryrun] all requested cells OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
