from repro.profiler.session import profile_compiled  # noqa: F401
