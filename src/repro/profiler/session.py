"""Profiling session: turn one compiled XLA step into an instruction-roofline
record — the paper's per-kernel table row, generalized to a distributed step.

This is the integration point of the whole system: dry-run -> compiled
artifact -> {cost_analysis, memory_analysis, HLO census} -> three-term
roofline + TPU instruction profile (Eq. 2/3/4 analogues).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core.hardware import HardwareSpec, TPU_V5E
from repro.core.hlo_counters import census_from_compiled
from repro.core.report import census_summary
from repro.core.roofline import roofline_terms, to_row
from repro.core.tpu_model import profile_from_census


def _memory_dict(mem) -> Dict[str, float]:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = float(getattr(mem, k, 0) or 0)
    out["device_total_bytes"] = (out["argument_size_in_bytes"]
                                 + out["output_size_in_bytes"]
                                 + out["temp_size_in_bytes"]
                                 - out["alias_size_in_bytes"])
    return out


def profile_compiled(name: str, compiled, n_devices: int,
                     hw: HardwareSpec = TPU_V5E,
                     model_flops: Optional[float] = None) -> Dict[str, Any]:
    census = census_from_compiled(compiled)
    terms = roofline_terms(name, census, hw, n_devices,
                           model_flops_total=model_flops)
    tpu_prof = profile_from_census(name, census, hw,
                                   runtime_s=max(terms.modeled_time_s, 1e-12),
                                   runtime_is_modeled=True)
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # older jax: one dict per device
            cost = cost[0] if cost else {}
        cost = dict(cost)
    except Exception:                                 # pragma: no cover
        cost = {}
    try:
        mem = _memory_dict(compiled.memory_analysis())
    except Exception:                                 # pragma: no cover
        mem = {}
    return {
        "name": name,
        "n_devices": n_devices,
        "hw": hw.name,
        "memory": mem,
        "xla_cost_analysis": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))
                              and not k.startswith("utilization")},
        "census": census_summary(census),
        "roofline": to_row(terms),
        "irm": tpu_prof.table_row(),
    }
