"""TPU instruction-issue model — the Eq. 1/3/4 analogue for TPU chips.

The paper normalizes raw counter values to the machine's native execution
granularity (AMD: SQ_INSTS_VALU x 4 SIMDs, divided by 64-lane wavefronts).
A TPU TensorCore has two instruction-bearing unit classes:

  * the MXU(s): systolic 128x128 arrays; one "issue" here = one full
    contraction pass (128-deep) producing a 128x128 output tile;
  * the VPU: (8,128)-lane vector registers, ``vpu_alus`` ALU sub-units.

``hlo_counters`` produces ceil-tiled issue counts per class (padding-aware,
like the paper's transaction counts).  This module turns those into the
paper's headline quantities: peak GIPS per unit class, achieved GIPS at a
given runtime (measured or roofline-modeled), and instruction intensity in
(issue-scaled) instructions per byte.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.hardware import HardwareSpec
from repro.core.hlo_counters import Census


@dataclasses.dataclass
class TpuInstructionProfile:
    """The TPU 'Table 1 row' for one compiled step."""

    name: str
    hw: HardwareSpec
    runtime_s: float                  # measured, or roofline-modeled
    runtime_is_modeled: bool
    # issue counts (per device)
    mxu_issues: float
    vpu_issues: float
    scalar_ops: float
    # traffic
    hbm_bytes: float
    # raw flop context
    mxu_flops: float
    vpu_flops: float
    mxu_flops_padded: float

    # --- Eq. 3 analogues ---------------------------------------------------
    @property
    def peak_mxu_gips(self) -> float:
        return self.hw.peak_mxu_issues_per_s() / 1e9

    @property
    def peak_vpu_gips(self) -> float:
        return self.hw.peak_vpu_issues_per_s() / 1e9

    # --- Eq. 4 analogues ---------------------------------------------------
    @property
    def achieved_mxu_gips(self) -> float:
        return self.mxu_issues / (1e9 * self.runtime_s)

    @property
    def achieved_vpu_gips(self) -> float:
        return self.vpu_issues / (1e9 * self.runtime_s)

    @property
    def achieved_total_gips(self) -> float:
        insts = self.mxu_issues + self.vpu_issues + self.scalar_ops
        return insts / (1e9 * self.runtime_s)

    # --- Eq. 2 analogue (runtime-free intensity, inst/byte) -----------------
    @property
    def mxu_intensity(self) -> float:
        return self.mxu_issues / self.hbm_bytes if self.hbm_bytes else 0.0

    @property
    def vpu_intensity(self) -> float:
        return self.vpu_issues / self.hbm_bytes if self.hbm_bytes else 0.0

    @property
    def total_intensity(self) -> float:
        insts = self.mxu_issues + self.vpu_issues + self.scalar_ops
        return insts / self.hbm_bytes if self.hbm_bytes else 0.0

    # --- padding efficiency: the IRM-only insight ---------------------------
    @property
    def mxu_padding_efficiency(self) -> float:
        """useful MXU flops / flops implied by issued passes.  < 1.0 means
        tiles are padded (e.g. head_dim 64 wastes half of each 128-deep
        pass) — invisible on a FLOP roofline, visible on this one."""
        if not self.mxu_flops_padded:
            return 1.0
        return self.mxu_flops / self.mxu_flops_padded

    @property
    def mxu_utilization(self) -> float:
        return self.achieved_mxu_gips / self.peak_mxu_gips

    @property
    def vpu_utilization(self) -> float:
        return self.achieved_vpu_gips / self.peak_vpu_gips

    def dominant_unit(self) -> str:
        return ("mxu" if self.mxu_utilization >= self.vpu_utilization
                else "vpu")

    def table_row(self) -> dict:
        return {
            "name": self.name,
            "hw": self.hw.name,
            "runtime_s": self.runtime_s,
            "runtime_modeled": self.runtime_is_modeled,
            "peak_mxu_gips": self.peak_mxu_gips,
            "peak_vpu_gips": self.peak_vpu_gips,
            "achieved_mxu_gips": self.achieved_mxu_gips,
            "achieved_vpu_gips": self.achieved_vpu_gips,
            "mxu_intensity_inst_per_byte": self.mxu_intensity,
            "vpu_intensity_inst_per_byte": self.vpu_intensity,
            "mxu_padding_efficiency": self.mxu_padding_efficiency,
            "mxu_utilization": self.mxu_utilization,
            "vpu_utilization": self.vpu_utilization,
            "dominant_unit": self.dominant_unit(),
        }


def profile_from_census(name: str, census: Census, hw: HardwareSpec,
                        runtime_s: float,
                        runtime_is_modeled: bool = True
                        ) -> TpuInstructionProfile:
    return TpuInstructionProfile(
        name=name, hw=hw, runtime_s=runtime_s,
        runtime_is_modeled=runtime_is_modeled,
        mxu_issues=census.mxu_issues,
        vpu_issues=census.vpu_issues,
        scalar_ops=census.scalar_ops,
        hbm_bytes=census.hbm_bytes,
        mxu_flops=census.mxu_flops,
        vpu_flops=census.vpu_flops,
        mxu_flops_padded=census.mxu_flops_padded,
    )
