"""repro.core — the paper's contribution: instruction roofline models built
from constrained profiler interfaces (rocProf counters on AMD; AOT
cost/HLO-census on XLA/TPU)."""
from repro.core import hardware, paper_data, paper_model  # noqa: F401
from repro.core.hardware import HardwareSpec, get as get_hardware  # noqa: F401
from repro.core.hlo_counters import (  # noqa: F401
    Census, census_from_compiled, census_from_text)
from repro.core.irm import gpu_irm, tpu_irm  # noqa: F401
from repro.core.paper_model import KernelMeasurement  # noqa: F401
from repro.core.roofline import RooflineTerms, roofline_terms  # noqa: F401
from repro.core.tpu_model import (  # noqa: F401
    TpuInstructionProfile, profile_from_census)
