"""Hardware specification registry.

The paper (Leinhauser et al. 2021) builds instruction roofline models from a
small set of per-device constants: compute units, schedulers per unit, IPC,
frequency, native execution width (warp=32 / wavefront=64), and an
*empirically measured* memory bandwidth (BabelStream) where the profiler
cannot report one.  We keep exactly those fields for the paper's three GPUs
(used to reproduce Tables 1-2 and Figs 4-7) and extend the spec with the
fields the TPU instantiation needs: MXU/VPU issue geometry, HBM bandwidth and
ICI link bandwidth for the collective ceiling.

All TPU numbers are for a single chip.  Modeling assumptions (documented in
DESIGN.md section 2):
  * v5e: 197 TFLOP/s bf16 == 4 MXUs x (128x128 MACs x 2 flop) x 1.5023 GHz.
  * VPU: 4 ALU sub-units x (8x128)-lane vregs (the GCN "4 SIMDs per CU" of
    Eq. 1 maps onto this issue model).
  * ICI: ~50 GB/s per link per direction (prompt-specified planning number).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Constants needed to build instruction rooflines for one device."""

    name: str
    vendor: str                       # "amd" | "nvidia" | "google"
    # --- instruction ceiling (paper Eq. 3) -------------------------------
    compute_units: int                # CUs (AMD) / SMs (NVIDIA) / cores (TPU)
    schedulers_per_cu: int            # wavefront/warp schedulers per CU/SM
    ipc: int                          # instructions issued per cycle/scheduler
    frequency_ghz: float
    # --- native execution granularity (paper Eq. 4) ----------------------
    lanes_per_issue: int              # wavefront=64, warp=32, TPU vreg=1024
    # --- memory ceiling ---------------------------------------------------
    hbm_bw_theoretical_gbs: float
    hbm_bw_measured_gbs: Optional[float] = None  # BabelStream-style measured
    # --- compute ceiling in FLOP terms (TPU instantiation) ----------------
    peak_flops_bf16: Optional[float] = None      # per chip, FLOP/s
    peak_flops_fp32: Optional[float] = None
    # --- MXU/VPU issue geometry (TPU only) --------------------------------
    mxu_count: int = 0                # systolic arrays per chip
    mxu_dim: int = 128                # MXU is mxu_dim x mxu_dim
    vpu_alus: int = 4                 # ALU sub-units per VPU
    vpu_sublanes: int = 8
    vpu_lanes: int = 128
    # --- interconnect (collective ceiling) --------------------------------
    ici_links: int = 0                # links per chip (torus degree)
    ici_bw_per_link_gbs: float = 0.0  # per direction
    hbm_gib: float = 0.0              # device memory capacity

    # -- paper Eq. 3: GIPS_peak = CU x WFS/CU x IPC x freq ------------------
    def peak_gips(self) -> float:
        return (self.compute_units * self.schedulers_per_cu * self.ipc
                * self.frequency_ghz)

    # -- memory ceiling used for roofline plots ----------------------------
    def memory_ceiling_gbs(self) -> float:
        if self.hbm_bw_measured_gbs is not None:
            return self.hbm_bw_measured_gbs
        return self.hbm_bw_theoretical_gbs

    # -- TPU-only derived peaks --------------------------------------------
    def vpu_lanes_per_issue(self) -> int:
        return self.vpu_sublanes * self.vpu_lanes  # one vreg

    def peak_mxu_issues_per_s(self) -> float:
        """One MXU 'issue' = a full 128-deep systolic pass producing a
        mxu_dim x mxu_dim output tile (takes mxu_dim cycles)."""
        if self.mxu_count == 0:
            return 0.0
        return self.mxu_count * self.frequency_ghz * 1e9 / self.mxu_dim

    def peak_vpu_issues_per_s(self) -> float:
        """One VPU issue = one vreg-wide (sublanes x lanes) ALU op."""
        return self.vpu_alus * self.frequency_ghz * 1e9

    def flops_per_mxu_issue(self) -> float:
        # output tile (d x d) x contraction depth (d) x 2 (mul+add)
        return 2.0 * self.mxu_dim ** 3

    def mxu_flops_consistency(self) -> float:
        """peak bf16 FLOP/s implied by the issue model; should match
        peak_flops_bf16 (asserted in tests)."""
        return self.peak_mxu_issues_per_s() * self.flops_per_mxu_issue()


# ---------------------------------------------------------------------------
# Registry.  AMD/NVIDIA entries hold the exact constants the paper uses in
# Tables 1-2 (CU/SM count, schedulers, IPC, frequency) plus the BabelStream
# bandwidths from section 6.2.
# ---------------------------------------------------------------------------

MI60 = HardwareSpec(
    name="AMD Radeon Instinct MI60",
    vendor="amd",
    compute_units=64,
    schedulers_per_cu=1,
    ipc=1,
    frequency_ghz=1.800,
    lanes_per_issue=64,               # wavefront
    hbm_bw_theoretical_gbs=1000.0,
    # BabelStream copy: 808,975.476 MB/s (paper section 6.2)
    hbm_bw_measured_gbs=808.975476,
    hbm_gib=32.0,
)

MI100 = HardwareSpec(
    name="AMD Instinct MI100",
    vendor="amd",
    compute_units=120,
    schedulers_per_cu=1,
    ipc=1,
    frequency_ghz=1.502,
    lanes_per_issue=64,
    hbm_bw_theoretical_gbs=1200.0,
    # BabelStream copy: 933,355.781 MB/s (paper section 6.2)
    hbm_bw_measured_gbs=933.355781,
    hbm_gib=32.0,
)

V100 = HardwareSpec(
    name="NVIDIA Tesla V100",
    vendor="nvidia",
    compute_units=80,                 # SMs
    schedulers_per_cu=4,              # warp schedulers per SM
    ipc=1,
    frequency_ghz=1.530,
    lanes_per_issue=32,               # warp
    hbm_bw_theoretical_gbs=900.0,
    # paper: achieved >99% of theoretical via Nsight Compute
    hbm_bw_measured_gbs=None,
    hbm_gib=16.0,
)

# --- TPU targets -----------------------------------------------------------

TPU_V5E = HardwareSpec(
    name="TPU v5e",
    vendor="google",
    compute_units=1,                  # TensorCores per chip
    schedulers_per_cu=1,
    ipc=1,
    frequency_ghz=1.5023,             # chosen so 4 MXUs give 197 TFLOP/s bf16
    lanes_per_issue=1024,             # one (8,128) vreg
    hbm_bw_theoretical_gbs=819.0,
    hbm_bw_measured_gbs=None,
    peak_flops_bf16=197e12,
    peak_flops_fp32=49.25e12,
    mxu_count=4,
    mxu_dim=128,
    vpu_alus=4,
    vpu_sublanes=8,
    vpu_lanes=128,
    ici_links=4,                      # 2D torus: +-x, +-y
    ici_bw_per_link_gbs=50.0,
    hbm_gib=16.0,
)

TPU_V5P = HardwareSpec(
    name="TPU v5p",
    vendor="google",
    compute_units=2,
    schedulers_per_cu=1,
    ipc=1,
    frequency_ghz=1.75,
    lanes_per_issue=1024,
    hbm_bw_theoretical_gbs=2765.0,
    peak_flops_bf16=459e12,
    peak_flops_fp32=114.75e12,
    mxu_count=8,                      # 4 per TensorCore x 2
    mxu_dim=128,
    vpu_alus=8,
    ici_links=6,                      # 3D torus
    ici_bw_per_link_gbs=100.0,
    hbm_gib=95.0,
)

REGISTRY: Dict[str, HardwareSpec] = {
    "mi60": MI60,
    "mi100": MI100,
    "v100": V100,
    "tpu_v5e": TPU_V5E,
    "tpu_v5p": TPU_V5P,
}


def get(name: str) -> HardwareSpec:
    key = name.lower().replace("-", "_")
    if key not in REGISTRY:
        raise KeyError(f"unknown hardware {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[key]
