"""Three-term roofline model from a compiled XLA artifact.

For a per-device SPMD program (census from ``hlo_counters``):

    compute_term_s    = device_flops / peak_FLOP/s
    memory_term_s     = device_hbm_bytes / HBM_bw
    collective_term_s = device_collective_wire_bytes / (links x link_bw)

The dominant term is the modeled step time; the roofline fraction of each
term is term / max(term) and the bottleneck is argmax.  Since the census is
already per device, chip counts only enter via the sharded shapes — no
further division is needed (the prompt's "HLO_FLOPs / (chips x peak)" with
whole-job FLOPs is identical to per-device FLOPs / peak).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.hardware import HardwareSpec
from repro.core.hlo_counters import Census


@dataclasses.dataclass
class RooflineTerms:
    name: str
    hw_name: str
    n_devices: int
    # inputs (per device)
    flops: float
    hbm_bytes: float
    collective_wire_bytes: float
    # terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    # derived
    dominant: str                    # "compute" | "memory" | "collective"
    modeled_time_s: float            # max of the three terms
    bound_fraction: float            # dominant / sum  (1.0 == perfectly skewed)
    # usefulness accounting
    model_flops: Optional[float] = None      # 6ND-style algorithmic flops
    useful_flops_ratio: Optional[float] = None   # model_flops / hlo_flops
    # roofline fractions: how close each non-dominant term is to the roof
    compute_fraction: float = 0.0    # compute_s / modeled_time_s
    memory_fraction: float = 0.0
    collective_fraction: float = 0.0
    # achieved-at-modeled-time rates
    achieved_tflops: float = 0.0     # per device, at modeled time
    achieved_gbs: float = 0.0
    mfu_vs_peak: float = 0.0         # useful model flops / (time x peak)

    def summary(self) -> str:
        return (f"{self.name}: compute {self.compute_s*1e3:.3f} ms | "
                f"memory {self.memory_s*1e3:.3f} ms | collective "
                f"{self.collective_s*1e3:.3f} ms -> {self.dominant}-bound "
                f"(modeled {self.modeled_time_s*1e3:.3f} ms, "
                f"MFU {self.mfu_vs_peak*100:.1f}%)")


def roofline_terms(name: str,
                   census: Census,
                   hw: HardwareSpec,
                   n_devices: int,
                   model_flops_total: Optional[float] = None,
                   peak_flops: Optional[float] = None) -> RooflineTerms:
    """Build the three-term roofline for one compiled step.

    ``model_flops_total`` is the whole-job algorithmic FLOP count (e.g. 6ND);
    it is divided by ``n_devices`` for the per-device usefulness ratio.
    """
    peak = peak_flops or hw.peak_flops_bf16
    if not peak:
        raise ValueError(f"{hw.name} has no FLOP peak; pass peak_flops")
    hbm = hw.memory_ceiling_gbs() * 1e9
    link = hw.ici_links * hw.ici_bw_per_link_gbs * 1e9
    compute_s = census.flops / peak
    memory_s = census.hbm_bytes / hbm
    collective_s = (census.collective_wire_bytes / link) if link else 0.0
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    modeled = terms[dominant]
    total = sum(terms.values()) or 1.0

    model_flops_dev = (model_flops_total / n_devices
                       if model_flops_total else None)
    useful = (model_flops_dev / census.flops
              if model_flops_dev and census.flops else None)
    mfu = (model_flops_dev / (modeled * peak)
           if model_flops_dev and modeled > 0 else 0.0)
    return RooflineTerms(
        name=name, hw_name=hw.name, n_devices=n_devices,
        flops=census.flops, hbm_bytes=census.hbm_bytes,
        collective_wire_bytes=census.collective_wire_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, modeled_time_s=modeled,
        bound_fraction=terms[dominant] / total,
        model_flops=model_flops_total, useful_flops_ratio=useful,
        compute_fraction=compute_s / modeled if modeled else 0.0,
        memory_fraction=memory_s / modeled if modeled else 0.0,
        collective_fraction=collective_s / modeled if modeled else 0.0,
        achieved_tflops=(census.flops / modeled / 1e12) if modeled else 0.0,
        achieved_gbs=(census.hbm_bytes / modeled / 1e9) if modeled else 0.0,
        mfu_vs_peak=mfu,
    )


def to_row(t: RooflineTerms) -> Dict[str, object]:
    return {
        "name": t.name,
        "devices": t.n_devices,
        "flops_per_dev": t.flops,
        "hbm_bytes_per_dev": t.hbm_bytes,
        "collective_bytes_per_dev": t.collective_wire_bytes,
        "compute_s": t.compute_s,
        "memory_s": t.memory_s,
        "collective_s": t.collective_s,
        "dominant": t.dominant,
        "modeled_time_s": t.modeled_time_s,
        "useful_flops_ratio": t.useful_flops_ratio,
        "mfu_vs_peak": t.mfu_vs_peak,
    }
