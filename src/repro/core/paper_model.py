"""Paper-faithful instruction roofline formulas (Leinhauser et al. 2021).

Implements Equations 1-4 exactly as published, for both the AMD (wavefront)
and NVIDIA (warp) variants, and the IRM point construction used for the
paper's Tables 1-2 and Figures 4-7.  These are validated against the paper's
published numbers in tests/test_paper_model.py.

Equation index
  Eq. 1:  instructions = SQ_INSTS_VALU * 4 + SQ_INSTS_SALU
  Eq. 2:  instruction intensity *performance* =
              (instructions / lanes) / ((bytes_read + bytes_written) * runtime)
          NOTE: the published Eq. 2 includes the multiplication by runtime;
          we reproduce it verbatim (it is what Tables 1-2 actually contain)
          and separately provide the runtime-free `instruction_intensity`
          (instructions / byte) used for plotting points on an IRM.
  Eq. 3:  GIPS_peak = CU * WFS_per_CU * IPC * frequency_GHz
  Eq. 4:  GIPS_achieved = (instructions / lanes) / (1e9 * runtime)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.hardware import HardwareSpec

AMD_SIMDS_PER_CU = 4       # Fig. 1 / GCN whitepaper: 4 SIMD vector units / CU
AMD_WAVEFRONT = 64
NVIDIA_WARP = 32


def amd_instructions(sq_insts_valu: float, sq_insts_salu: float,
                     simds_per_cu: int = AMD_SIMDS_PER_CU) -> float:
    """Eq. 1.  SQ_INSTS_VALU is reported per SIMD; there are 4 SIMD vector
    units per compute unit and a single scalar unit."""
    return sq_insts_valu * simds_per_cu + sq_insts_salu


def peak_gips(hw: HardwareSpec) -> float:
    """Eq. 3."""
    return hw.peak_gips()


def achieved_gips(instructions: float, runtime_s: float,
                  lanes_per_issue: int) -> float:
    """Eq. 4: instructions normalized to the native execution granularity
    (wavefront=64 / warp=32), in billions per second."""
    if runtime_s <= 0:
        raise ValueError("runtime must be positive")
    return (instructions / lanes_per_issue) / (1e9 * runtime_s)


def instruction_intensity_performance(instructions: float,
                                      bytes_read: float,
                                      bytes_written: float,
                                      runtime_s: float,
                                      lanes_per_issue: int) -> float:
    """Eq. 2 verbatim (includes the x runtime factor; see module docstring)."""
    denom = (bytes_read + bytes_written) * runtime_s
    if denom <= 0:
        raise ValueError("bytes and runtime must be positive")
    return (instructions / lanes_per_issue) / denom


def instruction_intensity(instructions: float, bytes_read: float,
                          bytes_written: float,
                          lanes_per_issue: int) -> float:
    """Runtime-free intensity in (scaled) instructions per byte — the x-axis
    of the paper's instruction roofline plots in instructions/byte units."""
    total = bytes_read + bytes_written
    if total <= 0:
        raise ValueError("bytes must be positive")
    return (instructions / lanes_per_issue) / total


def instruction_intensity_per_transaction(instructions: float,
                                          transactions: float,
                                          lanes_per_issue: int) -> float:
    """Ding & Williams' original x-axis (instructions / transaction), usable
    only where the profiler reports transactions (NVIDIA).  One transaction
    is 32 bytes."""
    if transactions <= 0:
        raise ValueError("transactions must be positive")
    return (instructions / lanes_per_issue) / transactions


@dataclasses.dataclass(frozen=True)
class KernelMeasurement:
    """One profiled kernel instance — the rocProf/nvprof record the paper's
    tables are built from."""

    name: str
    hw: HardwareSpec
    runtime_s: float
    instructions: float              # already Eq.1-scaled (or inst_executed)
    bytes_read: float
    bytes_written: float
    transactions: Optional[float] = None   # NVIDIA-only

    @property
    def scaled_instructions(self) -> float:
        return self.instructions / self.hw.lanes_per_issue

    def achieved_gips(self) -> float:
        return achieved_gips(self.instructions, self.runtime_s,
                             self.hw.lanes_per_issue)

    def intensity(self) -> float:
        return instruction_intensity(self.instructions, self.bytes_read,
                                     self.bytes_written,
                                     self.hw.lanes_per_issue)

    def intensity_performance(self) -> float:
        return instruction_intensity_performance(
            self.instructions, self.bytes_read, self.bytes_written,
            self.runtime_s, self.hw.lanes_per_issue)

    def peak_gips(self) -> float:
        return self.hw.peak_gips()

    def irm_point(self) -> tuple:
        """(x, y) for the instruction roofline plot: instructions/byte vs
        achieved GIPS."""
        return (self.intensity(), self.achieved_gips())

    def memory_bound_gips(self) -> float:
        """GIPS ceiling imposed by the memory roof at this point's intensity:
        intensity [inst/byte] x bandwidth [GB/s] = GIPS."""
        return self.intensity() * self.hw.memory_ceiling_gbs()

    def bound(self) -> str:
        """Which roof caps this kernel (the paper's bottleneck readout)."""
        return ("memory" if self.memory_bound_gips() < self.peak_gips()
                else "compute")
