"""hlo_counters — a "rocProf for XLA": instruction & traffic census over
post-optimization, post-SPMD-partitioning HLO text.

The paper's central move is extracting an instruction roofline from the small
set of counters a constrained profiler exposes (FETCH_SIZE / WRITE_SIZE /
SQ_INSTS_VALU / SQ_INSTS_SALU).  XLA's AOT interface is constrained in an
analogous way: ``compiled.cost_analysis()`` gives total flops / bytes (and
counts ``while`` bodies ONCE, ignoring trip counts), and nothing reports
per-unit instruction mixes or collective traffic.  This module recovers them
by parsing ``compiled.as_text()``:

  * per-opcode / per-class instruction census (MXU, VPU, scalar, layout,
    irregular-memory, collective, flow) — the SQ_INSTS_{VALU,SALU} analogue;
  * trip-count-aware scaling of ``while`` bodies (reads
    ``backend_config={"known_trip_count":{"n":...}}``), so scan-over-layers
    models are costed correctly;
  * MXU *issue* estimation per dot: ceil-div tiling over (M, N, K) by the
    128x128x128 systolic pass — this exposes padding / alignment waste the
    FLOP roofline hides (the TPU analogue of the paper's transaction-level
    strided-access insight);
  * VPU issue estimation with (8,128)-vreg padding;
  * HBM traffic model at fusion boundaries, slice-aware (a fusion parameter
    consumed only by (dynamic-)slice ops contributes the slice bytes, not the
    full buffer — critical for stacked scan weights);
  * collective census: operand bytes and ring-model wire bytes per kind,
    with replica-group sizes parsed from the op attributes.

Everything here is plain-text parsing on one device's SPMD module, i.e. all
quantities are **per device** unless noted.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import Counter
from functools import lru_cache
from typing import Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------

DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e4m3": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 0.5,
    "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "tuple": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")


@dataclasses.dataclass(frozen=True)
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elements(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> float:
        return self.elements * DTYPE_BYTES.get(self.dtype, 4)

    def padded_vreg_issues(self, sublane: int = 8, lane: int = 128) -> int:
        """Number of (sublane x lane) vector-register issues needed to touch
        every element, including layout padding of the two minor dims."""
        if not self.dims:
            return 1
        if len(self.dims) == 1:
            return max(1, math.ceil(self.dims[0] / lane))
        lead = 1
        for d in self.dims[:-2]:
            lead *= d
        return max(1, lead * math.ceil(self.dims[-2] / sublane)
                   * math.ceil(self.dims[-1] / lane))


@lru_cache(maxsize=65536)
def _parse_shapes_cached(text: str) -> Tuple[Shape, ...]:
    """Type strings repeat heavily across a module (every scan iteration,
    every fusion parameter re-states the same tuple type) — cache the parse
    instead of re-running the regex + int conversion per use."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype in ("token", "opaque"):
            out.append(Shape(dtype, ()))
            continue
        if dtype not in DTYPE_BYTES:
            continue
        dims_t = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append(Shape(dtype, dims_t))
    return tuple(out)


def parse_shapes(text: str) -> List[Shape]:
    """All shapes appearing in a type string (handles tuples)."""
    return list(_parse_shapes_cached(text))


def shapes_bytes(shapes: Sequence[Shape]) -> float:
    return float(sum(s.bytes for s in shapes))


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    shapes: Tuple[Shape, ...]          # result shape(s); tuples flattened
    operands: Tuple[str, ...]          # operand instruction names
    attrs: str                         # raw attribute tail
    args_raw: str = ""                 # raw text inside the operand parens
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instructions: Dict[str, Instruction]
    order: List[Instruction]
    _users: Optional[Dict[str, List[Instruction]]] = \
        dataclasses.field(default=None, repr=False)
    _params: Optional[Dict[str, Instruction]] = \
        dataclasses.field(default=None, repr=False)

    @property
    def root(self) -> Optional[Instruction]:
        for inst in self.order:
            if inst.is_root:
                return inst
        return self.order[-1] if self.order else None

    def users_of(self, name: str) -> List[Instruction]:
        """Downstream users, via a lazily-built one-pass index (the naive
        per-query scan is O(insts) and the fusion byte accounting queries
        it per parameter)."""
        if self._users is None:
            users: Dict[str, List[Instruction]] = {}
            for inst in self.order:
                for op in inst.operands:
                    users.setdefault(op, []).append(inst)
            self._users = users
        return self._users.get(name, [])

    def param_named(self, index: int) -> Optional[Instruction]:
        if self._params is None:
            self._params = {i.args_raw.strip(): i for i in self.order
                            if i.opcode == "parameter"}
        return self._params.get(str(index))


_COMP_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-~]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INST_RE = re.compile(r"^\s*(ROOT\s+)?%([\w.\-~]+)\s*=\s*(.+)$")
_OPCODE_RE = re.compile(r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-~]+)")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"?(\d+)"?')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w.\-~]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-~]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-~]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-~]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")


_TYPE_TOKEN_RE = re.compile(r"[a-z]\w*(\[[^\]]*\])?(\{[^}]*\})?")


def _split_type(rest: str) -> Tuple[str, str]:
    """Split 'TYPE opcode(...)' into (type_str, remainder)."""
    if rest.startswith("("):
        depth = 0
        for i, c in enumerate(rest):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    return rest[: i + 1], rest[i + 1:].lstrip()
        return rest, ""
    m = _TYPE_TOKEN_RE.match(rest)
    if not m:
        return "", rest
    return m.group(0), rest[m.end():].lstrip()


def _match_paren(text: str) -> Tuple[str, str]:
    """text starts at '('; return (inside, after)."""
    depth = 0
    for i, c in enumerate(text):
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return text[1:i], text[i + 1:]
    return text[1:], ""


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    """Parse HLO module text -> ({computation name: Computation}, entry)."""
    comps: Dict[str, Computation] = {}
    entry_name = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEADER_RE.match(stripped)
            if m and ("(" in stripped):
                cur = Computation(m.group(2), {}, [])
                if m.group(1):
                    entry_name = m.group(2)
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST_RE.match(line)
        if not m:
            continue
        is_root, name, rest = bool(m.group(1)), m.group(2), m.group(3)
        type_str, remainder = _split_type(rest)
        om = _OPCODE_RE.match(remainder)
        if not om:
            continue
        opcode = om.group(1)
        args, after = _match_paren(remainder[om.end() - 1:])
        operands = tuple(_OPERAND_RE.findall(args))
        inst = Instruction(
            name=name, opcode=opcode,
            shapes=_parse_shapes_cached(type_str),
            operands=operands, attrs=after, args_raw=args,
            is_root=is_root)
        cur.instructions[name] = inst
        cur.order.append(inst)
    if cur is not None:                      # unterminated (defensive)
        comps[cur.name] = cur
    return comps, entry_name


# ---------------------------------------------------------------------------
# opcode classification
# ---------------------------------------------------------------------------

MXU_OPS = {"dot", "convolution", "ragged-dot"}

COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
}

LAYOUT_OPS = {
    "copy", "transpose", "reshape", "pad", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "reverse", "copy-start",
    "copy-done",
}

IRREGULAR_OPS = {"gather", "scatter", "sort", "select-and-scatter"}

REDUCE_OPS = {"reduce", "reduce-window"}

FLOW_OPS = {"while", "conditional", "call", "fusion", "custom-call",
            "after-all", "async-start", "async-done", "async-update",
            "optimization-barrier", "infeed", "outfeed", "send", "recv",
            "send-done", "recv-done", "domain", "partition-id", "replica-id",
            "rng-get-and-update-state"}

NO_WORK_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "bitcast-convert"}

# everything else (add/multiply/exp/convert/select/compare/broadcast/iota/...)
# is treated as a VPU elementwise op.


def classify(opcode: str) -> str:
    base = opcode[:-6] if opcode.endswith("-start") else (
        opcode[:-5] if opcode.endswith("-done") else opcode)
    if base in MXU_OPS:
        return "mxu"
    if base in COLLECTIVE_OPS:
        return "collective"
    if base in LAYOUT_OPS:
        return "layout"
    if base in IRREGULAR_OPS:
        return "irregular"
    if base in REDUCE_OPS:
        return "reduce"
    if base in FLOW_OPS or opcode in FLOW_OPS:
        return "flow"
    if base in NO_WORK_OPS:
        return "none"
    return "vpu"


# ---------------------------------------------------------------------------
# census
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CollectiveStat:
    kind: str
    count: float = 0.0
    operand_bytes: float = 0.0       # payload size (result for all-gather)
    wire_bytes: float = 0.0          # ring-model bytes on the wire per device


@dataclasses.dataclass
class Census:
    """Per-device instruction/traffic census (all fields trip-count scaled)."""

    flops: float = 0.0               # mxu_flops + vpu_flops
    mxu_flops: float = 0.0
    vpu_flops: float = 0.0           # 1 flop per elementwise output element
    hbm_bytes: float = 0.0           # fusion-boundary traffic model
    layout_bytes: float = 0.0        # subset of hbm_bytes moved by layout ops
    irregular_bytes: float = 0.0     # gather/scatter traffic
    mxu_issues: float = 0.0          # 128^3 systolic passes (ceil-tiled)
    mxu_flops_padded: float = 0.0    # issues x flops-per-issue
    vpu_issues: float = 0.0          # (8,128) vreg issues (ceil-tiled)
    scalar_ops: float = 0.0          # scalar-result + flow ops (SALU analogue)
    opcode_counts: Counter = dataclasses.field(default_factory=Counter)
    class_counts: Counter = dataclasses.field(default_factory=Counter)
    collectives: Dict[str, CollectiveStat] = dataclasses.field(
        default_factory=dict)

    @property
    def collective_wire_bytes(self) -> float:
        return sum(c.wire_bytes for c in self.collectives.values())

    @property
    def collective_operand_bytes(self) -> float:
        return sum(c.operand_bytes for c in self.collectives.values())

    @property
    def total_instructions(self) -> float:
        """Eq. 1 analogue: issue-scaled vector instructions + scalar ones."""
        return self.mxu_issues + self.vpu_issues + self.scalar_ops

    def merge_scaled(self, other: "Census", mult: float) -> None:
        self.flops += other.flops * mult
        self.mxu_flops += other.mxu_flops * mult
        self.vpu_flops += other.vpu_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.layout_bytes += other.layout_bytes * mult
        self.irregular_bytes += other.irregular_bytes * mult
        self.mxu_issues += other.mxu_issues * mult
        self.mxu_flops_padded += other.mxu_flops_padded * mult
        self.vpu_issues += other.vpu_issues * mult
        self.scalar_ops += other.scalar_ops * mult
        for k, v in other.opcode_counts.items():
            self.opcode_counts[k] += v * mult
        for k, v in other.class_counts.items():
            self.class_counts[k] += v * mult
        for kind, stat in other.collectives.items():
            dst = self.collectives.setdefault(kind, CollectiveStat(kind))
            dst.count += stat.count * mult
            dst.operand_bytes += stat.operand_bytes * mult
            dst.wire_bytes += stat.wire_bytes * mult


def _group_size(attrs: str, num_partitions: int) -> int:
    m = _GROUPS_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        groups = [g for g in m.group(1).split("},{") if g.strip()]
        if groups:
            first = groups[0].strip("{}")
            ids = [x for x in first.split(",") if x.strip()]
            return max(1, len(ids))
    return max(1, num_partitions)


def _dot_census(inst: Instruction, comp: Computation) -> Tuple[float, float]:
    """Returns (flops, mxu_issues) for a dot instruction."""
    result = inst.shapes[0]
    lhs_shape: Optional[Shape] = None
    if inst.operands:
        op0 = comp.instructions.get(inst.operands[0])
        if op0 is not None and op0.shapes:
            lhs_shape = op0.shapes[0]
    cm = _CONTRACT_RE.search(inst.attrs)
    contract = 1
    if cm and lhs_shape is not None:
        for idx in (int(i) for i in cm.group(1).split(",") if i):
            if idx < len(lhs_shape.dims):
                contract *= lhs_shape.dims[idx]
    bm = _LHS_BATCH_RE.search(inst.attrs)
    n_batch = len([i for i in bm.group(1).split(",") if i]) if bm else 0
    flops = 2.0 * result.elements * contract
    # tile census: result = (batch..., M..., N) — treat minor dim as N, the
    # rest of the non-batch dims as M.
    dims = result.dims
    batch = 1
    for d in dims[:n_batch]:
        batch *= d
    rest = dims[n_batch:]
    if rest:
        n_dim = rest[-1]
        m_dim = 1
        for d in rest[:-1]:
            m_dim *= d
    else:
        n_dim, m_dim = 1, 1
    tiles = (batch * math.ceil(max(1, m_dim) / 128)
             * math.ceil(max(1, n_dim) / 128) * math.ceil(contract / 128))
    return flops, float(tiles)


def _conv_census(inst: Instruction, comp: Computation) -> Tuple[float, float]:
    """Rough convolution cost: 2 * output_elems * (kernel spatial * in-ch)."""
    result = inst.shapes[0]
    kernel: Optional[Shape] = None
    if len(inst.operands) > 1:
        op1 = comp.instructions.get(inst.operands[1])
        if op1 is not None and op1.shapes:
            kernel = op1.shapes[0]
    k_elems = kernel.elements if kernel is not None else 1
    out_ch = result.dims[-1] if result.dims else 1
    per_out = k_elems / max(1, out_ch)
    flops = 2.0 * result.elements * per_out
    issues = flops / (2.0 * 128 ** 3)
    return flops, max(1.0, math.ceil(issues))


_SLICE_LIKE = {"slice", "dynamic-slice"}
# single-operand ops that preserve the access pattern; fused interiors of
# these are register-resident, so byte accounting sees through them
_TRANSPARENT = {"convert", "bitcast", "copy", "reshape", "negate"}


def _through_users(fcomp: Computation, name: str):
    """BFS downstream through transparent ops (which may fan out to several
    users, e.g. ``convert -> {dynamic-slice, dynamic-update-slice}`` in the
    decode-cache pattern); returns the non-transparent terminal users."""
    out = []
    frontier = list(fcomp.users_of(name))
    seen = set()
    while frontier:
        u = frontier.pop()
        if u.name in seen:
            continue
        seen.add(u.name)
        if u.opcode in _TRANSPARENT:
            nxt = fcomp.users_of(u.name)
            if not nxt:
                out.append((u, u))
            else:
                frontier.extend(nxt)
        else:
            out.append((u, u))
    return out


def _through_operand(fcomp: Computation, inst: Instruction,
                     idx: int) -> Optional[Instruction]:
    """Follow operand `idx` upstream through transparent ops."""
    if idx >= len(inst.operands):
        return None
    cur = fcomp.instructions.get(inst.operands[idx])
    while cur is not None and cur.opcode in _TRANSPARENT and cur.operands:
        cur = fcomp.instructions.get(cur.operands[0])
    return cur


def _fusion_param_read_bytes(fcomp: Computation, param_index: int,
                             full: Shape) -> float:
    """Slice-aware read size of one fusion parameter (sees through
    convert/bitcast/copy chains)."""
    pinst = fcomp.param_named(param_index)
    pname = pinst.name if pinst is not None else None
    if pname is None:
        return full.bytes
    finals = _through_users(fcomp, pname)
    if not finals:
        return 0.0
    # every use is either a slice read or an in-place dynamic-update-slice
    # whose destination chain starts at this param (XLA fuses these in
    # place on TPU: only the slice regions are touched, the rest aliases) —
    # e.g. the decode-cache pattern  kc = slice(K, l); K' = dus(K, kc', l)
    total = 0.0
    for _, f in finals:
        if f.opcode in _SLICE_LIKE:
            total += f.shapes[0].bytes
            continue
        if f.opcode == "gather":
            src = _through_operand(fcomp, f, 0)
            if src is not None and src.name == pname:
                # table operand of a gather: only the gathered rows are
                # read (paged-KV pools — see the irregular-class model)
                total += f.shapes[0].bytes
                continue
        if f.opcode == "scatter":
            dest = _through_operand(fcomp, f, 0)
            if dest is not None and dest.name == pname and \
                    len(f.operands) >= 3:
                upd = _through_operand(fcomp, f, 2)
                if upd is not None and upd.shapes:
                    total += upd.shapes[0].bytes
                    continue
        if f.opcode == "dynamic-update-slice":
            dest = _through_operand(fcomp, f, 0)
            if dest is not None and dest.name == pname:
                upd = _through_operand(fcomp, f, 1)
                if upd is not None and upd.shapes:
                    total += upd.shapes[0].bytes
                    continue
        return full.bytes                       # some other use: full read
    return float(total)


def _fusion_write_bytes(fcomp: Computation) -> float:
    """Slice-aware write size of a fusion root (sees through transparent
    chains: a root convert(dus(...)) writes only the updated slice when XLA
    fuses it in place)."""
    root = fcomp.root
    if root is None:
        return 0.0
    roots = [root]
    if root.opcode == "tuple":
        roots = [fcomp.instructions[o] for o in root.operands
                 if o in fcomp.instructions]
    total = 0.0
    for r in roots:
        cur = r
        while cur.opcode in _TRANSPARENT and cur.operands:
            nxt = fcomp.instructions.get(cur.operands[0])
            if nxt is None:
                break
            cur = nxt
        if cur.opcode == "dynamic-update-slice" and len(cur.operands) >= 2:
            upd = _through_operand(fcomp, cur, 1)
            if upd is not None and upd.shapes:
                total += upd.shapes[0].bytes
                continue
        if cur.opcode == "scatter" and len(cur.operands) >= 3:
            # in-place row scatter: only the update rows are written
            upd = _through_operand(fcomp, cur, 2)
            if upd is not None and upd.shapes:
                total += upd.shapes[0].bytes
                continue
        total += shapes_bytes(r.shapes)
    return total


def _is_convert_like(inst: Instruction, comps: Dict[str, Computation]) -> bool:
    """A ``convert``, or a ``call`` whose callee is nothing but one root
    convert of its parameter (the CPU backend's sharded "parallel_convert"
    wrapper around large buffers)."""
    if inst.opcode == "convert":
        return True
    if inst.opcode == "call":
        m = _TO_APPLY_RE.search(inst.attrs)
        callee = comps.get(m.group(1)) if m else None
        if callee is not None:
            body = [i for i in callee.order if i.opcode != "parameter"]
            return len(body) == 1 and body[0].opcode == "convert"
    return False


def _dtype_bracket_elisions(comp: Computation,
                            comps: Dict[str, Computation]) -> set:
    """Names of standalone ``convert`` pairs (or single-convert ``call``
    wrappers) that only BRACKET a donated in-place update in a wider
    compute dtype: an upcast straight off a parameter / loop state matched
    with a downcast of the SAME shape back to the SAME dtype feeding the
    root.  Backends without native narrow-dtype scatter (CPU) materialize
    these as whole-buffer converts around the update — e.g. the paged-KV
    COW page copy on a bf16 pool compiles to
    ``convert(pool) -> scatter -> convert`` and the brackets alone would
    charge 3x the POOL per copy, erasing the page-wise accounting the
    paged cache exists to create.  XLA:TPU updates the storage dtype in
    place (or fuses the converts), so the census elides matched bracket
    pairs; a genuine one-way cast (weight upcast, output quantization) has
    no same-shape partner and is still counted."""
    root = comp.root
    root_feeds = set()
    if root is not None:
        root_feeds.add(root.name)
        if root.opcode == "tuple":
            root_feeds.update(root.operands)
    ups: List[Instruction] = []
    # (src_shape, res_shape, name-to-elide or None): the downcast may be a
    # standalone convert (elide it too) or live INSIDE a root-feeding
    # fusion as its interior root (the fusion stays counted — only the
    # orphaned standalone upcast is the artifact then)
    downs: List[Tuple[Shape, Shape, Optional[str]]] = []
    for inst in comp.order:
        if not inst.operands or not inst.shapes:
            continue
        if _is_convert_like(inst, comps):
            src = comp.instructions.get(inst.operands[0])
            if src is None or not src.shapes:
                continue
            if src.opcode in ("parameter", "get-tuple-element"):
                ups.append(inst)
            if inst.name in root_feeds:
                downs.append((src.shapes[0], inst.shapes[0], inst.name))
        elif inst.opcode in ("fusion", "call") and inst.name in root_feeds:
            # follow nested fusion/call roots to a final interior convert
            # (the CPU backend nests its sharded wrapper around the update
            # fusion): the fusion stays counted — only the orphaned
            # standalone upcast is the artifact
            cur = inst
            for _ in range(3):
                cm = (_CALLS_RE.search(cur.attrs) if cur.opcode == "fusion"
                      else _TO_APPLY_RE.search(cur.attrs)
                      if cur.opcode == "call" else None)
                fcomp = comps.get(cm.group(1)) if cm else None
                froot = fcomp.root if fcomp is not None else None
                if froot is None:
                    break
                if froot.opcode == "convert" and froot.operands \
                        and froot.shapes:
                    fsrc = fcomp.instructions.get(froot.operands[0])
                    if fsrc is not None and fsrc.shapes:
                        downs.append((fsrc.shapes[0], froot.shapes[0],
                                      None))
                    break
                cur = froot
    elide: set = set()
    used_downs: set = set()
    for u in ups:
        u_src = comp.instructions[u.operands[0]].shapes[0]
        u_res = u.shapes[0]
        if DTYPE_BYTES.get(u_res.dtype, 0) <= DTYPE_BYTES.get(u_src.dtype, 0):
            continue                                   # not an upcast
        for di, (d_src, d_res, d_name) in enumerate(downs):
            if di in used_downs or d_name == u.name:
                continue
            if (d_res.dtype == u_src.dtype and d_res.dims == u_src.dims
                    and d_src.dtype == u_res.dtype
                    and d_src.dims == u_res.dims):
                elide.add(u.name)
                if d_name is not None:
                    elide.add(d_name)
                used_downs.add(di)
                break
    return elide


class ModuleCensus:
    """Walks the computation graph of a parsed module, scaling by while trip
    counts, producing a Census."""

    def __init__(self, comps: Dict[str, Computation], entry: str,
                 num_partitions: int = 1, default_trip: int = 1):
        self.comps = comps
        self.entry = entry
        self.num_partitions = num_partitions
        self.default_trip = default_trip
        self._cache: Dict[Tuple[str, bool], Census] = {}

    def run(self) -> Census:
        return self._census(self.entry, count_bytes=True)

    # -- internals ----------------------------------------------------------

    def _census(self, comp_name: str, count_bytes: bool) -> Census:
        key = (comp_name, count_bytes)
        if key in self._cache:
            return self._cache[key]
        comp = self.comps.get(comp_name)
        out = Census()
        if comp is None:
            self._cache[key] = out
            return out
        elide = _dtype_bracket_elisions(comp, self.comps)
        for inst in comp.order:
            if inst.name in elide:
                continue                   # backend dtype-bracket artifact
            self._one(inst, comp, out, count_bytes)
        self._cache[key] = out
        return out

    def _operand_shapes(self, inst: Instruction,
                        comp: Computation) -> List[Shape]:
        out: List[Shape] = []
        for name in inst.operands:
            op = comp.instructions.get(name)
            if op is not None:
                out.extend(op.shapes)
        return out

    def _one(self, inst: Instruction, comp: Computation, out: Census,
             count_bytes: bool) -> None:
        op = inst.opcode
        cls = classify(op)
        if op.endswith("-done") or op in ("async-update",):
            return                                  # counted at -start
        if cls == "none":
            return
        out.opcode_counts[op] += 1
        out.class_counts[cls] += 1
        res_bytes = shapes_bytes(inst.shapes)
        opnd_shapes = self._operand_shapes(inst, comp)
        opnd_bytes = shapes_bytes(opnd_shapes)

        if op == "while":
            trip = self.default_trip
            m = _TRIP_RE.search(inst.attrs)
            if m:
                trip = int(m.group(1))
            bm = _BODY_RE.search(inst.attrs)
            cm = _COND_RE.search(inst.attrs)
            if bm:
                out.merge_scaled(self._census(bm.group(1), count_bytes), trip)
            if cm:
                out.merge_scaled(self._census(cm.group(1), count_bytes),
                                 trip + 1)
            out.scalar_ops += 1
            return

        if op == "conditional":
            bm = _BRANCHES_RE.search(inst.attrs)
            names = []
            if bm:
                names = [n.strip().lstrip("%") for n in bm.group(1).split(",")]
            else:
                tm = _TO_APPLY_RE.search(inst.attrs)
                if tm:
                    names = [tm.group(1)]
            for n in names:                          # upper bound: all branches
                out.merge_scaled(self._census(n, count_bytes), 1.0)
            out.scalar_ops += 1
            return

        if op == "call":
            tm = _TO_APPLY_RE.search(inst.attrs)
            if tm:
                out.merge_scaled(self._census(tm.group(1), count_bytes), 1.0)
            out.scalar_ops += 1
            return

        if op == "fusion":
            cm2 = _CALLS_RE.search(inst.attrs)
            if cm2:
                fname = cm2.group(1)
                # interior census for instruction/flop counts (no bytes —
                # fused intermediates stay on-chip)
                out.merge_scaled(self._census(fname, count_bytes=False), 1.0)
                if count_bytes:
                    fcomp = self.comps.get(fname)
                    if fcomp is not None:
                        reads = 0.0
                        for i, sh in enumerate(opnd_shapes):
                            reads += _fusion_param_read_bytes(fcomp, i, sh)
                        out.hbm_bytes += reads + _fusion_write_bytes(fcomp)
                    else:
                        out.hbm_bytes += opnd_bytes + res_bytes
            return

        base = op[:-6] if op.endswith("-start") else op

        if cls == "collective":
            g = _group_size(inst.attrs, self.num_partitions)
            stat = out.collectives.setdefault(base, CollectiveStat(base))
            stat.count += 1
            if base == "all-gather":
                payload = res_bytes
                wire = res_bytes * (g - 1) / g
            elif base == "all-reduce":
                payload = res_bytes
                wire = 2.0 * res_bytes * (g - 1) / g
            elif base == "reduce-scatter":
                payload = opnd_bytes
                wire = opnd_bytes * (g - 1) / g
            elif base in ("all-to-all", "ragged-all-to-all"):
                payload = opnd_bytes
                wire = opnd_bytes * (g - 1) / g
            elif base == "collective-broadcast":
                payload = res_bytes
                wire = res_bytes
            else:                                    # collective-permute
                payload = res_bytes
                wire = res_bytes
            stat.operand_bytes += payload
            stat.wire_bytes += wire
            if count_bytes:
                out.hbm_bytes += opnd_bytes + res_bytes
            return

        if cls == "mxu":
            if base == "dot" or base == "ragged-dot":
                flops, issues = _dot_census(inst, comp)
            else:
                flops, issues = _conv_census(inst, comp)
            out.mxu_flops += flops
            out.flops += flops
            out.mxu_issues += issues
            out.mxu_flops_padded += issues * 2.0 * 128 ** 3
            if count_bytes:
                out.hbm_bytes += opnd_bytes + res_bytes
            return

        # --- scalar / flow ---------------------------------------------------
        is_scalar = all(len(s.dims) == 0 for s in inst.shapes)
        if cls == "flow" or is_scalar:
            out.scalar_ops += 1
            if count_bytes and cls != "flow":
                out.hbm_bytes += opnd_bytes + res_bytes
            if count_bytes and op == "custom-call":
                out.hbm_bytes += opnd_bytes + res_bytes
            return

        # --- layout / irregular / reduce / vpu -------------------------------
        if cls == "layout":
            if op == "copy" and inst.operands:
                # loop-carry pass-through copies (copy of a parameter /
                # get-tuple-element of the loop state) and ROOT copies that
                # move a FUSION/CALL result into the donated output buffer
                # are aliasing artifacts — XLA:TPU elides both via buffer
                # donation (the producer writes the aliased buffer
                # directly).  Shape equality cannot distinguish a genuine
                # layout-converting root copy (Shape drops layouts), so
                # the root case is gated on the producer opcode; a
                # layout-change root copy of a fusion is still elided —
                # acceptable for a TPU traffic model where the relayout
                # folds into the producer.
                src = comp.instructions.get(inst.operands[0])
                if src is not None and (
                        src.opcode in ("parameter", "get-tuple-element")
                        or (inst.is_root
                            and src.opcode in ("fusion", "call"))):
                    out.opcode_counts[op] -= 1
                    out.class_counts[cls] -= 1
                    return
            if base in _SLICE_LIKE:
                moved = 2.0 * res_bytes
            elif base == "dynamic-update-slice":
                upd = (opnd_shapes[1].bytes if len(opnd_shapes) > 1
                       else res_bytes)
                moved = 2.0 * upd
            elif base == "pad":
                moved = opnd_bytes + res_bytes
            else:
                moved = opnd_bytes + res_bytes
            out.layout_bytes += moved
            if count_bytes:
                out.hbm_bytes += moved
            # layout movement still costs vreg issues
            out.vpu_issues += inst.shapes[0].padded_vreg_issues()
            return

        if cls == "irregular":
            if base == "gather" and opnd_shapes:
                # the memory system touches the gathered rows (read) + the
                # result (write) + the index stream — NOT the whole table
                # operand (the paged-KV block-table gather reads live pages
                # only; counting the full pool would erase exactly the
                # transaction scaling the paged cache exists to create)
                idx = opnd_shapes[1].bytes if len(opnd_shapes) > 1 else 0.0
                moved = 2.0 * res_bytes + idx
            elif base == "scatter" and len(opnd_shapes) >= 3:
                # in-place row update: read+write the update rows + the
                # index stream; the untouched operand aliases (same
                # convention as dynamic-update-slice above)
                moved = 2.0 * opnd_shapes[2].bytes + opnd_shapes[1].bytes
            else:
                moved = opnd_bytes + res_bytes
            out.irregular_bytes += moved
            if count_bytes:
                out.hbm_bytes += moved
            out.vpu_issues += inst.shapes[0].padded_vreg_issues()
            out.vpu_flops += inst.shapes[0].elements
            out.flops += inst.shapes[0].elements
            return

        if cls == "reduce":
            in_elems = sum(s.elements for s in opnd_shapes[:1]) or 1
            in_issues = (opnd_shapes[0].padded_vreg_issues()
                         if opnd_shapes else 1)
            out.vpu_flops += in_elems
            out.flops += in_elems
            out.vpu_issues += in_issues
            if count_bytes:
                out.hbm_bytes += opnd_bytes + res_bytes
            return

        # vpu elementwise
        elems = sum(s.elements for s in inst.shapes)
        out.vpu_flops += elems
        out.flops += elems
        out.vpu_issues += sum(s.padded_vreg_issues() for s in inst.shapes)
        if count_bytes:
            if base == "broadcast" or base == "iota":
                out.hbm_bytes += res_bytes
            else:
                out.hbm_bytes += opnd_bytes + res_bytes


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------

_NUM_PARTITIONS_RE = re.compile(r"num_partitions=(\d+)")


def census_from_text(hlo_text: str) -> Census:
    comps, entry = parse_module(hlo_text)
    if not entry:
        # fall back: the largest computation
        entry = max(comps, key=lambda n: len(comps[n].order)) if comps else ""
    m = _NUM_PARTITIONS_RE.search(hlo_text[:2000])
    nparts = int(m.group(1)) if m else 1
    return ModuleCensus(comps, entry, num_partitions=nparts).run()


def census_from_compiled(compiled) -> Census:
    return census_from_text(compiled.as_text())
