"""Log-log instruction roofline plots (paper Figs 4-7 style).

Matplotlib is optional at import time so headless test environments without
it still import `repro.core`.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.core.irm import InstructionRooflineModel


def plot_irm(model: InstructionRooflineModel, path: str,
             x_range: Optional[tuple] = None) -> str:
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    xs_pts = [p.intensity for p in model.points if p.intensity > 0]
    if x_range is None:
        lo = min(xs_pts) / 10 if xs_pts else 1e-3
        hi = max(xs_pts) * 10 if xs_pts else 1e2
        knee = model.knee()
        lo = min(lo, knee / 10)
        hi = max(hi, knee * 10)
        x_range = (lo, hi)

    fig, ax = plt.subplots(figsize=(7, 5))
    n = 200
    xs = [x_range[0] * (x_range[1] / x_range[0]) ** (i / (n - 1))
          for i in range(n)]
    for c in model.ceilings:
        ys = [c.y_at(x) for x in xs]
        ax.plot(xs, ys, lw=1.6, label=c.label)
    markers = {"HBM": "o", "MXU": "s", "VPU": "^", "L1": "v", "L2": "d"}
    for p in model.points:
        if p.intensity <= 0 or p.gips <= 0:
            continue
        ax.plot([p.intensity], [p.gips],
                markers.get(p.series, "o"), ms=8, label=p.label)
    ax.set_xscale("log")
    ax.set_yscale("log")
    ax.set_xlabel("Instruction intensity (instructions / byte)")
    ax.set_ylabel("Performance (GIPS)")
    ax.set_title(model.title)
    ax.grid(True, which="both", alpha=0.3)
    ax.legend(fontsize=7, loc="lower right")
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return path
