"""Markdown / CSV emitters for roofline + IRM results."""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Sequence

from repro.core.hlo_counters import Census
from repro.core.roofline import RooflineTerms


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e5 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)


def markdown_table(rows: Sequence[Dict[str, object]],
                   columns: Sequence[str] = ()) -> str:
    if not rows:
        return "(no rows)"
    cols = list(columns) if columns else list(rows[0].keys())
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        lines.append("| " + " | ".join(_fmt(r.get(c, "")) for c in cols) + " |")
    return "\n".join(lines)


def csv_lines(rows: Sequence[Dict[str, object]],
              columns: Sequence[str] = ()) -> List[str]:
    if not rows:
        return []
    cols = list(columns) if columns else list(rows[0].keys())
    out = [",".join(cols)]
    for r in rows:
        out.append(",".join(_fmt(r.get(c, "")) for c in cols))
    return out


def census_summary(c: Census) -> Dict[str, object]:
    return {
        "flops": c.flops,
        "mxu_flops": c.mxu_flops,
        "vpu_flops": c.vpu_flops,
        "hbm_bytes": c.hbm_bytes,
        "layout_bytes": c.layout_bytes,
        "irregular_bytes": c.irregular_bytes,
        "mxu_issues": c.mxu_issues,
        "vpu_issues": c.vpu_issues,
        "scalar_ops": c.scalar_ops,
        "collective_wire_bytes": c.collective_wire_bytes,
        "collectives": {k: {"count": v.count,
                            "operand_bytes": v.operand_bytes,
                            "wire_bytes": v.wire_bytes}
                        for k, v in sorted(c.collectives.items())},
        "top_opcodes": dict(sorted(c.opcode_counts.items(),
                                   key=lambda kv: -kv[1])[:12]),
    }


def roofline_markdown(terms: Iterable[RooflineTerms]) -> str:
    rows = []
    for t in terms:
        rows.append({
            "cell": t.name,
            "devs": t.n_devices,
            "compute_ms": t.compute_s * 1e3,
            "memory_ms": t.memory_s * 1e3,
            "collective_ms": t.collective_s * 1e3,
            "dominant": t.dominant,
            "modeled_ms": t.modeled_time_s * 1e3,
            "useful_flops": (f"{t.useful_flops_ratio:.2f}"
                             if t.useful_flops_ratio else "-"),
            "MFU": f"{t.mfu_vs_peak*100:.1f}%",
        })
    return markdown_table(rows)


def dump_json(obj, path: str) -> None:
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, default=str)
