"""Published raw measurements from Leinhauser et al. 2021, Tables 1 and 2.

These are the paper's own profiler readings (nvprof / rocProf) for the
ComputeCurrent kernel of PIConGPU's LWFA and TWEAC science cases.  They are
the ground truth our implementation of Eqs. 1-4 must reproduce:
tests/test_paper_model.py recomputes Achieved GIPS and the two intensity
columns from the raw (instructions, bytes, runtime) triples and asserts they
match the published values within the paper's own stated rounding slack
("values ... are rounded to three decimal points and therefore manually
calculating ... may vary slightly").
"""
from __future__ import annotations

from repro.core.hardware import MI60, MI100, V100
from repro.core.paper_model import KernelMeasurement

# --- Table 1: LWFA simulation, ComputeCurrent kernel -----------------------

LWFA_V100 = KernelMeasurement(
    name="ComputeCurrent/LWFA", hw=V100,
    runtime_s=0.0040,
    instructions=279_498_240,
    bytes_read=267_280_000_000.0,
    bytes_written=97_329_000_000.0,
)
LWFA_MI60 = KernelMeasurement(
    name="ComputeCurrent/LWFA", hw=MI60,
    runtime_s=0.0127,
    instructions=502_440_960,
    bytes_read=1_125_436_000.0,
    bytes_written=432_711_000.0,
)
LWFA_MI100 = KernelMeasurement(
    name="ComputeCurrent/LWFA", hw=MI100,
    runtime_s=0.0025,
    instructions=449_796_480,
    bytes_read=1_124_711_000.0,
    bytes_written=408_483_000.0,
)

# Published derived values (Table 1).
LWFA_PUBLISHED = {
    "v100": dict(peak_gips=489.60, achieved_gips=2.178, intensity=0.006),
    "mi60": dict(peak_gips=115.20, achieved_gips=0.620, intensity=0.398),
    "mi100": dict(peak_gips=180.24, achieved_gips=2.856, intensity=1.863),
}

# --- Table 2: TWEAC simulation, ComputeCurrent kernel ----------------------

TWEAC_V100 = KernelMeasurement(
    name="ComputeCurrent/TWEAC", hw=V100,
    runtime_s=0.283,
    instructions=60_149_000_000,
    bytes_read=40_931_000_000.0,
    bytes_written=1_810_100_000.0,
)
TWEAC_MI60 = KernelMeasurement(
    name="ComputeCurrent/TWEAC", hw=MI60,
    runtime_s=0.394,
    instructions=90_319_028_127,
    bytes_read=11_451_009_000.0,
    bytes_written=785_101_000.0,
)
TWEAC_MI100 = KernelMeasurement(
    name="ComputeCurrent/TWEAC", hw=MI100,
    runtime_s=0.246,
    instructions=78_488_570_820,
    bytes_read=11_460_394_000.0,
    bytes_written=792_172_000.0,
)

TWEAC_PUBLISHED = {
    "v100": dict(peak_gips=489.60, achieved_gips=6.634, intensity=0.155),
    "mi60": dict(peak_gips=115.20, achieved_gips=3.586, intensity=0.293),
    "mi100": dict(peak_gips=180.24, achieved_gips=4.993, intensity=0.408),
}

TABLE1 = {"v100": LWFA_V100, "mi60": LWFA_MI60, "mi100": LWFA_MI100}
TABLE2 = {"v100": TWEAC_V100, "mi60": TWEAC_MI60, "mi100": TWEAC_MI100}

# V100 intensity in instructions/transaction, as quoted in the prose.
V100_LWFA_INTENSITY_PER_TXN = 0.178
V100_TWEAC_INTENSITY_PER_TXN = 4.931
TRANSACTION_BYTES = 32
