"""Instruction Roofline Model assembly: ceilings + achieved points.

An IRM (paper Figs 4-7) is a log-log plot with

  x: instruction intensity  [scaled instructions / byte]
  y: performance            [GIPS]

and two families of ceilings: the horizontal peak-GIPS line (Eq. 3) and the
diagonal memory roof  y = bandwidth_GBs * x  (bandwidth measured with a
STREAM-class benchmark where the profiler can't report it).  The same object
serves the paper's AMD/NVIDIA GPUs (one ceiling pair) and our TPU variant
(separate MXU / VPU instruction ceilings + an ICI collective roof).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.hardware import HardwareSpec
from repro.core.paper_model import KernelMeasurement
from repro.core.tpu_model import TpuInstructionProfile


@dataclasses.dataclass
class Ceiling:
    label: str
    gips: Optional[float] = None       # horizontal compute ceiling
    gbs: Optional[float] = None        # diagonal memory ceiling (GB/s)

    def y_at(self, intensity: float) -> float:
        if self.gips is not None:
            return self.gips
        return self.gbs * intensity


@dataclasses.dataclass
class IRMPoint:
    label: str
    intensity: float                   # inst/byte (issue-scaled)
    gips: float
    series: str = "HBM"


@dataclasses.dataclass
class InstructionRooflineModel:
    hw: HardwareSpec
    ceilings: List[Ceiling]
    points: List[IRMPoint]
    title: str = ""

    def roof_at(self, intensity: float) -> float:
        """The binding roof value at a given intensity."""
        return min(c.y_at(intensity) for c in self.ceilings)

    def headroom(self, p: IRMPoint) -> float:
        """roof / achieved — how far below the binding roof the point sits."""
        roof = self.roof_at(p.intensity)
        return roof / p.gips if p.gips else float("inf")

    def knee(self) -> float:
        """Intensity where the memory roof meets the lowest compute roof."""
        gips = min(c.gips for c in self.ceilings if c.gips is not None)
        gbs = max(c.gbs for c in self.ceilings if c.gbs is not None)
        return gips / gbs

    def classify(self, p: IRMPoint) -> str:
        return "memory" if p.intensity < self.knee() else "compute"


def gpu_irm(hw: HardwareSpec, measurements: List[KernelMeasurement],
            title: str = "") -> InstructionRooflineModel:
    """The paper's construction: Eq. 3 compute ceiling + BabelStream memory
    ceiling; points from Eq. 2/4."""
    ceilings = [
        Ceiling(label=f"Peak {hw.peak_gips():.2f} GIPS", gips=hw.peak_gips()),
        Ceiling(label=f"HBM {hw.memory_ceiling_gbs():.1f} GB/s",
                gbs=hw.memory_ceiling_gbs()),
    ]
    points = [IRMPoint(label=m.name, intensity=m.intensity(),
                       gips=m.achieved_gips()) for m in measurements]
    return InstructionRooflineModel(hw=hw, ceilings=ceilings, points=points,
                                    title=title or f"IRM — {hw.name}")


def tpu_irm(profiles: List[TpuInstructionProfile],
            title: str = "") -> InstructionRooflineModel:
    """TPU variant: separate MXU / VPU instruction ceilings; points per unit
    class (one kernel contributes an MXU point and a VPU point, both against
    the same HBM byte count — mirroring the paper's per-level points)."""
    if not profiles:
        raise ValueError("need at least one profile")
    hw = profiles[0].hw
    ceilings = [
        Ceiling(label=f"MXU peak {hw.peak_mxu_issues_per_s()/1e9:.3f} GIPS",
                gips=hw.peak_mxu_issues_per_s() / 1e9),
        Ceiling(label=f"VPU peak {hw.peak_vpu_issues_per_s()/1e9:.3f} GIPS",
                gips=hw.peak_vpu_issues_per_s() / 1e9),
        Ceiling(label=f"HBM {hw.memory_ceiling_gbs():.0f} GB/s",
                gbs=hw.memory_ceiling_gbs()),
    ]
    points: List[IRMPoint] = []
    for p in profiles:
        points.append(IRMPoint(label=f"{p.name} (MXU)",
                               intensity=p.mxu_intensity,
                               gips=p.achieved_mxu_gips, series="MXU"))
        points.append(IRMPoint(label=f"{p.name} (VPU)",
                               intensity=p.vpu_intensity,
                               gips=p.achieved_vpu_gips, series="VPU"))
    return InstructionRooflineModel(hw=hw, ceilings=ceilings, points=points,
                                    title=title or f"Instruction roofline — {hw.name}")
