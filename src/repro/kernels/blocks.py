"""Shared block-size fallback for Pallas grids.

Pallas BlockSpecs need a block size that divides the array dim exactly;
odd-shaped inputs (BabelStream sweeps, arbitrary max_seq caches) must fall
back to a smaller block instead of crashing.  One helper, parameterized by
the hardware alignment preference (lanes for kv tiles, sublanes for
row-blocked streams), so the divisor-search logic lives in exactly one
place.
"""
from __future__ import annotations

from typing import Tuple


def largest_divisor_block(total: int, block: int,
                          aligns: Tuple[int, ...] = (8, 1)) -> int:
    """Largest divisor of ``total`` that is <= ``block``, preferring
    multiples of each alignment in ``aligns`` order (e.g. (128, 8, 1) for
    lane-major tiles, (8, 1) for sublane row blocks)."""
    hi = max(1, min(block, total))
    if total % hi == 0:
        return hi
    for align in aligns:
        for c in range(hi - hi % align, 0, -align):
            if c and total % c == 0:
                return c
    return 1
