"""Paged single-token decode attention as a Pallas TPU kernel.

The paged serving hot path: one new query per slot attends over that slot's
PAGES of a shared (num_pages, page, KV, D) pool.  The physical page holding
each logical block comes from a scalar-prefetched block table — the DMA
address is computed from SMEM before the tile is fetched, so the kernel
streams ONLY the pages a slot actually owns.  That is the point for the
instruction roofline: the decode step's transaction count is proportional to
live tokens (max_blocks x page per slot) instead of ``max_seq``, which
``core.hlo_counters`` verifies on the jnp gather oracle (the dense cache
reads every row of a (B, max_seq, KV, D) cache whether or not it is live).

Shape strategy (mirrors the dense decode kernel in ``decode.py``):

  * grid = (B, KV, ceil(max_blocks / P)) with P = ``pages_per_step`` —
    logical blocks are the MINOR axis, so the online-softmax state for one
    (slot, kv-head) lives in VMEM scratch across the page sweep.
  * MULTI-PAGE BLOCKING (``pages_per_step`` > 1): each grid step scalar-
    prefetches a page LIST — P physically-scattered pages resolved through
    the block table — and sweeps all P through the online-softmax update
    before the next grid step.  Grid steps (and their per-step init/
    finalize + index bookkeeping overhead) shrink by P for long slots; the
    tiles fetched are identical, so the transaction census is unchanged.
    The block table is padded to a multiple of P with null-page entries so
    every prefetched address stays valid (``grid_steps``/``padded_blocks``
    expose the blocking arithmetic for tests).
  * GQA without materializing repeated kv heads: q reshaped to
    (B, KV, G, D), each page runs [G, D] x [D, page] on the MXU.
  * per-slot ``kv_len`` + the flattened block table + the layer index
    arrive via scalar prefetch (SMEM): the k/v BlockSpec index_maps read
    ``tbl[b * padded_blocks + j * P + p]`` to pick the p-th physical page
    of grid step j, and pages at or beyond the slot's length are skipped
    with ``pl.when`` (their table entries point at the reserved null page
    0, so the prefetch address is always valid).
  * the pool stays STACKED (L, num_pages, page, KV, D): the layer-scan
    caller passes its trip counter as the ``layer`` scalar and the
    index_map addresses (layer, page) directly — no per-layer pool slice
    is ever materialized (a dynamic-slice of the full pool per layer is
    exactly the max_seq-proportional traffic the paged design removes).

Inference-only: no VJP (the jnp gather oracle in ``ref.py`` carries
gradients where needed).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def grid_steps(num_blocks: int, pages_per_step: int) -> int:
    """Grid steps along the block axis: P pages per step -> ceil(NB / P)."""
    return -(-num_blocks // max(1, pages_per_step))


def padded_blocks(num_blocks: int, pages_per_step: int) -> int:
    """Block-table width after padding to a multiple of ``pages_per_step``
    (pad entries are null-page references the kernel skips)."""
    return grid_steps(num_blocks, pages_per_step) * max(1, pages_per_step)


def _kernel(kvlen_ref, tbl_ref, layer_ref, q_ref, *refs, scale: float,
            page: int, num_steps: int, pages_per_step: int,
            quantized: bool):
    P = pages_per_step
    k_refs = refs[:P]
    v_refs = refs[P:2 * P]
    if quantized:                        # int8 pages + per-row f32 scales
        ks_refs = refs[2 * P:3 * P]
        vs_refs = refs[3 * P:4 * P]
        rest = refs[4 * P:]
    else:
        ks_refs = vs_refs = (None,) * P
        rest = refs[2 * P:]
    o_ref = rest[0]
    m_scr, l_scr, acc_scr = rest[1:]
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = kvlen_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)                  # (G, D)

    def _sweep(p, k_ref, v_ref, ks_ref, vs_ref):
        # logical block j*P + p holds positions [bj*page, (bj+1)*page):
        # live iff it overlaps [0, kv_len) — per-slot positions start at 0
        bj = j * P + p

        @pl.when(bj * page < kv_len)
        def _body():
            k = k_ref[0, 0, :, 0].astype(jnp.float32)    # (page, D)
            v = v_ref[0, 0, :, 0].astype(jnp.float32)
            if quantized:                # dequantize in the f32 accumulator
                k = k * ks_ref[0, 0, :, 0][:, None]
                v = v * vs_ref[0, 0, :, 0][:, None]
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # (G, page)
            tpos = bj * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(tpos < kv_len, s, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
            p_ = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)
            l_scr[...] = l_scr[...] * corr + p_.sum(axis=1, keepdims=True)
            acc_scr[...] = (acc_scr[...] * corr
                            + jax.lax.dot_general(
                                p_, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32))
            m_scr[...] = m_new

    for p in range(P):                   # unrolled page-list sweep
        _sweep(p, k_refs[p], v_refs[p], ks_refs[p], vs_refs[p])

    @pl.when(j == num_steps - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_decode_attention_fwd(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_table: jax.Array,
                               kv_len: jax.Array,
                               layer: jax.Array | int = 0, *,
                               k_scale: jax.Array | None = None,
                               v_scale: jax.Array | None = None,
                               pages_per_step: int = 1,
                               interpret: bool = False) -> jax.Array:
    """q (B, 1, H, D); k_pool, v_pool (L, num_pages, page, KV, D) stacked
    pools (a 4D (num_pages, page, KV, D) single-layer pool is promoted);
    block_table (B, max_blocks) int32 physical page ids (0 = reserved null
    page for unallocated blocks); kv_len (B,) int32 per-slot token counts
    (positions >= kv_len[b] are masked); layer — which pool layer to
    address (the layer-scan trip counter); k_scale, v_scale — optional
    (L, num_pages, page, KV) f32 per-row-per-head scales for int8 pools
    (each page's scale rows ride the same scalar-prefetched address as the
    page itself; int8 tiles are upcast and scaled inside the f32
    online-softmax accumulator); pages_per_step — pages swept per grid
    step (1 = the original one-page grid).  Returns (B, 1, H, D).
    """
    B, S, H, D = q.shape
    assert S == 1, "paged decode kernel is single-token"
    quantized = k_scale is not None
    if k_pool.ndim == 4:
        k_pool, v_pool = k_pool[None], v_pool[None]
        if quantized:
            k_scale, v_scale = k_scale[None], v_scale[None]
    _, num_pages, page, KV, _ = k_pool.shape
    NB = block_table.shape[1]
    P = max(1, pages_per_step)
    steps = grid_steps(NB, P)
    NBp = padded_blocks(NB, P)
    G = H // KV
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, KV, G, D)                  # kv-major head grouping
    tbl = jnp.asarray(block_table, jnp.int32)
    if NBp != NB:                                # pad with null-page entries
        tbl = jnp.pad(tbl, ((0, 0), (0, NBp - NB)))
    tbl = tbl.reshape(B * NBp)
    kvl = jnp.asarray(kv_len, jnp.int32).reshape(B)
    lay = jnp.asarray(layer, jnp.int32).reshape(1)

    def _page_map(p):
        # the p-th page of grid step j: physical id tbl[b*NBp + j*P + p]
        def index_map(b, h, j, kvl_ref, tbl_ref, lay_ref):
            return (lay_ref[0], tbl_ref[b * NBp + j * P + p], 0, h, 0)
        return index_map

    def _scale_map(p):
        # scale rows of the same physical page (no head-dim axis)
        def index_map(b, h, j, kvl_ref, tbl_ref, lay_ref):
            return (lay_ref[0], tbl_ref[b * NBp + j * P + p], 0, h)
        return index_map

    page_spec = [pl.BlockSpec((1, 1, page, 1, D), _page_map(p))
                 for p in range(P)]
    scale_spec = [pl.BlockSpec((1, 1, page, 1), _scale_map(p))
                  for p in range(P)]
    scale_ins = ([*scale_spec, *scale_spec] if quantized else [])
    scale_args = (([k_scale] * P + [v_scale] * P) if quantized else [])
    kernel = functools.partial(_kernel, scale=scale, page=page,
                               num_steps=steps, pages_per_step=P,
                               quantized=quantized)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, KV, steps),
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, j, *_: (b, h, 0, 0)),
                *page_spec,                       # k pages 0..P-1
                *page_spec,                       # v pages 0..P-1
                *scale_ins,                       # k then v scales (int8)
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),     # running row max
                pltpu.VMEM((G, 1), jnp.float32),     # running row sum
                pltpu.VMEM((G, D), jnp.float32),     # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(kvl, tbl, lay, qg, *([k_pool] * P), *([v_pool] * P), *scale_args)
    return out.reshape(B, 1, H, D)
