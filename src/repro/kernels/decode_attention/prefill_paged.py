"""Ragged multi-token paged PREFILL attention as a Pallas TPU kernel.

The chunked-prefill hot path: each slot appends a chunk of up to ``T``
prompt tokens into its pages (the caller scatters the chunk's K/V rows
BEFORE attention runs, exactly like the single-token decode append) and the
(T, H, D) query block then attends CAUSALLY over the slot's live pages —
history plus the in-flight chunk — in ONE kernel launch.  Admitting a
prompt of P tokens therefore costs ``ceil(P / T)`` compiled steps instead
of the P sequential decode-cell steps the prefill-by-decode path paid: the
serving tick's admission latency stops scaling with prompt length while
the kernel's transaction count keeps scaling with live tokens (chunk rows
+ live pages), which ``core.hlo_counters`` pins on the jnp gather oracle.

Shape strategy (mirrors the single-token paged decode kernel in
``paged.py``):

  * grid = (B, KV, max_blocks) — logical blocks are the MINOR axis so one
    (slot, kv-head)'s online-softmax state lives in VMEM scratch across
    the page sweep; the query block rides along whole.
  * the q block is flattened to (T*G, D) rows, t-major (row r holds query
    token ``r // G`` of head group ``r % G``), so the per-page score tile
    is a single (T*G, page) MXU matmul and the causal mask is an iota
    divide away.
  * RAGGED chunks: per-slot ``base`` (tokens resident BEFORE the chunk)
    and ``new_len`` (= base + granted tokens) arrive via scalar prefetch.
    Query row t sits at absolute position base + t and attends positions
    <= base + t (causal) and < new_len (the slot's granted extent); rows
    past the grant produce garbage the caller ignores (their appends
    landed on the null page), but they apply the same masks as the
    oracle, so interpret-mode equivalence holds row-for-row for every
    slot with at least one live position (new_len > 0).  The one
    divergence is a fully EMPTY slot (base == 0 AND grant == 0, i.e. an
    unoccupied batch row): all its rows are fully masked — the kernel's
    guarded finalize emits zeros where the oracle's degenerate all-masked
    softmax goes uniform.  Both are garbage the engine never reads;
    other slots' rows are unaffected (pinned by test).
  * the physical page of logical block j comes from the scalar-prefetched
    block table — dead blocks (j*page >= new_len) are skipped with
    ``pl.when`` and their table entries point at the reserved null page 0,
    so the prefetched DMA address is always valid.
  * MULTI-PAGE BLOCKING (``pages_per_step`` > 1): each grid step scalar-
    prefetches a page LIST — P physically-scattered pages resolved through
    the block table — and sweeps all P through the online-softmax update
    before the next grid step, exactly like ``paged.py``.  Grid steps (and
    their per-step init/finalize + index bookkeeping overhead) shrink by P
    for long histories — the shape a speculative VERIFY chunk over a long
    decode hits every tick; the tiles fetched are identical, so the
    transaction census is unchanged.  The block table is padded to a
    multiple of P with null-page entries so every prefetched address stays
    valid (``grid_steps``/``padded_blocks`` in ``paged.py`` expose the
    blocking arithmetic).
  * GQA without materializing repeated kv heads: each page runs
    [T*G, D] x [D, page] on the MXU.
  * the pool stays STACKED (L, num_pages, page, KV, D); the layer-scan
    caller passes its trip counter as the ``layer`` scalar.

Inference-only: no VJP (the jnp gather oracle in ``ref.py`` carries
gradients where needed).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .paged import grid_steps, padded_blocks

NEG_INF = -1e30


def _kernel(base_ref, len_ref, tbl_ref, layer_ref, q_ref, *refs,
            scale: float, page: int, num_steps: int, pages_per_step: int,
            groups: int, quantized: bool):
    P = pages_per_step
    k_refs = refs[:P]
    v_refs = refs[P:2 * P]
    if quantized:                        # int8 pages + per-row f32 scales
        ks_refs = refs[2 * P:3 * P]
        vs_refs = refs[3 * P:4 * P]
        rest = refs[4 * P:]
    else:
        ks_refs = vs_refs = (None,) * P
        rest = refs[2 * P:]
    o_ref = rest[0]
    m_scr, l_scr, acc_scr = rest[1:]
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    base = base_ref[b]
    kv_len = len_ref[b]
    q = q_ref[0, 0].astype(jnp.float32)                  # (T*G, D)

    def _sweep(p, k_ref, v_ref, ks_ref, vs_ref):
        # logical block j*P + p holds positions [bj*page, (bj+1)*page):
        # live iff it overlaps [0, new_len) — per-slot positions start at
        # 0 on the slot's own pages
        bj = j * P + p

        @pl.when(bj * page < kv_len)
        def _body():
            k = k_ref[0, 0, :, 0].astype(jnp.float32)    # (page, D)
            v = v_ref[0, 0, :, 0].astype(jnp.float32)
            if quantized:                # dequantize in the f32 accumulator
                k = k * ks_ref[0, 0, :, 0][:, None]
                v = v * vs_ref[0, 0, :, 0][:, None]
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # (T*G, page)
            tpos = bj * page + jax.lax.broadcasted_iota(jnp.int32,
                                                        s.shape, 1)
            # row r is query token r // G at absolute position base + r // G
            qpos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape,
                                                   0) // groups
            s = jnp.where((tpos <= qpos) & (tpos < kv_len), s, NEG_INF)
            m_prev = m_scr[...]
            m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
            p_ = jnp.exp(s - m_new)
            corr = jnp.exp(m_prev - m_new)
            l_scr[...] = l_scr[...] * corr + p_.sum(axis=1, keepdims=True)
            acc_scr[...] = (acc_scr[...] * corr
                            + jax.lax.dot_general(
                                p_, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32))
            m_scr[...] = m_new

    for p in range(P):                   # unrolled page-list sweep
        _sweep(p, k_refs[p], v_refs[p], ks_refs[p], vs_refs[p])

    @pl.when(j == num_steps - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def paged_prefill_attention_fwd(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, block_table: jax.Array,
                                base_len: jax.Array, new_len: jax.Array,
                                layer: jax.Array | int = 0, *,
                                k_scale: jax.Array | None = None,
                                v_scale: jax.Array | None = None,
                                pages_per_step: int = 1,
                                interpret: bool = False) -> jax.Array:
    """q (B, T, H, D) — the chunk's query block (its K/V rows must already
    be scattered into the pool); k_pool, v_pool (L, num_pages, page, KV, D)
    stacked pools (a 4D single-layer pool is promoted); block_table
    (B, max_blocks) int32 physical page ids (0 = reserved null page);
    base_len (B,) int32 tokens resident before the chunk; new_len (B,)
    int32 = base_len + granted chunk tokens (rows past a slot's grant are
    masked like the oracle and ignored by the caller); layer — which pool
    layer to address; k_scale, v_scale — optional (L, num_pages, page, KV)
    f32 per-row-per-head scales for int8 pools, dequantized inside the
    page sweep; pages_per_step — pages swept per grid step (1 = the
    original one-page grid).  Returns (B, T, H, D).
    """
    B, T, H, D = q.shape
    quantized = k_scale is not None
    if k_pool.ndim == 4:
        k_pool, v_pool = k_pool[None], v_pool[None]
        if quantized:
            k_scale, v_scale = k_scale[None], v_scale[None]
    _, num_pages, page, KV, _ = k_pool.shape
    NB = block_table.shape[1]
    P = max(1, pages_per_step)
    steps = grid_steps(NB, P)
    NBp = padded_blocks(NB, P)
    G = H // KV
    TG = T * G
    scale = 1.0 / math.sqrt(D)

    # t-major row flattening: row r = query token r // G, head group r % G
    qg = q.reshape(B, T, KV, G, D).transpose(0, 2, 1, 3, 4)
    qg = qg.reshape(B, KV, TG, D)
    tbl = jnp.asarray(block_table, jnp.int32)
    if NBp != NB:                                # pad with null-page entries
        tbl = jnp.pad(tbl, ((0, 0), (0, NBp - NB)))
    tbl = tbl.reshape(B * NBp)
    base = jnp.asarray(base_len, jnp.int32).reshape(B)
    kvl = jnp.asarray(new_len, jnp.int32).reshape(B)
    lay = jnp.asarray(layer, jnp.int32).reshape(1)

    def _page_map(p):
        # the p-th page of grid step j: physical id tbl[b*NBp + j*P + p]
        def index_map(b, h, j, base_ref, len_ref, tbl_ref, lay_ref):
            return (lay_ref[0], tbl_ref[b * NBp + j * P + p], 0, h, 0)
        return index_map

    def _scale_map(p):
        # scale rows of the same physical page (no head-dim axis)
        def index_map(b, h, j, base_ref, len_ref, tbl_ref, lay_ref):
            return (lay_ref[0], tbl_ref[b * NBp + j * P + p], 0, h)
        return index_map

    page_spec = [pl.BlockSpec((1, 1, page, 1, D), _page_map(p))
                 for p in range(P)]
    scale_spec = [pl.BlockSpec((1, 1, page, 1), _scale_map(p))
                  for p in range(P)]
    scale_ins = ([*scale_spec, *scale_spec] if quantized else [])
    scale_args = (([k_scale] * P + [v_scale] * P) if quantized else [])
    kernel = functools.partial(_kernel, scale=scale, page=page,
                               num_steps=steps, pages_per_step=P,
                               groups=G, quantized=quantized)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(B, KV, steps),
            in_specs=[
                pl.BlockSpec((1, 1, TG, D), lambda b, h, j, *_: (b, h, 0, 0)),
                *page_spec,                       # k pages 0..P-1
                *page_spec,                       # v pages 0..P-1
                *scale_ins,                       # k then v scales (int8)
            ],
            out_specs=pl.BlockSpec((1, 1, TG, D),
                                   lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((TG, 1), jnp.float32),    # running row max
                pltpu.VMEM((TG, 1), jnp.float32),    # running row sum
                pltpu.VMEM((TG, D), jnp.float32),    # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, TG, D), q.dtype),
        interpret=interpret,
    )(base, kvl, tbl, lay, qg, *([k_pool] * P), *([v_pool] * P),
      *scale_args)
    out = out.reshape(B, KV, T, G, D).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, T, H, D)
