"""Pure-jnp oracles for the Pallas decode-attention kernels: the direct
softmax attention with kv_len / kv_start window masking
(repro.models.attention.direct_attention) — interpret-mode tests assert the
kernels match them bit-for-bit in fp32.

``paged_decode_attention_ref`` is also the production jnp path for the paged
cache (``cfg.attention_impl == "reference"``): a block-table gather
materializes each slot's logical view of the pool, so the HLO census sees
gather traffic proportional to live pages — the roofline claim the paged
design exists to make measurable."""
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.attention import direct_attention


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len, kv_start: Optional[jax.Array] = None
                         ) -> jax.Array:
    """q (B, 1, H, D); k, v (B, T, KV, D).  Returns (B, 1, H, D)."""
    kv_len_m1 = kv_len - 1
    return direct_attention(q, k, v, causal=True, q_offset=kv_len_m1,
                            kv_len=kv_len, kv_start=kv_start)


def _gather_logical(q, k_pool, v_pool, block_table, layer,
                    k_scale=None, v_scale=None):
    """Gather each slot's pages (and, for int8 pools, their per-row scales)
    into the logical (B, max_blocks*page, KV, D) view — live pages only,
    never the whole pool.  Quantized pools are dequantized with the SAME
    arithmetic as the kernels' page sweeps (upcast int8 to f32, multiply by
    the row's scale), so interpret-equivalence pins both paths."""
    if k_pool.ndim == 4:
        k_pool, v_pool = k_pool[None], v_pool[None]
        if k_scale is not None:
            k_scale, v_scale = k_scale[None], v_scale[None]
    B = block_table.shape[0]
    _, _, page, KV, D = k_pool.shape
    NB = block_table.shape[1]
    kg = k_pool[layer, block_table]          # (B, NB, page, KV, D)
    vg = v_pool[layer, block_table]
    if k_scale is not None:                  # int8 pages + per-row scales
        kg = kg.astype(jnp.float32) * k_scale[layer, block_table][..., None]
        vg = vg.astype(jnp.float32) * v_scale[layer, block_table][..., None]
        kg = kg.astype(q.dtype)
        vg = vg.astype(q.dtype)
    return (kg.reshape(B, NB * page, KV, D),
            vg.reshape(B, NB * page, KV, D))


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, block_table: jax.Array,
                               kv_len: jax.Array, layer=0,
                               k_scale: Optional[jax.Array] = None,
                               v_scale: Optional[jax.Array] = None
                               ) -> jax.Array:
    """q (B, 1, H, D); k_pool, v_pool (L, num_pages, page, KV, D) stacked
    pools (4D single-layer pools are promoted); block_table (B, max_blocks)
    int32; kv_len (B,) int32 per-slot token counts; layer — the pool layer
    to address; k_scale, v_scale — optional (L, num_pages, page, KV) f32
    per-row scales for int8 pools (dequantized after the gather).  Gathers
    each slot's pages into its logical (max_blocks*page, KV, D) view in ONE
    (layer, page) gather — live pages only, never the whole pool — then
    masks positions >= kv_len[b].  Returns (B, 1, H, D)."""
    kg, vg = _gather_logical(q, k_pool, v_pool, block_table, layer,
                             k_scale, v_scale)
    return direct_attention(q, kg, vg, causal=False, kv_len=kv_len)


def paged_prefill_attention_ref(q: jax.Array, k_pool: jax.Array,
                                v_pool: jax.Array, block_table: jax.Array,
                                base_len: jax.Array, new_len: jax.Array,
                                layer=0,
                                k_scale: Optional[jax.Array] = None,
                                v_scale: Optional[jax.Array] = None
                                ) -> jax.Array:
    """Oracle for the ragged multi-token paged PREFILL kernel: q
    (B, T, H, D) — a chunk whose K/V rows are already scattered into the
    pool; base_len (B,) tokens resident before the chunk; new_len (B,)
    = base_len + granted tokens.  Gathers each slot's logical view in one
    (layer, page) gather — live pages only — then applies the per-slot
    CAUSAL mask (query row t attends positions <= base_len[b] + t) and the
    per-slot extent mask (< new_len[b]).  Rows past a slot's grant are
    masked the same way the kernel masks them (their output is garbage the
    engine ignores, but the two paths agree row-for-row).
    Returns (B, T, H, D)."""
    kg, vg = _gather_logical(q, k_pool, v_pool, block_table, layer,
                             k_scale, v_scale)
    return direct_attention(q, kg, vg, causal=True, q_offset=base_len,
                            kv_len=new_len)
