"""Pure-jnp oracle for the Pallas decode-attention kernel: the direct
softmax attention with kv_len / kv_start window masking
(repro.models.attention.direct_attention) — interpret-mode tests assert the
kernel matches it bit-for-bit in fp32."""
from typing import Optional

import jax

from repro.models.attention import direct_attention


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len, kv_start: Optional[jax.Array] = None
                         ) -> jax.Array:
    """q (B, 1, H, D); k, v (B, T, KV, D).  Returns (B, 1, H, D)."""
    kv_len_m1 = kv_len - 1
    return direct_attention(q, k, v, causal=True, q_offset=kv_len_m1,
                            kv_len=kv_len, kv_start=kv_start)
