"""Dispatch wrapper for the Pallas decode-attention kernel.

Runs the real kernel on TPU and interpret mode elsewhere (CPU smoke/tests).
Called from inside the jitted decode step (transformer.attn_decode when
``cfg.attention_impl == "pallas"``), so no jit wrapper here.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.decode_attention import decode as _decode
from repro.kernels.decode_attention import paged as _paged
from repro.kernels.decode_attention import prefill_paged as _prefill


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len, kv_start: Optional[jax.Array] = None,
                     block_kv: int = 128,
                     interpret: Optional[bool] = None) -> jax.Array:
    """q (B, 1, H, D); k, v (B, T, KV, D); kv_len scalar; kv_start (B,) or
    None.  Returns (B, 1, H, D)."""
    return _decode.decode_attention_fwd(
        q, k, v, kv_len, kv_start, block_kv=block_kv,
        interpret=_auto_interpret(interpret))


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_table: jax.Array,
                           kv_len: jax.Array, layer=0,
                           pages_per_step: int = 1,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """q (B, 1, H, D); k_pool, v_pool (L, num_pages, page, KV, D) stacked
    pools (4D single-layer accepted); block_table (B, max_blocks) int32
    (page 0 = reserved null page); kv_len (B,) int32 per-slot token counts;
    layer — pool layer to address; pages_per_step — page-list blocking
    factor (P pages swept per grid step); k_scale, v_scale — optional
    (L, num_pages, page, KV) f32 per-row scales for int8 pools.
    Returns (B, 1, H, D)."""
    return _paged.paged_decode_attention_fwd(
        q, k_pool, v_pool, block_table, kv_len, layer,
        k_scale=k_scale, v_scale=v_scale,
        pages_per_step=pages_per_step,
        interpret=_auto_interpret(interpret))


def paged_prefill_attention(q: jax.Array, k_pool: jax.Array,
                            v_pool: jax.Array, block_table: jax.Array,
                            base_len: jax.Array, new_len: jax.Array,
                            layer=0,
                            pages_per_step: int = 1,
                            k_scale: Optional[jax.Array] = None,
                            v_scale: Optional[jax.Array] = None,
                            interpret: Optional[bool] = None) -> jax.Array:
    """Ragged multi-token paged prefill: q (B, T, H, D) chunk (its K/V
    rows already scattered into the pool); k_pool, v_pool
    (L, num_pages, page, KV, D) stacked pools (4D single-layer accepted);
    block_table (B, max_blocks) int32 (page 0 = reserved null page);
    base_len (B,) int32 tokens resident before the chunk; new_len (B,)
    int32 = base_len + granted chunk tokens; layer — pool layer to
    address; pages_per_step — page-list blocking factor (P pages swept
    per grid step); k_scale, v_scale — optional (L, num_pages, page, KV)
    f32 per-row scales for int8 pools.  Returns (B, T, H, D)."""
    return _prefill.paged_prefill_attention_fwd(
        q, k_pool, v_pool, block_table, base_len, new_len, layer,
        k_scale=k_scale, v_scale=v_scale,
        pages_per_step=pages_per_step,
        interpret=_auto_interpret(interpret))
