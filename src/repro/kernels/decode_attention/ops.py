"""Dispatch wrapper for the Pallas decode-attention kernel.

Runs the real kernel on TPU and interpret mode elsewhere (CPU smoke/tests).
Called from inside the jitted decode step (transformer.attn_decode when
``cfg.attention_impl == "pallas"``), so no jit wrapper here.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.decode_attention import decode as _decode


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len, kv_start: Optional[jax.Array] = None,
                     block_kv: int = 128,
                     interpret: Optional[bool] = None) -> jax.Array:
    """q (B, 1, H, D); k, v (B, T, KV, D); kv_len scalar; kv_start (B,) or
    None.  Returns (B, 1, H, D)."""
    return _decode.decode_attention_fwd(
        q, k, v, kv_len, kv_start, block_kv=block_kv,
        interpret=_auto_interpret(interpret))
