"""Single-token decode attention as a Pallas TPU kernel.

The serving decode hot path: one new query per slot attends over that slot's
rows of the (B, T, KV, D) batched KV cache.  The cache never leaves HBM
wholesale — it is streamed through VMEM in (block_kv x D) tiles, D padded to
128 lanes, so every transaction the instruction roofline sees is
(8,128)-aligned (the paper's strided-access lesson, section 5.2).

Shape strategy vs the prefill flash kernel:

  * grid = (B, KV, num_kv_blocks) — kv blocks are the MINOR axis, so the
    online-softmax state for one (slot, kv-head) lives in VMEM scratch
    across the kv sweep (TPU grids execute sequentially per core).
  * GQA WITHOUT materializing repeated kv heads: q is reshaped to
    (B, KV, G, D) and each grid step processes the whole G-row group of
    one kv head against one (block_kv, D) cache tile — the MXU pass is
    [G, D] x [D, block_kv].
  * ``kv_len`` / per-slot ``start`` arrive via scalar prefetch (SMEM):
    dead blocks (entirely outside [start[b], kv_len)) are skipped with
    ``pl.when`` — no FLOPs or VMEM traffic issued — and the boundary
    blocks apply an elementwise position mask.

Inference-only: no VJP (the jnp reference in models/attention.py carries
gradients where needed).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.blocks import largest_divisor_block

NEG_INF = -1e30


def pick_block(total: int, block: int) -> int:
    """Largest divisor of ``total`` that is <= ``block``, preferring
    lane/sublane-aligned sizes (multiples of 128, then 8)."""
    return largest_divisor_block(total, block, aligns=(128, 8, 1))


def _kernel(kvlen_ref, start_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, block_kv: int,
            num_kv: int):
    b = pl.program_id(0)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = kvlen_ref[0]
    start = start_ref[b]
    # block is live iff it overlaps the slot's window [start, kv_len)
    run = (kj * block_kv < kv_len) & ((kj + 1) * block_kv > start)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)           # (bkv, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, bkv)
        tpos = kj * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where((tpos < kv_len) & (tpos >= start), s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(kj == num_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array,
                         kv_start: Optional[jax.Array] = None, *,
                         block_kv: int = 128,
                         interpret: bool = False) -> jax.Array:
    """q (B, 1, H, D); k, v (B, T, KV, D); kv_len scalar int32 (positions
    >= kv_len are masked); kv_start (B,) int32 or None (positions <
    kv_start[b] are masked).  Returns (B, 1, H, D)."""
    B, S, H, D = q.shape
    assert S == 1, "decode kernel is single-token"
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bkv = pick_block(T, block_kv)
    num_kv = T // bkv
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, KV, G, D)                  # kv-major head grouping
    kv_len_arr = jnp.asarray(kv_len, jnp.int32).reshape(1)
    if kv_start is None:
        kv_start = jnp.zeros((B,), jnp.int32)
    start_arr = jnp.asarray(kv_start, jnp.int32).reshape(B)

    kernel = functools.partial(_kernel, scale=scale, block_kv=bkv,
                               num_kv=num_kv)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, num_kv),
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, j, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, bkv, 1, D), lambda b, h, j, *_: (b, j, h, 0)),
                pl.BlockSpec((1, bkv, 1, D), lambda b, h, j, *_: (b, j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, D),
                                   lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),     # running row max
                pltpu.VMEM((G, 1), jnp.float32),     # running row sum
                pltpu.VMEM((G, D), jnp.float32),     # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(kv_len_arr, start_arr, qg, k, v)
    return out.reshape(B, 1, H, D)
