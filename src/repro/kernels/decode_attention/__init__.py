from repro.kernels.decode_attention.ops import (  # noqa: F401
    decode_attention, paged_decode_attention, paged_prefill_attention)
