"""Mamba1 selective scan as a Pallas TPU kernel.

TPU adaptation of the CUDA selective-scan: the state h (bd, N) lives in VMEM
scratch for the WHOLE sequence while x/dt/B/C stream through in chunks —
HBM traffic is exactly inputs + outputs (the jnp scan pays h in/out + decay
materialization per step: ~60x more).

  grid = (B, d_in/bd, S/chunk)   — chunk is the minor (sequential) axis, so
                                   the scratch state carries across chunks
  blocks: x, dt (1, chunk, bd); B, C (1, chunk, N); A (bd, N)
  per-step work is VPU-shaped: (bd, N) elementwise + an N-reduction

d_in is the LANE dim of x blocks (bd multiple of 128); N=16 fits a vreg
sublane group.  VMEM: (chunk x bd)*2 + (chunk x N)*2 + (bd x N) floats —
~600 KiB at chunk=256, bd=256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, hout_ref, h_scr, *,
            chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (chunk, bd)
    dt = dt_ref[0].astype(jnp.float32)
    bb = b_ref[0].astype(jnp.float32)         # (chunk, N)
    cc = c_ref[0].astype(jnp.float32)
    A = a_ref[...].astype(jnp.float32)        # (bd, N)

    def step(t, carry):
        h, y = carry
        dt_t = jax.lax.dynamic_index_in_dim(dt, t, 0, False)   # (bd,)
        x_t = jax.lax.dynamic_index_in_dim(x, t, 0, False)
        b_t = jax.lax.dynamic_index_in_dim(bb, t, 0, False)    # (N,)
        c_t = jax.lax.dynamic_index_in_dim(cc, t, 0, False)
        dA = jnp.exp(dt_t[:, None] * A)                        # (bd, N)
        h = dA * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_t = jnp.sum(h * c_t[None, :], axis=1)                # (bd,)
        y = jax.lax.dynamic_update_index_in_dim(y, y_t, t, 0)
        return h, y

    y0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h_scr[...], y0))
    h_scr[...] = h
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _flush():
        hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan_fwd(x: jax.Array, dt: jax.Array, A: jax.Array,
                       Bc: jax.Array, Cc: jax.Array, *,
                       block_d: int = 256, chunk: int = 256,
                       interpret: bool = False):
    """x, dt (B, S, d_in); A (d_in, N); Bc, Cc (B, S, N).
    Returns (y (B, S, d_in), h_final (B, d_in, N))."""
    B, S, d_in = x.shape
    N = A.shape[1]
    bd = min(block_d, d_in)
    c = min(chunk, S)
    assert d_in % bd == 0 and S % c == 0, (d_in, bd, S, c)
    grid = (B, d_in // bd, S // c)

    kernel = functools.partial(_kernel, chunk=c, num_chunks=S // c)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, c, bd), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, c, bd), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, c, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((1, c, N), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((bd, N), lambda b, di, ci: (di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, bd), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, bd, N), lambda b, di, ci: (b, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, d_in), x.dtype),
            jax.ShapeDtypeStruct((B, d_in, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, Bc, Cc, A)
    return y, h_fin


def analytic_hbm_bytes(B: int, S: int, d_in: int, N: int,
                       dtype_bytes: int = 4) -> float:
    """HBM traffic model for one forward invocation: stream x, dt, y
    (B,S,d_in) + B, C (B,S,N) + A + h out — the quantity substituted into
    the kernel-adjusted roofline."""
    return float(B * S * (3 * d_in + 2 * N) * dtype_bytes
                 + d_in * N * 4 + B * d_in * N * 4)
