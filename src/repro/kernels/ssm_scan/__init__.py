from repro.kernels.ssm_scan.ops import selective_scan  # noqa: F401
