"""Oracle: the model's own sequential-time scan (repro.models.ssm)."""
from repro.models.ssm import mamba1_scan  # noqa: F401
