"""Jitted wrapper for the Pallas selective scan; backward falls back to the
jnp sequential scan's autodiff (same math)."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssm_scan import scan as _scan
from repro.models.ssm import mamba1_scan


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def selective_scan(x, dt, A, Bc, Cc, block_d: int = 256, chunk: int = 256,
                   interpret=None):
    return _scan.selective_scan_fwd(
        x, dt, A, Bc, Cc, block_d=block_d, chunk=chunk,
        interpret=_auto_interpret(interpret))
