"""Pure-jnp oracles for the BabelStream-TPU kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def copy(a):
    return a + 0


def mul(c, scalar: float = 0.4):
    return c * scalar


def add(a, b):
    return a + b


def triad(b, c, scalar: float = 0.4):
    return b + scalar * c


def dot(a, b):
    return jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32))
