"""BabelStream-TPU: the five STREAM kernels as Pallas TPU kernels.

The paper uses BabelStream's HIP implementation to measure each AMD GPU's
*attainable* memory bandwidth (its copy result becomes the IRM memory
ceiling, section 6.2).  This is the TPU port: each kernel streams HBM-resident
arrays through VMEM in (8, LANE*k)-aligned blocks via ``pl.pallas_call`` with
explicit BlockSpecs.

  copy : c[i] = a[i]
  mul  : b[i] = s * c[i]
  add  : c[i] = a[i] + b[i]
  triad: a[i] = b[i] + s * c[i]
  dot  : sum(a[i] * b[i])   (grid-sequential accumulation into SMEM-like
                             (1,1) VMEM accumulator — TPU grids execute
                             sequentially per core, so this is race-free)

Arrays are 2-D (rows, cols): rows multiple of 8 sublanes, cols multiple of
128 lanes.  ``BLOCK_ROWS`` x cols is the VMEM working set per grid step —
sized well under the ~16 MiB v5e VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.blocks import largest_divisor_block

BLOCK_ROWS = 256          # x 512 lanes x 4B = 512 KiB per operand block


def _block_rows(rows: int, block_rows: int) -> int:
    """Largest divisor of ``rows`` <= ``block_rows`` (prefer 8-sublane
    multiples) — odd-shaped arrays fall back to a smaller block instead of
    crashing the BabelStream sweep."""
    return largest_divisor_block(rows, block_rows, aligns=(8, 1))


def _grid(shape, block_rows):
    """``block_rows`` is an already-resolved divisor (callers go through
    ``_block_rows``)."""
    return (shape[0] // block_rows,)


def _bspec(block_rows, cols):
    return pl.BlockSpec((block_rows, cols), lambda i: (i, 0))


# --- kernel bodies ----------------------------------------------------------

def _copy_kernel(a_ref, c_ref):
    c_ref[...] = a_ref[...]


def _mul_kernel(c_ref, b_ref, *, scalar):
    b_ref[...] = c_ref[...] * scalar


def _add_kernel(a_ref, b_ref, c_ref):
    c_ref[...] = a_ref[...] + b_ref[...]


def _triad_kernel(b_ref, c_ref, a_ref, *, scalar):
    a_ref[...] = b_ref[...] + scalar * c_ref[...]


def _dot_kernel(a_ref, b_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    part = jnp.sum(a_ref[...].astype(jnp.float32)
                   * b_ref[...].astype(jnp.float32))
    acc_ref[0, 0] += part


# --- pallas_call wrappers ----------------------------------------------------

def copy(a: jax.Array, *, block_rows: int = BLOCK_ROWS,
         interpret: bool = False) -> jax.Array:
    rows, cols = a.shape
    br = _block_rows(rows, block_rows)
    return pl.pallas_call(
        _copy_kernel,
        grid=_grid(a.shape, br),
        in_specs=[_bspec(br, cols)],
        out_specs=_bspec(br, cols),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a)


def mul(c: jax.Array, scalar: float = 0.4, *,
        block_rows: int = BLOCK_ROWS, interpret: bool = False) -> jax.Array:
    rows, cols = c.shape
    br = _block_rows(rows, block_rows)
    return pl.pallas_call(
        functools.partial(_mul_kernel, scalar=scalar),
        grid=_grid(c.shape, br),
        in_specs=[_bspec(br, cols)],
        out_specs=_bspec(br, cols),
        out_shape=jax.ShapeDtypeStruct(c.shape, c.dtype),
        interpret=interpret,
    )(c)


def add(a: jax.Array, b: jax.Array, *, block_rows: int = BLOCK_ROWS,
        interpret: bool = False) -> jax.Array:
    rows, cols = a.shape
    br = _block_rows(rows, block_rows)
    return pl.pallas_call(
        _add_kernel,
        grid=_grid(a.shape, br),
        in_specs=[_bspec(br, cols), _bspec(br, cols)],
        out_specs=_bspec(br, cols),
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=interpret,
    )(a, b)


def triad(b: jax.Array, c: jax.Array, scalar: float = 0.4, *,
          block_rows: int = BLOCK_ROWS, interpret: bool = False) -> jax.Array:
    rows, cols = b.shape
    br = _block_rows(rows, block_rows)
    return pl.pallas_call(
        functools.partial(_triad_kernel, scalar=scalar),
        grid=_grid(b.shape, br),
        in_specs=[_bspec(br, cols), _bspec(br, cols)],
        out_specs=_bspec(br, cols),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=interpret,
    )(b, c)


def dot(a: jax.Array, b: jax.Array, *, block_rows: int = BLOCK_ROWS,
        interpret: bool = False) -> jax.Array:
    rows, cols = a.shape
    br = _block_rows(rows, block_rows)
    out = pl.pallas_call(
        _dot_kernel,
        grid=_grid(a.shape, br),
        in_specs=[_bspec(br, cols), _bspec(br, cols)],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[0, 0]
