"""Jitted public wrappers for the BabelStream-TPU suite.

``interpret=None`` auto-selects: real Pallas lowering on TPU backends,
interpret mode (Python execution of the kernel body) on CPU — which is how
this container validates the kernels.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.stream import stream


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@partial(jax.jit, static_argnames=("interpret",))
def stream_copy(a, interpret=None):
    return stream.copy(a, interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def stream_mul(c, interpret=None):
    return stream.mul(c, interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def stream_add(a, b, interpret=None):
    return stream.add(a, b, interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def stream_triad(b, c, interpret=None):
    return stream.triad(b, c, interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("interpret",))
def stream_dot(a, b, interpret=None):
    return stream.dot(a, b, interpret=_auto_interpret(interpret))
