from repro.kernels.stream.ops import (  # noqa: F401
    stream_add, stream_copy, stream_dot, stream_mul, stream_triad)
