"""Jitted wrapper for the Pallas flash attention kernel.

Forward runs the Pallas kernel (interpret mode on CPU); backward falls back
to the custom-VJP jnp flash (same math, O(S) memory).
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention import flash as _flash
from repro.models.flash import flash_attention_ref


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_vjp(q, k, v, causal, block_q, block_kv):
    return _flash.flash_attention_fwd(
        q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
        interpret=_auto_interpret(None))


def _fwd(q, k, v, causal, block_q, block_kv):
    out = _flash_vjp(q, k, v, causal, block_q, block_kv)
    return out, (q, k, v)


def _bwd(causal, block_q, block_kv, res, dout):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: flash_attention_ref(
            q_, k_, v_, causal, block_q, block_kv), q, k, v)
    return vjp(dout)


_flash_vjp.defvjp(_fwd, _bwd)


@partial(jax.jit, static_argnames=("causal", "block_q", "block_kv"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 512,
                    block_kv: int = 512):
    """q (B,S,H,D); k, v (B,T,H,D) (kv repeated to H heads)."""
    S, T = q.shape[1], k.shape[1]
    bq = min(block_q, S)
    bkv = min(block_kv, T)
    return _flash_vjp(q, k, v, causal, bq, bkv)
