"""Pure-jnp oracle for the Pallas flash kernel: the direct softmax attention
(repro.models.attention.direct_attention) and the chunked custom-VJP flash
(repro.models.flash.flash_attention_ref) — all three must agree."""
from repro.models.attention import direct_attention  # noqa: F401
from repro.models.flash import flash_attention_ref  # noqa: F401
