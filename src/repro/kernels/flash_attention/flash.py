"""Flash attention forward as a Pallas TPU kernel.

TPU adaptation (vs the CUDA flash-attention algorithm):

  * grid = (B*H, num_q_blocks, num_kv_blocks) — the kv dimension is the
    MINOR grid axis, so for a fixed q block the kernel visits kv blocks
    sequentially (TPU grids execute in order on a core) and the online
    softmax state lives in VMEM scratch across those grid steps.
  * block shapes are MXU/VPU aligned: q/k/v tiles (block_q x d) with d
    padded to 128 lanes; the score tile (block_q x block_kv) hits the MXU
    as a [bq, d] x [d, bkv] pass.
  * causal skipping: fully-masked blocks are skipped with ``pl.when``
    (no FLOPs issued), the diagonal block applies the triangular mask —
    mirroring the STATIC triangular enumeration of the jnp reference.
  * VMEM budget: (block_q + 2*block_kv) * d * 4B + block_q*block_kv*4B
    — default 512x512xd=128 fits comfortably in the ~16 MiB v5e VMEM.

The backward pass uses the custom-VJP jnp implementation
(repro/models/flash.py) — on-TPU backward kernels would follow the same
two-pass structure.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, block_q: int, block_kv: int,
            num_kv: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # block is live iff some q position >= some k position
        run = kj * block_kv <= qi * block_q + block_q - 1

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                 # (bq, d)
        k = k_ref[0].astype(jnp.float32)                 # (bkv, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            kpos = kj * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(kj == num_kv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, block_q: int = 512,
                        block_kv: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q (B, S, H, D); k, v (B, T, H, D) with kv heads already repeated.
    Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    T = k.shape[1]
    bq = min(block_q, S)
    bkv = min(block_kv, T)
    assert S % bq == 0 and T % bkv == 0, (S, bq, T, bkv)
    scale = 1.0 / math.sqrt(D)

    # (B*H, S, D) layout: batch*head major, MXU-aligned minor dims
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, T, D)

    grid = (B * H, S // bq, T // bkv)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, block_q=bq, block_kv=bkv,
        num_kv=T // bkv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running row max
            pltpu.VMEM((bq, 1), jnp.float32),      # running row sum
            pltpu.VMEM((bq, D), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
