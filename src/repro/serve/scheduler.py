"""Tick scheduler: packs chunked-prefill and decode work into each engine
tick under page-pool pressure.

Every ``PagedEngine`` tick runs ONE fused ``decode_many_paged`` chunk of
``cfg.prefill_chunk`` compiled scan steps — the compile universe is exactly
one module, so scheduling freedom lives entirely in the PER-STEP ACTIVE
MASK: slot ``i`` advances for the first ``steps[i] <= chunk`` steps of the
tick and idles (null-page appends, frozen length) for the rest.

The scheduler turns the old all-or-nothing reservation — a slot either got
its whole chunk's pages or sat out the tick — into packing:

  * PARTIAL GRANTS — a slot whose full chunk does not fit the free list is
    granted as many steps as its pages allow instead of stalling outright,
    so prefill keeps streaming through partially-idle chunks;
  * COW PRIVATIZATION — before granting steps that would append into a
    page shared with another slot (refcount > 1), the shared block is
    copy-on-write privatized; if no page is free for the copy the grant is
    clipped to the page boundary (never mutating a shared page).  The
    copies are BATCHED: the per-slot loop only reserves
    (``PagedKVCache.cow_reserve`` — host bookkeeping, fresh page, table
    rewire) and the plan ends with ONE ``cow_flush`` device dispatch for
    every page the tick privatizes, regardless of how many slots or
    blocks are involved;
  * FAIRNESS (``cfg.fairness``) — page-grant order: ``"least-served"``
    gives pages to the slot with the fewest fresh tokens appended so far
    (a long prefill cannot starve late joiners), ``"slot-order"`` is the
    legacy first-fit by slot index;
  * BUDGET (``cfg.tick_budget``) — caps the fresh tokens appended per tick
    across all slots (0 = uncapped), smoothing page consumption so
    admissions always find headroom.

The scheduler owns allocation policy only: it mutates the ``PagedKVCache``
through ``ensure()`` / ``cow()`` and returns a ``TickPlan``; the engine
owns the device step and the request lifecycle.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.serve.cache import PagedKVCache


@dataclasses.dataclass
class TickPlan:
    """One tick's work assignment.  The engine uploads ``steps`` (B ints)
    and the per-step mask is built ON DEVICE; ``active`` is derived lazily
    for tests/introspection and never materialized on the tick path."""
    steps: np.ndarray          # (B,) int32 — fused steps granted per slot
    chunk: int                 # scan steps in the tick's fused cell
    stalled: int = 0           # active slots that wanted steps but got none
    cow_copies: int = 0        # pages privatized for this tick's appends

    @property
    def active(self) -> np.ndarray:
        """(chunk, B) bool per-step active mask (derived from steps)."""
        return np.arange(self.chunk)[:, None] < self.steps[None, :]

    @property
    def any_work(self) -> bool:
        return bool(self.steps.any())


class TickScheduler:
    """Allocates each tick's per-slot step grants (see module docstring)."""

    def __init__(self, fairness: str = "least-served", tick_budget: int = 0):
        if fairness not in ("least-served", "slot-order"):
            raise ValueError(f"unknown fairness policy: {fairness!r}")
        self.fairness = fairness
        self.tick_budget = tick_budget

    def _order(self, slots) -> List[int]:
        idx = range(len(slots))
        if self.fairness == "least-served":
            return sorted(idx, key=lambda i: (slots[i].served, i))
        return list(idx)

    def plan(self, slots, kv: PagedKVCache, chunk: int) -> TickPlan:
        """Grant steps slot by slot in fairness order.  For each slot:
        cap the want at its remaining work (budget + unfed prompt — chunk
        overshoot past the request's last kept token lands on the null
        page and needs no pages), privatize shared blocks the appends
        would touch, then reserve pages for the largest feasible grant."""
        B = len(slots)
        steps = np.zeros((B,), np.int32)
        budget = self.tick_budget if self.tick_budget > 0 else chunk * B
        stalled = 0
        cows = 0
        for i in self._order(slots):
            slot = slots[i]
            if not slot.active or budget <= 0:
                continue
            remaining = len(slot.forced) + slot.budget - len(slot.out)
            want = min(chunk, remaining, budget)
            if want <= 0:
                continue
            length = int(kv.length[i])
            # COW FIRST, then reserve: privatizing a shared block needs a
            # free page, and ensure() extending the table could consume
            # the last one — COW-before-ensure lets the slot privatize
            # and advance within its existing pages instead of hoarding a
            # fresh page it cannot write past (regression-tested).  Only
            # RESERVED here (host bookkeeping); the one batched device
            # copy for every page the tick privatizes is flushed below.
            for b in kv.shared_blocks(i, length, length + want):
                if kv.cow_reserve(i, b):
                    cows += 1
                else:
                    # no page free for the copy: stop before the shared
                    # block — a shared page is never appended to
                    want = max(0, b * kv.page - length)
                    break
            granted = 0
            for s in range(want, 0, -1):
                if kv.ensure(i, length + s):
                    granted = s
                    break
            if granted == 0:
                stalled += 1
            steps[i] = granted
            budget -= granted
        kv.cow_flush()                  # ONE device copy for the whole tick
        return TickPlan(steps=steps, chunk=chunk, stalled=stalled,
                        cow_copies=cows)
