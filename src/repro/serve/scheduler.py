"""Tick scheduler: packs chunked-prefill and decode work into each engine
tick under page-pool pressure.

Every ``PagedEngine`` tick runs at most TWO fused cells: the ragged
multi-token PREFILL LANE (``prefill_many_paged``: one kernel step appends
and attends a chunk of up to ``prefill_tokens`` prompt tokens per slot) and
the DECODE cell (``decode_many_paged``: ``cfg.prefill_chunk`` compiled scan
steps under a per-step active mask).  The compile universe is exactly those
two modules, so scheduling freedom lives entirely in the per-slot GRANTS:

  * PREFILL GRANTS (``prefill_tokens`` > 0) — a slot with unfed prompt
    tokens is granted a chunk of up to ``prefill_tokens`` of them, served
    by ONE prefill-lane kernel step instead of one decode step per token
    (admission latency stops scaling with prompt length).  Grants are
    PAGE-ALIGNED where possible: a chunk that does not drain the prompt
    is clipped to end on a page boundary whenever the boundary is
    reachable, so in the common case mid-prompt chunks never leave a
    partially written page and a later sharer's copy-on-write boundary
    coincides with a chunk boundary.  Alignment is a COW-MINIMIZING
    POLICY, not the safety mechanism: a chunk that cannot reach a
    boundary (``prefill_tokens`` < page remainder, or a partial grant
    under pool pressure) and every prompt's final ragged chunk do end
    mid-page, and correctness then rests — exactly as on the decode
    path — on ``_grant()`` privatizing every shared block an append
    would touch BEFORE the tick.
  * PARTIAL GRANTS — a slot whose full chunk does not fit the free list is
    granted as many steps/tokens as its pages allow instead of stalling
    outright, so prefill keeps streaming through partially-idle chunks;
  * COW PRIVATIZATION — before granting steps that would append into a
    page shared with another slot (refcount > 1), the shared block is
    copy-on-write privatized; if no page is free for the copy the grant is
    clipped to the page boundary (never mutating a shared page).  The
    copies are BATCHED: the per-slot loop only reserves
    (``PagedKVCache.cow_reserve`` — host bookkeeping, fresh page, table
    rewire) and the plan ends with ONE ``cow_flush`` device dispatch for
    every page the tick privatizes, across BOTH lanes;
  * FAIRNESS (``cfg.fairness``) — grant order: ``"least-served"`` gives
    pages to the slot with the fewest fresh tokens appended so far (a
    long prefill cannot starve late joiners), ``"slot-order"`` is the
    legacy first-fit by slot index;
  * BUDGET (``cfg.tick_budget``) — caps the fresh tokens appended per tick
    across all slots and both lanes (0 = uncapped), smoothing page
    consumption so admissions always find headroom.

With ``prefill_tokens == 0`` (prefill lane disabled) prompts route through
the decode cell as forced tokens — the legacy prefill-by-decode path, kept
for measured comparison.

The scheduler owns allocation policy only: it mutates the ``PagedKVCache``
through ``ensure()`` / ``cow_reserve()`` and returns a ``TickPlan``; the
engine owns the device steps and the request lifecycle.

RETAINED-POOL RECLAMATION rides the same reserve path: ``ensure()`` and
``cow_reserve()`` allocate through the cache's ``_alloc_page`` choke
point, which lazily reclaims cross-lifetime RETAINED pages (dead donors'
frozen prefixes, serve/cache.py) when the free list runs dry.  A grant
therefore drains the retained pool BEFORE it reports a stall and before
the engine ever considers preempting a live slot — retained pages are a
cache, never capacity pressure.  ``TickPlan.reclaimed`` reports how many
retained pages this tick's grants consumed.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.serve.cache import PagedKVCache


@dataclasses.dataclass
class TickPlan:
    """One tick's work assignment.  The engine uploads ``steps`` (B ints)
    for the decode cell (the per-step mask is built ON DEVICE) and
    ``prefill`` (B ints) alongside the ragged (B, T) token block for the
    prefill lane; ``active`` is derived lazily for tests/introspection and
    never materialized on the tick path."""
    steps: np.ndarray          # (B,) int32 — fused decode steps per slot
    chunk: int                 # scan steps in the tick's decode cell
    prefill: np.ndarray = None  # (B,) int32 — prefill-lane tokens per slot
    stalled: int = 0           # active slots that wanted work but got none
    cow_copies: int = 0        # pages privatized for this tick's appends
    reclaimed: int = 0         # retained pages reclaimed to serve grants

    def __post_init__(self):
        if self.prefill is None:
            self.prefill = np.zeros_like(self.steps)

    @property
    def active(self) -> np.ndarray:
        """(chunk, B) bool per-step decode mask (derived from steps)."""
        return np.arange(self.chunk)[:, None] < self.steps[None, :]

    @property
    def any_work(self) -> bool:
        return bool(self.steps.any()) or bool(self.prefill.any())


PREEMPT_POLICIES = ("fewest-tokens", "most-pages")


class TickScheduler:
    """Allocates each tick's per-slot grants (see module docstring) and
    picks preemption victims when the engine must reclaim capacity."""

    def __init__(self, fairness: str = "least-served", tick_budget: int = 0,
                 preempt_policy: str = "fewest-tokens"):
        if fairness not in ("least-served", "slot-order"):
            raise ValueError(f"unknown fairness policy: {fairness!r}")
        if preempt_policy not in PREEMPT_POLICIES:
            raise ValueError(f"unknown preempt policy: {preempt_policy!r} "
                             f"(choices: {PREEMPT_POLICIES})")
        self.fairness = fairness
        self.tick_budget = tick_budget
        self.preempt_policy = preempt_policy

    def _order(self, slots) -> List[int]:
        idx = range(len(slots))
        if self.fairness == "least-served":
            return sorted(idx, key=lambda i: (slots[i].served, i))
        return list(idx)

    def pick_victim(self, slots, kv: PagedKVCache,
                    generated=None, exclude=()) -> int:
        """Choose the slot to PREEMPT when no slot can be granted work.

        ``"fewest-tokens"`` (default): the slot with the fewest tokens
        generated so far — it has the least recompute to redo — breaking
        ties toward the MOST pages held (preempting it frees the most
        capacity), then lowest slot index.  ``"most-pages"`` inverts the
        priority: free the most pages first, fewest tokens as the tie
        break.  ``generated`` maps slot index -> total tokens generated
        across preemptions (the engine passes emitted + current out; falls
        back to the slot's current out).  Returns -1 if no active slot is
        eligible."""
        cand = [i for i, s in enumerate(slots)
                if s.active and i not in exclude]
        if not cand:
            return -1

        def gen(i):
            if generated is not None and i in generated:
                return generated[i]
            return len(slots[i].out)

        if self.preempt_policy == "most-pages":
            key = lambda i: (-len(kv.owned[i]), gen(i), i)  # noqa: E731
        else:
            key = lambda i: (gen(i), -len(kv.owned[i]), i)  # noqa: E731
        return min(cand, key=key)

    def _grant(self, kv: PagedKVCache, i: int, length: int, want: int):
        """Privatize shared blocks the appends would touch, then reserve
        pages for the largest feasible grant.  COW FIRST, then reserve:
        privatizing a shared block needs a free page, and ensure()
        extending the table could consume the last one — COW-before-ensure
        lets the slot privatize and advance within its existing pages
        instead of hoarding a fresh page it cannot write past
        (regression-tested).  Only RESERVED here (host bookkeeping); the
        one batched device copy for every page the tick privatizes is
        flushed at the end of the plan.  A reservation the granted range
        no longer reaches is ROLLED BACK (``cow_rollback``): under pool
        pressure a page privatized ahead of an append that will never
        come is a page stolen from whoever could actually advance.
        Returns (granted, cows)."""
        cows = 0
        for b in kv.shared_blocks(i, length, length + want):
            if kv.cow_reserve(i, b):
                cows += 1
            else:
                # no page free for the copy: stop before the shared
                # block — a shared page is never appended to
                want = max(0, b * kv.page - length)
                break
        granted = 0
        for s in range(want, 0, -1):
            if kv.ensure(i, length + s):
                granted = s
                break
        if cows:
            # blocks past the last one the granted appends touch: undo
            last_blk = (length + granted - 1) // kv.page if granted else -1
            cows -= kv.cow_rollback(i, last_blk + 1)
        return granted, cows

    def plan(self, slots, kv: PagedKVCache, chunk: int,
             prefill_tokens: int = 0) -> TickPlan:
        """Grant work slot by slot in fairness order.  A slot with unfed
        prompt tokens gets a PREFILL-LANE grant (page-aligned chunk of up
        to ``prefill_tokens``); everyone else gets decode steps capped at
        remaining work (budget + unfed prompt when the lane is off — chunk
        overshoot past the request's last kept token lands on the null
        page and needs no pages)."""
        B = len(slots)
        steps = np.zeros((B,), np.int32)
        prefill = np.zeros((B,), np.int32)
        budget = self.tick_budget if self.tick_budget > 0 \
            else (chunk + prefill_tokens) * B
        stalled = 0
        cows = 0
        reclaimed0 = kv.retained_reclaimed_pages
        for i in self._order(slots):
            slot = slots[i]
            if not slot.active or budget <= 0:
                continue
            length = int(kv.length[i])
            if prefill_tokens > 0 and slot.prompt_left > 0:
                want = min(prefill_tokens, slot.prompt_left, budget)
                if want < slot.prompt_left:
                    # page-aligned chunk: end on a page boundary unless
                    # the grant cannot even reach one
                    aligned = want - (length + want) % kv.page
                    if aligned > 0:
                        want = aligned
                granted, c = self._grant(kv, i, length, want)
                cows += c
                if granted == 0:
                    stalled += 1
                prefill[i] = granted
                budget -= granted
                continue
            remaining = len(slot.forced) + slot.budget - len(slot.out)
            want = min(chunk, remaining, budget)
            if want <= 0:
                continue
            granted, c = self._grant(kv, i, length, want)
            cows += c
            if granted == 0:
                stalled += 1
            steps[i] = granted
            budget -= granted
        kv.cow_flush()                  # ONE device copy for the whole tick
        return TickPlan(steps=steps, chunk=chunk, prefill=prefill,
                        stalled=stalled, cow_copies=cows,
                        reclaimed=kv.retained_reclaimed_pages - reclaimed0)
