"""Paged KV cache manager: a refcounted page pool with prefix sharing and
copy-on-write.

Device state (``Model.init_paged_cache``): k/v page pools
(L, num_pages, page, KV, hd), a block table (B, max_blocks) int32 and
per-slot lengths (B,) int32.  The manager owns the host mirrors, the page
FREE LIST and the per-page REFCOUNTS; page 0 is the reserved NULL page —
never allocated, the landing zone for inactive slots' appends and
unallocated table entries (so the Pallas kernel's scalar-prefetched DMA
address is always valid).

Prefix sharing maps ONE physical page into SEVERAL block tables
(``share()``): a request admitted with a prompt prefix already resident in
a live slot's pages references those pages instead of recomputing them —
rope positions are request-relative in the paged decode path, so the K/V
rows for an identical token prefix are bit-identical across slots and the
reference is exact, not approximate.  Pages referenced more than once are
IMMUTABLE: before any slot may append into a page with refcount > 1 the
scheduler reserves a copy-on-write (``cow_reserve``: fresh page allocated,
table rewired, copy queued) and the tick's reservations are flushed in ONE
batched donated device dispatch (``cow_flush`` — a tick privatizing N pages
issues one copy call whose census bytes are exactly N x page_bytes,
standalone and in-fusion), rewiring only the writing slot's table entries.
Eviction decrements refcounts; a page returns to the free list only when
its refcount reaches zero, so evicting a sharer never frees a page another
slot still references.

CROSS-LIFETIME RETENTION (``retain=True``): when a slot is freed its
page-aligned token-prefix pages are not returned to the free list but
moved to a RETAINED pool — refcount 0 (no block table references them),
content frozen, keyed by the same rolling-hash prefix digests the
engine's live-donor index uses (``prefix_digests``).  A later admission
whose prompt starts with the same tokens adopts those pages by reference
(``match_retained`` / ``adopt_retained``) even though the donor is long
gone — request-relative rope makes the frozen K/V rows exact for any
adopter.  Retained pages are reclaimable BY DEFINITION: the allocation
choke point (``_alloc_page``, used by ``ensure``/``cow_reserve``/
``seize_pages``) lazily reclaims them under pool pressure (LRU or
digest-popularity order, ``retain_policy``), so the scheduler's reserve
path drains the retained pool before it ever stalls or preempts, and a
fault-plan squeeze can seize straight through it.

Invariants (``check()``, fuzz-asserted by the property harness): every
page's refcount equals the number of block-table references to it; the
null page plus every referenced page plus the free list plus the seized
set plus the retained-only pages cover [0, num_pages) exactly — no page
is ever double-allocated, leaked, or freed while referenced or retained.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, Iterable, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model

# rolling-hash prefix digests — shared with the engine's live-donor index
# (serve/engine.py) so a retained entry and a live slot hash identically
_HASH_MUL = 1_000_003
_HASH_MOD = (1 << 61) - 1


def digest_step(h: int, tok: int) -> int:
    """One token step of the rolling prefix digest (+1 biases token 0)."""
    return (h * _HASH_MUL + int(tok) + 1) % _HASH_MOD


def prefix_digests(tokens, page: int) -> List[int]:
    """Digest of every PAGE-ALIGNED prefix of ``tokens``: out[j] covers
    tokens[:(j+1)*page].  Only full pages are digested — retention is
    page-granular (a partial trailing page is recomputed by the adopter)."""
    out: List[int] = []
    h = 0
    for idx, t in enumerate(tokens):
        h = digest_step(h, t)
        if (idx + 1) % page == 0:
            out.append(h)
    return out


@dataclasses.dataclass
class RetainedPrefix:
    """A dead slot's page-aligned prompt prefix, held for re-sharing.

    ``tokens`` is the exact token prefix (len == page * len(pages)),
    ``pages`` the physical pages that hold its K/V rows, ``keys`` the
    (n_pages, digest) lookup keys this entry is registered under (one per
    page boundary, so a shorter prompt can still hit a longer entry).
    ``stamp`` is the retention clock at last touch (LRU), ``hits`` the
    number of adoptions (digest popularity)."""
    tokens: List[int]
    pages: List[int]
    keys: List[Tuple[int, int]]
    stamp: int
    hits: int = 0


def _copy_pages(pool, dst, src):
    """Device page copy on the stacked (L, num_pages, page, KV, hd) pool:
    rows of pages ``src`` are written into pages ``dst`` (both (n,) int32).
    Jitted with a donated pool so the copy is in place — the HLO is a
    page-sized gather + scatter whose census bytes scale with the pages
    copied, never with the pool."""
    return pool.at[:, dst].set(pool[:, src])


def _copy_pages_both(k, v, dst, src):
    """COW copies k AND v in one dispatch (both pools donated)."""
    return _copy_pages(k, dst, src), _copy_pages(v, dst, src)


def _copy_pages_quant(k, v, ks, vs, dst, src):
    """Quantized COW: k, v AND their per-row scale pools move in the same
    single dispatch — int8 rows + f32 scales are copied verbatim, so the
    privatized page is bit-exact (no requantization on the copy path)."""
    return (_copy_pages(k, dst, src), _copy_pages(v, dst, src),
            _copy_pages(ks, dst, src), _copy_pages(vs, dst, src))


class PagedKVCache:
    """Host-side manager for the paged decode cache (see module docstring)."""

    RETAIN_POLICIES = ("lru", "popularity")

    def __init__(self, model: Model, max_batch: int, max_seq: int, *,
                 page_size: int = 16, max_blocks: int = 0,
                 num_pages: int = 0, retain: bool = False,
                 retain_cap: int = 0, retain_policy: str = "lru"):
        if retain_policy not in self.RETAIN_POLICIES:
            raise ValueError(f"unknown retain_policy {retain_policy!r}; "
                             f"expected one of {self.RETAIN_POLICIES}")
        self.page = page_size
        self.max_blocks = max_blocks or -(-max_seq // page_size)
        # default pool: every slot can hold its full table + the null page
        self.num_pages = num_pages or (max_batch * self.max_blocks + 1)
        self.B = max_batch
        arrays = model.init_paged_cache(max_batch, self.max_blocks,
                                        self.page, self.num_pages)
        self.k = arrays["k"]
        self.v = arrays["v"]
        # quantized pools (cfg.kv_dtype == "int8") carry per-row-per-head
        # f32 scale pools that travel WITH their pages through every copy
        self.k_scale = arrays.get("k_scale")
        self.v_scale = arrays.get("v_scale")
        self.quantized = self.k_scale is not None
        self.table = np.zeros((max_batch, self.max_blocks), np.int32)
        self.length = np.zeros((max_batch,), np.int32)
        self.owned: List[List[int]] = [[] for _ in range(max_batch)]
        self.refcount = np.zeros((self.num_pages,), np.int32)
        self.free: List[int] = list(range(self.num_pages - 1, 0, -1))
        self._gather = jax.jit(lambda pool, perm: pool[:, perm],
                               donate_argnums=(0,))
        self._copy = jax.jit(_copy_pages_both, donate_argnums=(0, 1))
        self._copy_quant = jax.jit(_copy_pages_quant,
                                   donate_argnums=(0, 1, 2, 3))
        # per-page bytes across BOTH pools (+ scale pools when quantized) —
        # derived from the ACTUAL pool itemsize so census byte gates stay
        # exact for any kv_dtype
        L = self.k.shape[0]
        self.page_bytes = 2 * L * self.page * self.k.shape[3] \
            * self.k.shape[4] * self.k.dtype.itemsize
        if self.quantized:
            self.page_bytes += 2 * L * self.page * self.k_scale.shape[3] \
                * self.k_scale.dtype.itemsize
        self.cow_copies = 0
        self.cow_bytes = 0
        self.cow_dispatches = 0          # device copy calls (1 per flush)
        self.shared_pages = 0            # share() page references handed out
        # (dst, src, slot, blk) page pairs reserved by cow_reserve()
        # awaiting the one batched device copy of the tick (cow_flush);
        # slot/blk tag each pair so a reservation can be rolled back
        # (cow_rollback) or cancelled when its slot is freed mid-tick
        # (free_slot) without orphaning a pending copy into a free page
        self._pending_cow: List[Tuple[int, int, int, int]] = []
        # pages temporarily seized from the free list by the fault-
        # injection harness (serve/faults.py pool-pressure events): not
        # allocatable, not referenced — check() accounts for them so the
        # pool partition invariant survives injected pressure
        self.seized: Set[int] = set()
        # slot rows whose table/length changed since the engine last synced
        # its device mirrors (admission, COW, eviction, defrag mark these;
        # the engine uploads ONLY these rows, then clears the set)
        self.dirty: Set[int] = set(range(max_batch))
        # -- cross-lifetime retention (see module docstring) ---------------
        self.retain = retain
        self.retain_cap = retain_cap          # max retained-ONLY pages; 0 = pool-bounded
        self.retain_policy = retain_policy
        self.retained: List[RetainedPrefix] = []
        # (n_pages, digest) -> entries registered under that page boundary;
        # several entries can share a key (same prefix, different lengths)
        self._retained_keys: Dict[Tuple[int, int], List[RetainedPrefix]] = {}
        # per-page count of RetainedPrefix entries holding the page — a
        # page leaves the free-list candidate set while this OR refcount
        # is nonzero (overlapping entries may retain the same page)
        self.retained_refs = np.zeros((self.num_pages,), np.int32)
        self._retain_clock = 0
        self.retained_hits = 0               # adopt_retained() calls
        self.retained_hit_tokens = 0         # tokens re-shared from retained
        self.retained_reclaimed_pages = 0    # pages freed under pressure
        self.retained_dropped = 0            # entries reclaimed/flushed

    # -- allocation ----------------------------------------------------------

    def _alloc_page(self) -> Optional[int]:
        """THE allocation choke point: pop a free page, lazily reclaiming
        retained entries (policy order) when the free list is dry.  Every
        reserve-path primitive (``ensure``, ``cow_reserve``,
        ``seize_pages``) allocates through here, so retained pages are
        exactly as allocatable as free pages — the scheduler never stalls
        or preempts while the retained pool could still be drained."""
        if not self.free and self.retained:
            self.reclaim_retained(1)
        return self.free.pop() if self.free else None

    def ensure(self, i: int, n_tokens: int) -> bool:
        """Allocate pages so slot ``i`` can hold ``n_tokens`` tokens.
        Returns False (allocating nothing further) if the free list runs
        dry — the scheduler then grants the slot fewer steps (or stalls it)
        until eviction frees pages."""
        need = -(-n_tokens // self.page)
        if need > self.max_blocks:
            raise RuntimeError(
                f"slot {i} needs {need} blocks > max_blocks="
                f"{self.max_blocks} (request exceeds max_seq)")
        while len(self.owned[i]) < need:
            pg = self._alloc_page()
            if pg is None:
                return False
            self.refcount[pg] = 1
            self.table[i, len(self.owned[i])] = pg
            self.owned[i].append(pg)
            self.dirty.add(i)
        return True

    def share(self, dst: int, donor: int, n_tokens: int) -> None:
        """Map the donor's pages covering token positions [0, n_tokens)
        into slot ``dst``'s block table (refcount bump, no allocation, no
        copy) and set its length.  ``dst`` must be empty.  The trailing
        page may be partially filled — ``dst`` reads only rows below its
        own length there, and its first append into it triggers ``cow()``.
        """
        assert not self.owned[dst], "share() target slot must be empty"
        need = -(-n_tokens // self.page)
        pages = self.owned[donor][:need]
        assert len(pages) == need, "donor does not cover the shared prefix"
        for j, pg in enumerate(pages):
            self.table[dst, j] = pg
            self.refcount[pg] += 1
        self.owned[dst] = list(pages)
        self.length[dst] = n_tokens
        self.shared_pages += need
        self.dirty.add(dst)

    def cow_reserve(self, i: int, blk: int) -> bool:
        """Reserve a copy-on-write of block ``blk`` of slot ``i``: if the
        page is shared (refcount > 1), allocate a fresh destination page,
        rewire only this slot's table entry, and QUEUE the (dst, src) page
        copy for the tick's single batched device dispatch (``cow_flush``).
        All host bookkeeping (refcounts, tables, counters) happens here;
        only the device copy is deferred — nothing reads or writes the
        reserved pages until the flush lands, because the scheduler flushes
        before the engine issues the tick's decode dispatch.  Returns False
        if the free list is dry (the scheduler stalls the slot until
        eviction frees a page).  No-op on exclusively-owned pages."""
        pg = self.owned[i][blk]
        if self.refcount[pg] <= 1:
            return True
        q = self._alloc_page()
        if q is None:
            return False
        self._pending_cow.append((q, pg, i, blk))
        self.refcount[pg] -= 1
        self.refcount[q] = 1
        self.owned[i][blk] = q
        self.table[i, blk] = q
        self.cow_copies += 1
        self.cow_bytes += self.page_bytes
        self.dirty.add(i)
        return True

    def cow_flush(self) -> int:
        """Privatize every page queued by ``cow_reserve`` in ONE donated
        gather/scatter dispatch over both pools (the batched COW: a tick
        that privatizes N pages costs one device call, not N).  The batch
        is NOT padded — the device moves exactly pages_copied x page_bytes
        (the census-pinned claim); the copy program compiles once per
        distinct batch size, bounded by the pages a single tick can touch
        (B x (ceil(chunk/page) + 1)); ``warm_copy`` pre-compiles the
        common small sizes so typical flushes never compile mid-tick.
        Returns the pages copied."""
        if not self._pending_cow:
            return 0
        dst = jnp.asarray([p[0] for p in self._pending_cow], jnp.int32)
        src = jnp.asarray([p[1] for p in self._pending_cow], jnp.int32)
        if self.quantized:               # scales move with their pages
            (self.k, self.v, self.k_scale, self.v_scale) = self._copy_quant(
                self.k, self.v, self.k_scale, self.v_scale, dst, src)
        else:
            self.k, self.v = self._copy(self.k, self.v, dst, src)
        n = len(self._pending_cow)
        self._pending_cow.clear()
        self.cow_dispatches += 1
        return n

    def cow_rollback(self, i: int, from_blk: int = 0) -> int:
        """Undo slot ``i``'s PENDING copy-on-write reservations at block
        indices >= ``from_blk``: the shared mapping is restored (source
        refcount bumped back, table/owned rewired to the original page)
        and the reserved destination page returns to the free list before
        any device copy was issued.  The scheduler calls this when a grant
        shrinks below a block it already reserved — under pool pressure
        the reserved page must go to a slot that can actually advance, not
        sit privatized ahead of an append that will never reach it.
        Returns the number of reservations undone."""
        kept, undone = [], 0
        for (q, pg, s, b) in self._pending_cow:
            if s == i and b >= from_blk:
                self.refcount[pg] += 1
                self.refcount[q] = 0
                self.free.append(q)
                self.owned[i][b] = pg
                self.table[i, b] = pg
                self.cow_copies -= 1
                self.cow_bytes -= self.page_bytes
                undone += 1
            else:
                kept.append((q, pg, s, b))
        self._pending_cow = kept
        if undone:
            self.dirty.add(i)
        return undone

    def cow_many(self, items: Iterable[Tuple[int, int]]) -> int:
        """Batched copy-on-write: privatize ALL shared (slot, blk) pairs in
        one device dispatch.  Pairs whose page is already exclusive are
        skipped; a dry free list stops the batch at the first unservable
        pair (pairs after it are NOT privatized).  Returns the number of
        pages copied.  Convenience wrapper over the reserve/flush pair — a
        caller that must react per pair (e.g. the tick scheduler clipping
        a slot's grant when its COW cannot be served) calls
        ``cow_reserve`` itself and flushes once at the end of the plan."""
        for i, blk in items:
            if not self.cow_reserve(i, blk):
                break
        return self.cow_flush()

    def warm_copy(self, sizes: Tuple[int, ...] = (1, 2)) -> None:
        """Pre-compile the batched page copy for the given batch sizes
        (null-page self-copies: page 0 onto page 0) so the common COW
        flush sizes never pay an XLA compile inside a serving tick —
        compiled against the ACTUAL pool dtype (quantized pools warm the
        four-pool copy cell).  Counters are untouched — this is not a COW.
        """
        for n in sizes:
            idx = jnp.zeros((n,), jnp.int32)
            if self.quantized:
                (self.k, self.v, self.k_scale,
                 self.v_scale) = self._copy_quant(
                    self.k, self.v, self.k_scale, self.v_scale, idx, idx)
            else:
                self.k, self.v = self._copy(self.k, self.v, idx, idx)

    def cow(self, i: int, blk: int) -> bool:
        """Single-page copy-on-write (reserve + immediate flush) — kept for
        callers outside the tick scheduler's batched path."""
        ok = self.cow_reserve(i, blk)
        self.cow_flush()
        return ok

    def shared_blocks(self, i: int, lo_tok: int, hi_tok: int) -> List[int]:
        """Block indices of slot ``i`` whose pages are shared (refcount > 1)
        among the blocks that appends to token positions [lo_tok, hi_tok)
        would touch — the set ``cow()`` must privatize before the tick."""
        b0 = lo_tok // self.page
        b1 = (hi_tok - 1) // self.page
        return [b for b in range(b0, min(b1, len(self.owned[i]) - 1) + 1)
                if self.refcount[self.owned[i][b]] > 1]

    def seize_pages(self, n: int) -> List[int]:
        """Fault injection (pool pressure): remove up to ``n`` pages from
        the free list into the SEIZED set — temporarily unallocatable, as
        if another tenant grabbed them.  Retained pages are reclaimable by
        definition, so a squeeze deeper than the free list drains the
        retained pool too (entries dropped through ``reclaim_retained`` —
        the digest map forgets them cleanly before their pages move).
        ``check()`` accounts for seized pages, so every invariant keeps
        holding under injected pressure.  Returns the seized page ids
        (pass them back to ``release_pages``)."""
        took = []
        while len(took) < n:
            pg = self._alloc_page()
            if pg is None:
                break
            took.append(pg)
        self.seized.update(took)
        return took

    def release_pages(self, pages: Iterable[int]) -> None:
        """Return previously seized pages to the free list."""
        for pg in pages:
            assert pg in self.seized, f"page {pg} was not seized"
            self.seized.discard(pg)
            self.free.append(pg)

    def free_slot(self, i: int, retain_tokens=None) -> None:
        """Eviction: drop slot ``i``'s references; pages whose refcount
        reaches zero go back to the free list (a page another slot still
        references — or a retained entry still holds — stays live).  Any
        PENDING copy-on-write reservation the slot holds is cancelled
        first (rolled back, not flushed): preemption/cancellation can free
        a slot mid-tick, and a pending copy into a page that just returned
        to the free list would corrupt whoever allocates it next
        (regression + fuzz pinned).

        ``retain_tokens`` (the slot's exact token history, prompt +
        emitted) opts the slot's page-aligned prefix into the retained
        pool BEFORE the references drop — cross-lifetime sharing: a later
        admission with the same prompt prefix adopts those pages even
        though this slot is gone."""
        if self._pending_cow:
            self.cow_rollback(i)
        if self.retain and retain_tokens is not None:
            self._retain_prefix(self.owned[i], retain_tokens)
        for pg in reversed(self.owned[i]):
            self.refcount[pg] -= 1
            if self.refcount[pg] == 0 and self.retained_refs[pg] == 0:
                self.free.append(pg)
        self.owned[i] = []
        self.table[i, :] = 0
        self.length[i] = 0
        self.dirty.add(i)
        if self.retain and self.retain_cap > 0:
            # cap counts retained-ONLY pages, so it must run after the
            # donor's references dropped (its pages just became refcount 0)
            self._enforce_retain_cap()

    # -- cross-lifetime retention --------------------------------------------

    def _retain_prefix(self, pages: List[int], tokens) -> None:
        """Register ``tokens``' page-aligned prefix (held in ``pages``) as
        a RetainedPrefix.  Exact duplicates (same length, same tokens) are
        touched, not re-inserted; a shorter/longer prefix of an existing
        entry coexists with it (shared physical pages carry a
        ``retained_refs`` count per entry)."""
        n_full = min(len(tokens) // self.page, len(pages))
        if n_full == 0:
            return
        toks = [int(t) for t in tokens[:n_full * self.page]]
        digests = prefix_digests(toks, self.page)
        self._retain_clock += 1
        for cand in self._retained_keys.get((n_full, digests[-1]), []):
            if cand.tokens == toks:          # exact duplicate: refresh LRU
                cand.stamp = self._retain_clock
                return
        entry = RetainedPrefix(
            tokens=toks, pages=list(pages[:n_full]),
            keys=[(j + 1, d) for j, d in enumerate(digests)],
            stamp=self._retain_clock)
        self.retained.append(entry)
        for pg in entry.pages:
            self.retained_refs[pg] += 1
        for key in entry.keys:
            self._retained_keys.setdefault(key, []).append(entry)

    def _retained_only(self) -> Set[int]:
        """Pages held ONLY by retained entries (refcount 0) — the pages a
        reclamation can actually return to the free list."""
        return {int(p) for p in np.flatnonzero(
            (self.retained_refs > 0) & (self.refcount == 0))}

    def _enforce_retain_cap(self) -> None:
        while len(self._retained_only()) > self.retain_cap:
            victims = [e for e in self._reclaim_order()
                       if self._entry_freeable(e)]
            if not victims:
                break
            self._drop_entry(victims[0])

    def _entry_freeable(self, e: RetainedPrefix) -> int:
        """Pages dropping ``e`` would return to the free list."""
        return sum(1 for pg in e.pages
                   if self.refcount[pg] == 0 and self.retained_refs[pg] == 1)

    def _reclaim_order(self) -> List[RetainedPrefix]:
        if self.retain_policy == "popularity":
            # least-adopted first; LRU breaks ties
            return sorted(self.retained, key=lambda e: (e.hits, e.stamp))
        return sorted(self.retained, key=lambda e: e.stamp)

    def _drop_entry(self, e: RetainedPrefix) -> int:
        """Forget a retained entry: unregister its digest keys, drop its
        page holds, free pages nobody else holds.  Returns pages freed."""
        self.retained.remove(e)
        for key in e.keys:
            owners = self._retained_keys[key]
            owners.remove(e)
            if not owners:
                del self._retained_keys[key]
        freed = 0
        for pg in e.pages:
            self.retained_refs[pg] -= 1
            if self.retained_refs[pg] == 0 and self.refcount[pg] == 0:
                self.free.append(pg)
                freed += 1
        self.retained_dropped += 1
        self.retained_reclaimed_pages += freed
        return freed

    def reclaim_retained(self, need: int) -> int:
        """Drop retained entries in policy order until >= ``need`` pages
        returned to the free list (or the pool is dry).  Entries whose
        pages are ALL still live (adopted by running slots) are skipped —
        dropping them frees nothing and would only forget a popular
        digest.  Adoption bumps refcount, so reclamation can never touch a
        page a live slot just re-shared."""
        freed = 0
        for e in self._reclaim_order():
            if freed >= need:
                break
            if self._entry_freeable(e) == 0:
                continue
            freed += self._drop_entry(e)
        return freed

    def flush_retained(self) -> int:
        """Drop EVERY retained entry (tests / shutdown).  Returns pages
        returned to the free list."""
        freed = 0
        for e in list(self.retained):
            freed += self._drop_entry(e)
        return freed

    def match_retained(self, prompt, cap: int):
        """Longest page-aligned retained prefix of ``prompt[:cap]``.
        Walks the rolling digest outward page by page, stopping at the
        first boundary with no registered entry (an entry registers every
        boundary it covers, so a miss at n pages rules out all longer
        matches).  The winning candidate is verified token-exact; on a
        digest collision falls back to a linear scan over all entries.
        Returns (entry, n_tokens) or (None, 0)."""
        if not self.retained:
            return None, 0
        limit = min(cap, len(prompt))
        h = 0
        best: Optional[RetainedPrefix] = None
        best_n = 0
        for idx in range(limit):
            h = digest_step(h, prompt[idx])
            if (idx + 1) % self.page:
                continue
            owners = self._retained_keys.get(((idx + 1) // self.page, h))
            if not owners:
                break
            best, best_n = owners[0], idx + 1
        if best is not None \
                and best.tokens[:best_n] != [int(t) for t in
                                             prompt[:best_n]]:
            best, best_n = None, 0           # collision: exact fallback
            for e in self.retained:
                n = 0
                for a, b in zip(e.tokens, prompt[:limit]):
                    if a != int(b):
                        break
                    n += 1
                n -= n % self.page
                if n > best_n:
                    best, best_n = e, n
        if best is None or best_n == 0:
            return None, 0
        return best, best_n

    def adopt_retained(self, dst: int, entry: RetainedPrefix,
                       n_tokens: int) -> None:
        """Cross-lifetime ``share()``: map the retained entry's pages
        covering [0, n_tokens) into empty slot ``dst``'s block table
        (refcount bump — the pages become live again) and set its length.
        Adopted pages are FULL, so the adopter never writes into them
        (appends land past the prefix); refcount > 0 also shields them
        from ``reclaim_retained`` for as long as the adopter runs."""
        assert not self.owned[dst], "adopt_retained() target must be empty"
        assert n_tokens % self.page == 0, "retained adoption is page-aligned"
        need = n_tokens // self.page
        assert need <= len(entry.pages), "entry does not cover the prefix"
        for j, pg in enumerate(entry.pages[:need]):
            self.table[dst, j] = pg
            self.refcount[pg] += 1
        self.owned[dst] = list(entry.pages[:need])
        self.length[dst] = n_tokens
        self.shared_pages += need
        self._retain_clock += 1
        entry.stamp = self._retain_clock
        entry.hits += 1
        self.retained_hits += 1
        self.retained_hit_tokens += n_tokens
        self.dirty.add(dst)

    # -- bookkeeping ----------------------------------------------------------

    @property
    def live_pages(self) -> int:
        """Distinct physical pages referenced by at least one slot."""
        return len({p for o in self.owned for p in o})

    @property
    def retained_pages(self) -> int:
        """Distinct pages held by retained entries (live or not)."""
        return len({p for e in self.retained for p in e.pages})

    @property
    def allocatable(self) -> int:
        """Pages an allocation could obtain RIGHT NOW: the free list plus
        retained-only pages (reclaimable by definition)."""
        return len(self.free) + len(self._retained_only())

    @property
    def logical_pages(self) -> int:
        """Block-table references summed over slots (>= live_pages when
        prefix sharing maps one page into several tables)."""
        return sum(len(o) for o in self.owned)

    def utilization(self) -> float:
        """Fraction of allocatable pages currently referenced by live
        slots (physical: shared pages count once)."""
        return self.live_pages / max(1, self.num_pages - 1)

    def occupancy(self) -> float:
        """Fraction of rows in live pages holding real tokens — intra-page
        fragmentation, invariant under defrag (which only renumbers)."""
        rows = self.live_pages * self.page
        # shared rows are stored once but the physical rows written are
        # exactly the DISTINCT tokens: count each live page's filled rows
        # under its furthest-advanced referent
        fill = {}
        for i in range(self.B):
            n = int(self.length[i])
            for j, pg in enumerate(self.owned[i]):
                f = min(self.page, max(0, n - j * self.page))
                fill[pg] = max(fill.get(pg, 0), f)
        return sum(fill.values()) / rows if rows else 0.0

    def check(self, allow_pending: bool = False) -> None:
        """Refcount/free-list/table invariants (cheap; the property harness
        calls this every fuzz step).  ``allow_pending=True`` checks the
        MID-PLAN state (reservations made, flush not yet issued): pending
        pairs must reference live pages only — a pending copy into or out
        of a free page is exactly the corruption ``free_slot``'s
        cancellation and ``cow_rollback`` exist to prevent."""
        if allow_pending:
            free = set(self.free)
            for (q, pg, s, b) in self._pending_cow:
                assert q not in free and pg not in free, \
                    f"pending COW ({q} <- {pg}) references a free page"
                assert self.refcount[q] == 1, \
                    f"pending COW destination {q} has refcount " \
                    f"{self.refcount[q]}"
                assert 0 <= b < len(self.owned[s]) \
                    and self.owned[s][b] == q, \
                    f"pending COW for slot {s} block {b} lost its rewire"
        else:
            assert not self._pending_cow, "unflushed COW reservations"
        refs = Counter(p for o in self.owned for p in o)
        assert 0 not in refs, "null page referenced"
        for i, o in enumerate(self.owned):
            assert len(set(o)) == len(o), f"slot {i} references a page twice"
            assert list(self.table[i, :len(o)]) == o, "table/owned drift"
            assert not self.table[i, len(o):].any(), "stale table entry"
        for p in range(1, self.num_pages):
            assert self.refcount[p] == refs.get(p, 0), \
                f"page {p}: refcount {self.refcount[p]} != " \
                f"{refs.get(p, 0)} table references"
        assert len(set(self.free)) == len(self.free), "free-list duplicate"
        assert not set(refs) & set(self.free), "page both referenced and free"
        assert not self.seized & set(refs), "seized page still referenced"
        assert not self.seized & set(self.free), "seized page still free"
        # -- retained-pool invariants (three-way partition) -----------------
        rr = Counter(p for e in self.retained for p in e.pages)
        assert 0 not in rr, "null page retained"
        for p in range(1, self.num_pages):
            assert self.retained_refs[p] == rr.get(p, 0), \
                f"page {p}: retained_refs {self.retained_refs[p]} != " \
                f"{rr.get(p, 0)} retaining entries"
        rset = set(rr)
        assert not rset & set(self.free), "retained page in the free list"
        assert not rset & self.seized, "retained page seized"
        for e in self.retained:
            assert e.pages and len(e.tokens) == self.page * len(e.pages), \
                "retained entry is not page-aligned"
            assert len(set(e.pages)) == len(e.pages), \
                "retained entry holds a page twice"
            digs = prefix_digests(e.tokens, self.page)
            assert e.keys == [(j + 1, d) for j, d in enumerate(digs)], \
                "retained entry digests drifted from its tokens"
            for key in e.keys:
                assert e in self._retained_keys.get(key, []), \
                    f"retained entry unregistered under key {key}"
        n_keys = sum(len(v) for v in self._retained_keys.values())
        assert n_keys == sum(len(e.keys) for e in self.retained), \
            "digest map holds keys for a dropped entry"
        retained_only = {p for p in rset if self.refcount[p] == 0}
        assert set(refs) | set(self.free) | self.seized | retained_only \
            == set(range(1, self.num_pages)), "page leaked"

    # -- defrag ----------------------------------------------------------------

    def defrag(self) -> None:
        """Compact OCCUPIED pages to the contiguous pool prefix (one
        donated device gather per pool) and rewrite the block tables.
        A page shared by several tables moves ONCE and every table entry is
        renumbered to the same new id.  The layout after compaction is
        [null | live | retained-only | seized | free]: retained entries'
        pages and the seized set are renumbered through the same
        permutation and kept OUT of the rebuilt free list (seized pages
        re-entering free after a defrag was a live fuzz-found bug).
        Purely physical: logical contents are untouched, so engine output
        is bit-identical across defrags (property-tested)."""
        self.cow_flush()                 # pending copies address OLD page ids
        mapping = {0: 0}
        perm = [0]                                    # new -> old; null stays
        for i in range(self.B):
            for j, pg in enumerate(self.owned[i]):
                if pg not in mapping:
                    mapping[pg] = len(perm)
                    perm.append(pg)
                self.table[i, j] = mapping[pg]
            self.owned[i] = [mapping[pg] for pg in self.owned[i]]
        for e in self.retained:          # retained-only pages pack next
            for pg in e.pages:
                if pg not in mapping:
                    mapping[pg] = len(perm)
                    perm.append(pg)
            e.pages = [mapping[pg] for pg in e.pages]
        for pg in sorted(self.seized):   # seized keep their rows, renumbered
            if pg not in mapping:
                mapping[pg] = len(perm)
                perm.append(pg)
        self.seized = {mapping[pg] for pg in self.seized}
        kept = len(perm) - 1             # live + retained-only + seized
        perm.extend(p for p in range(1, self.num_pages) if p not in mapping)
        new_rc = np.zeros_like(self.refcount)
        new_rr = np.zeros_like(self.retained_refs)
        for old, new in mapping.items():
            new_rc[new] = self.refcount[old]
            new_rr[new] = self.retained_refs[old]
        self.refcount = new_rc
        self.retained_refs = new_rr
        self.free = list(range(self.num_pages - 1, kept, -1))
        perm_dev = jnp.asarray(np.asarray(perm, np.int32))
        self.k = self._gather(self.k, perm_dev)
        self.v = self._gather(self.v, perm_dev)
        if self.quantized:               # scales renumber with their pages
            self.k_scale = self._gather(self.k_scale, perm_dev)
            self.v_scale = self._gather(self.v_scale, perm_dev)
        self.dirty.update(range(self.B))     # every table renumbered
