"""Paged KV cache manager: a refcounted page pool with prefix sharing and
copy-on-write.

Device state (``Model.init_paged_cache``): k/v page pools
(L, num_pages, page, KV, hd), a block table (B, max_blocks) int32 and
per-slot lengths (B,) int32.  The manager owns the host mirrors, the page
FREE LIST and the per-page REFCOUNTS; page 0 is the reserved NULL page —
never allocated, the landing zone for inactive slots' appends and
unallocated table entries (so the Pallas kernel's scalar-prefetched DMA
address is always valid).

Prefix sharing maps ONE physical page into SEVERAL block tables
(``share()``): a request admitted with a prompt prefix already resident in
a live slot's pages references those pages instead of recomputing them —
rope positions are request-relative in the paged decode path, so the K/V
rows for an identical token prefix are bit-identical across slots and the
reference is exact, not approximate.  Pages referenced more than once are
IMMUTABLE: before any slot may append into a page with refcount > 1 the
scheduler reserves a copy-on-write (``cow_reserve``: fresh page allocated,
table rewired, copy queued) and the tick's reservations are flushed in ONE
batched donated device dispatch (``cow_flush`` — a tick privatizing N pages
issues one copy call whose census bytes are exactly N x page_bytes,
standalone and in-fusion), rewiring only the writing slot's table entries.
Eviction decrements refcounts; a page returns to the free list only when
its refcount reaches zero, so evicting a sharer never frees a page another
slot still references.

Invariants (``check()``, fuzz-asserted by the property harness): every
page's refcount equals the number of block-table references to it; the
null page plus every referenced page plus the free list cover
[0, num_pages) exactly — no page is ever double-allocated, leaked, or
freed while referenced.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterable, List, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


def _copy_pages(pool, dst, src):
    """Device page copy on the stacked (L, num_pages, page, KV, hd) pool:
    rows of pages ``src`` are written into pages ``dst`` (both (n,) int32).
    Jitted with a donated pool so the copy is in place — the HLO is a
    page-sized gather + scatter whose census bytes scale with the pages
    copied, never with the pool."""
    return pool.at[:, dst].set(pool[:, src])


def _copy_pages_both(k, v, dst, src):
    """COW copies k AND v in one dispatch (both pools donated)."""
    return _copy_pages(k, dst, src), _copy_pages(v, dst, src)


class PagedKVCache:
    """Host-side manager for the paged decode cache (see module docstring)."""

    def __init__(self, model: Model, max_batch: int, max_seq: int, *,
                 page_size: int = 16, max_blocks: int = 0,
                 num_pages: int = 0):
        self.page = page_size
        self.max_blocks = max_blocks or -(-max_seq // page_size)
        # default pool: every slot can hold its full table + the null page
        self.num_pages = num_pages or (max_batch * self.max_blocks + 1)
        self.B = max_batch
        arrays = model.init_paged_cache(max_batch, self.max_blocks,
                                        self.page, self.num_pages)
        self.k = arrays["k"]
        self.v = arrays["v"]
        self.table = np.zeros((max_batch, self.max_blocks), np.int32)
        self.length = np.zeros((max_batch,), np.int32)
        self.owned: List[List[int]] = [[] for _ in range(max_batch)]
        self.refcount = np.zeros((self.num_pages,), np.int32)
        self.free: List[int] = list(range(self.num_pages - 1, 0, -1))
        self._gather = jax.jit(lambda pool, perm: pool[:, perm],
                               donate_argnums=(0,))
        self._copy = jax.jit(_copy_pages_both, donate_argnums=(0, 1))
        # per-page bytes across BOTH pools (the census-checked COW cost)
        L = self.k.shape[0]
        self.page_bytes = 2 * L * self.page * self.k.shape[3] \
            * self.k.shape[4] * self.k.dtype.itemsize
        self.cow_copies = 0
        self.cow_bytes = 0
        self.cow_dispatches = 0          # device copy calls (1 per flush)
        self.shared_pages = 0            # share() page references handed out
        # (dst, src, slot, blk) page pairs reserved by cow_reserve()
        # awaiting the one batched device copy of the tick (cow_flush);
        # slot/blk tag each pair so a reservation can be rolled back
        # (cow_rollback) or cancelled when its slot is freed mid-tick
        # (free_slot) without orphaning a pending copy into a free page
        self._pending_cow: List[Tuple[int, int, int, int]] = []
        # pages temporarily seized from the free list by the fault-
        # injection harness (serve/faults.py pool-pressure events): not
        # allocatable, not referenced — check() accounts for them so the
        # pool partition invariant survives injected pressure
        self.seized: Set[int] = set()
        # slot rows whose table/length changed since the engine last synced
        # its device mirrors (admission, COW, eviction, defrag mark these;
        # the engine uploads ONLY these rows, then clears the set)
        self.dirty: Set[int] = set(range(max_batch))

    # -- allocation ----------------------------------------------------------

    def ensure(self, i: int, n_tokens: int) -> bool:
        """Allocate pages so slot ``i`` can hold ``n_tokens`` tokens.
        Returns False (allocating nothing further) if the free list runs
        dry — the scheduler then grants the slot fewer steps (or stalls it)
        until eviction frees pages."""
        need = -(-n_tokens // self.page)
        if need > self.max_blocks:
            raise RuntimeError(
                f"slot {i} needs {need} blocks > max_blocks="
                f"{self.max_blocks} (request exceeds max_seq)")
        while len(self.owned[i]) < need:
            if not self.free:
                return False
            pg = self.free.pop()
            self.refcount[pg] = 1
            self.table[i, len(self.owned[i])] = pg
            self.owned[i].append(pg)
            self.dirty.add(i)
        return True

    def share(self, dst: int, donor: int, n_tokens: int) -> None:
        """Map the donor's pages covering token positions [0, n_tokens)
        into slot ``dst``'s block table (refcount bump, no allocation, no
        copy) and set its length.  ``dst`` must be empty.  The trailing
        page may be partially filled — ``dst`` reads only rows below its
        own length there, and its first append into it triggers ``cow()``.
        """
        assert not self.owned[dst], "share() target slot must be empty"
        need = -(-n_tokens // self.page)
        pages = self.owned[donor][:need]
        assert len(pages) == need, "donor does not cover the shared prefix"
        for j, pg in enumerate(pages):
            self.table[dst, j] = pg
            self.refcount[pg] += 1
        self.owned[dst] = list(pages)
        self.length[dst] = n_tokens
        self.shared_pages += need
        self.dirty.add(dst)

    def cow_reserve(self, i: int, blk: int) -> bool:
        """Reserve a copy-on-write of block ``blk`` of slot ``i``: if the
        page is shared (refcount > 1), allocate a fresh destination page,
        rewire only this slot's table entry, and QUEUE the (dst, src) page
        copy for the tick's single batched device dispatch (``cow_flush``).
        All host bookkeeping (refcounts, tables, counters) happens here;
        only the device copy is deferred — nothing reads or writes the
        reserved pages until the flush lands, because the scheduler flushes
        before the engine issues the tick's decode dispatch.  Returns False
        if the free list is dry (the scheduler stalls the slot until
        eviction frees a page).  No-op on exclusively-owned pages."""
        pg = self.owned[i][blk]
        if self.refcount[pg] <= 1:
            return True
        if not self.free:
            return False
        q = self.free.pop()
        self._pending_cow.append((q, pg, i, blk))
        self.refcount[pg] -= 1
        self.refcount[q] = 1
        self.owned[i][blk] = q
        self.table[i, blk] = q
        self.cow_copies += 1
        self.cow_bytes += self.page_bytes
        self.dirty.add(i)
        return True

    def cow_flush(self) -> int:
        """Privatize every page queued by ``cow_reserve`` in ONE donated
        gather/scatter dispatch over both pools (the batched COW: a tick
        that privatizes N pages costs one device call, not N).  The batch
        is NOT padded — the device moves exactly pages_copied x page_bytes
        (the census-pinned claim); the copy program compiles once per
        distinct batch size, bounded by the pages a single tick can touch
        (B x (ceil(chunk/page) + 1)); ``warm_copy`` pre-compiles the
        common small sizes so typical flushes never compile mid-tick.
        Returns the pages copied."""
        if not self._pending_cow:
            return 0
        dst = jnp.asarray([p[0] for p in self._pending_cow], jnp.int32)
        src = jnp.asarray([p[1] for p in self._pending_cow], jnp.int32)
        self.k, self.v = self._copy(self.k, self.v, dst, src)
        n = len(self._pending_cow)
        self._pending_cow.clear()
        self.cow_dispatches += 1
        return n

    def cow_rollback(self, i: int, from_blk: int = 0) -> int:
        """Undo slot ``i``'s PENDING copy-on-write reservations at block
        indices >= ``from_blk``: the shared mapping is restored (source
        refcount bumped back, table/owned rewired to the original page)
        and the reserved destination page returns to the free list before
        any device copy was issued.  The scheduler calls this when a grant
        shrinks below a block it already reserved — under pool pressure
        the reserved page must go to a slot that can actually advance, not
        sit privatized ahead of an append that will never reach it.
        Returns the number of reservations undone."""
        kept, undone = [], 0
        for (q, pg, s, b) in self._pending_cow:
            if s == i and b >= from_blk:
                self.refcount[pg] += 1
                self.refcount[q] = 0
                self.free.append(q)
                self.owned[i][b] = pg
                self.table[i, b] = pg
                self.cow_copies -= 1
                self.cow_bytes -= self.page_bytes
                undone += 1
            else:
                kept.append((q, pg, s, b))
        self._pending_cow = kept
        if undone:
            self.dirty.add(i)
        return undone

    def cow_many(self, items: Iterable[Tuple[int, int]]) -> int:
        """Batched copy-on-write: privatize ALL shared (slot, blk) pairs in
        one device dispatch.  Pairs whose page is already exclusive are
        skipped; a dry free list stops the batch at the first unservable
        pair (pairs after it are NOT privatized).  Returns the number of
        pages copied.  Convenience wrapper over the reserve/flush pair — a
        caller that must react per pair (e.g. the tick scheduler clipping
        a slot's grant when its COW cannot be served) calls
        ``cow_reserve`` itself and flushes once at the end of the plan."""
        for i, blk in items:
            if not self.cow_reserve(i, blk):
                break
        return self.cow_flush()

    def warm_copy(self, sizes: Tuple[int, ...] = (1, 2)) -> None:
        """Pre-compile the batched page copy for the given batch sizes
        (null-page self-copies: page 0 onto page 0) so the common COW
        flush sizes never pay an XLA compile inside a serving tick.
        Counters are untouched — this is not a COW."""
        for n in sizes:
            idx = jnp.zeros((n,), jnp.int32)
            self.k, self.v = self._copy(self.k, self.v, idx, idx)

    def cow(self, i: int, blk: int) -> bool:
        """Single-page copy-on-write (reserve + immediate flush) — kept for
        callers outside the tick scheduler's batched path."""
        ok = self.cow_reserve(i, blk)
        self.cow_flush()
        return ok

    def shared_blocks(self, i: int, lo_tok: int, hi_tok: int) -> List[int]:
        """Block indices of slot ``i`` whose pages are shared (refcount > 1)
        among the blocks that appends to token positions [lo_tok, hi_tok)
        would touch — the set ``cow()`` must privatize before the tick."""
        b0 = lo_tok // self.page
        b1 = (hi_tok - 1) // self.page
        return [b for b in range(b0, min(b1, len(self.owned[i]) - 1) + 1)
                if self.refcount[self.owned[i][b]] > 1]

    def seize_pages(self, n: int) -> List[int]:
        """Fault injection (pool pressure): remove up to ``n`` pages from
        the free list into the SEIZED set — temporarily unallocatable, as
        if another tenant grabbed them.  ``check()`` accounts for seized
        pages, so every invariant keeps holding under injected pressure.
        Returns the seized page ids (pass them back to
        ``release_pages``)."""
        took = [self.free.pop() for _ in range(min(n, len(self.free)))]
        self.seized.update(took)
        return took

    def release_pages(self, pages: Iterable[int]) -> None:
        """Return previously seized pages to the free list."""
        for pg in pages:
            assert pg in self.seized, f"page {pg} was not seized"
            self.seized.discard(pg)
            self.free.append(pg)

    def free_slot(self, i: int) -> None:
        """Eviction: drop slot ``i``'s references; pages whose refcount
        reaches zero go back to the free list (a page another slot still
        references stays live).  Any PENDING copy-on-write reservation
        the slot holds is cancelled first (rolled back, not flushed):
        preemption/cancellation can free a slot mid-tick, and a pending
        copy into a page that just returned to the free list would
        corrupt whoever allocates it next (regression + fuzz pinned)."""
        if self._pending_cow:
            self.cow_rollback(i)
        for pg in reversed(self.owned[i]):
            self.refcount[pg] -= 1
            if self.refcount[pg] == 0:
                self.free.append(pg)
        self.owned[i] = []
        self.table[i, :] = 0
        self.length[i] = 0
        self.dirty.add(i)

    # -- bookkeeping ----------------------------------------------------------

    @property
    def live_pages(self) -> int:
        """Distinct physical pages referenced by at least one slot."""
        return len({p for o in self.owned for p in o})

    @property
    def logical_pages(self) -> int:
        """Block-table references summed over slots (>= live_pages when
        prefix sharing maps one page into several tables)."""
        return sum(len(o) for o in self.owned)

    def utilization(self) -> float:
        """Fraction of allocatable pages currently referenced by live
        slots (physical: shared pages count once)."""
        return self.live_pages / max(1, self.num_pages - 1)

    def occupancy(self) -> float:
        """Fraction of rows in live pages holding real tokens — intra-page
        fragmentation, invariant under defrag (which only renumbers)."""
        rows = self.live_pages * self.page
        # shared rows are stored once but the physical rows written are
        # exactly the DISTINCT tokens: count each live page's filled rows
        # under its furthest-advanced referent
        fill = {}
        for i in range(self.B):
            n = int(self.length[i])
            for j, pg in enumerate(self.owned[i]):
                f = min(self.page, max(0, n - j * self.page))
                fill[pg] = max(fill.get(pg, 0), f)
        return sum(fill.values()) / rows if rows else 0.0

    def check(self, allow_pending: bool = False) -> None:
        """Refcount/free-list/table invariants (cheap; the property harness
        calls this every fuzz step).  ``allow_pending=True`` checks the
        MID-PLAN state (reservations made, flush not yet issued): pending
        pairs must reference live pages only — a pending copy into or out
        of a free page is exactly the corruption ``free_slot``'s
        cancellation and ``cow_rollback`` exist to prevent."""
        if allow_pending:
            free = set(self.free)
            for (q, pg, s, b) in self._pending_cow:
                assert q not in free and pg not in free, \
                    f"pending COW ({q} <- {pg}) references a free page"
                assert self.refcount[q] == 1, \
                    f"pending COW destination {q} has refcount " \
                    f"{self.refcount[q]}"
                assert 0 <= b < len(self.owned[s]) \
                    and self.owned[s][b] == q, \
                    f"pending COW for slot {s} block {b} lost its rewire"
        else:
            assert not self._pending_cow, "unflushed COW reservations"
        refs = Counter(p for o in self.owned for p in o)
        assert 0 not in refs, "null page referenced"
        for i, o in enumerate(self.owned):
            assert len(set(o)) == len(o), f"slot {i} references a page twice"
            assert list(self.table[i, :len(o)]) == o, "table/owned drift"
            assert not self.table[i, len(o):].any(), "stale table entry"
        for p in range(1, self.num_pages):
            assert self.refcount[p] == refs.get(p, 0), \
                f"page {p}: refcount {self.refcount[p]} != " \
                f"{refs.get(p, 0)} table references"
        assert len(set(self.free)) == len(self.free), "free-list duplicate"
        assert not set(refs) & set(self.free), "page both referenced and free"
        assert not self.seized & set(refs), "seized page still referenced"
        assert not self.seized & set(self.free), "seized page still free"
        assert set(refs) | set(self.free) | self.seized \
            == set(range(1, self.num_pages)), "page leaked"

    # -- defrag ----------------------------------------------------------------

    def defrag(self) -> None:
        """Compact live pages to the contiguous pool prefix [1, live+1)
        (one donated device gather per pool) and rewrite the block tables.
        A page shared by several tables moves ONCE and every table entry is
        renumbered to the same new id.  Purely physical: logical contents
        are untouched, so engine output is bit-identical across defrags
        (property-tested)."""
        self.cow_flush()                 # pending copies address OLD page ids
        mapping = {0: 0}
        perm = [0]                                    # new -> old; null stays
        for i in range(self.B):
            for j, pg in enumerate(self.owned[i]):
                if pg not in mapping:
                    mapping[pg] = len(perm)
                    perm.append(pg)
                self.table[i, j] = mapping[pg]
            self.owned[i] = [mapping[pg] for pg in self.owned[i]]
        live = len(perm) - 1
        perm.extend(p for p in range(1, self.num_pages) if p not in mapping)
        new_rc = np.zeros_like(self.refcount)
        for old, new in mapping.items():
            new_rc[new] = self.refcount[old]
        self.refcount = new_rc
        self.free = list(range(self.num_pages - 1, live, -1))
        perm_dev = jnp.asarray(np.asarray(perm, np.int32))
        self.k = self._gather(self.k, perm_dev)
        self.v = self._gather(self.v, perm_dev)
        self.dirty.update(range(self.B))     # every table renumbered
