"""Seeded fault injection for the paged serving engine.

The paper's premise — when the platform cannot observe a behavior, build
the measurement yourself — applies to failure behavior too: no amount of
happy-path benchmarking shows what a serving tick does when the page pool
is squeezed by a co-tenant, a slot's grant is lost, or a kernel returns
garbage logits.  A ``FaultPlan`` is a DETERMINISTIC schedule of such
events keyed on the engine's tick counter, so every injected overload
schedule is replayable byte-for-byte: the property harness fuzzes random
plans (``FaultPlan.random``) and asserts the engine's invariants — no
deadlock, ``PagedKVCache.check()`` every tick, zero page leaks, and
token-identical output for every preempted-then-resumed request — under
each one.

Event kinds (all handled in ``PagedEngine._apply_faults`` /
``PagedEngine.step``):

  * ``squeeze`` — pool pressure: ``pages`` pages leave the free list for
    ``duration`` ticks (``PagedKVCache.seize_pages``), as if another
    tenant allocated them; the scheduler sees a smaller pool and the
    preemption path absorbs the shortfall;
  * ``evict`` — forced eviction of ``slot`` (any active slot if the index
    is inactive): the request requeues and recomputes, exactly the
    preemption path but triggered externally;
  * ``drop`` — the tick's granted work for ``slot`` (< 0: every slot) is
    lost after planning: pages stay allocated, no tokens advance, the
    scheduler re-grants next tick (a lost dispatch, not a crash);
  * ``poison`` — the slot's sampled tokens come back out-of-vocab this
    tick (nonfinite-logit stand-in: the engine only ever sees sampled
    ints, so garbage logits manifest as garbage tokens); the engine's
    always-on output guard quarantines the slot and requeues the request
    with its pre-tick output.  Under SPECULATIVE decoding a tick keeps up
    to k+1 verified tokens per slot — poison garbages the WHOLE verified
    window, and the guard inspects EVERY kept token (accepted prefix +
    bonus), so one bad token anywhere in the window quarantines the slot
    exactly like a single-token tick; none of the window reaches results;
  * ``kill`` — PROCESS DEATH: the engine raises ``EngineKilled`` at the
    top of the tick, before any state mutates.  Unlike the four
    recoverable kinds the live engine cannot absorb a kill — the DRIVER
    owns recovery: build a fresh engine, ``restore_engine`` it from the
    newest snapshot (``serve/snapshot.py``), re-arm the plan with
    ``without_kills_through(fired_tick)`` so the replayed ticks do not
    re-die on the same event, resubmit whatever the snapshot predates,
    and keep stepping.  The kill-and-recover property drill pins the
    result bit-identical to the uninterrupted run.

``FaultPlan.random`` samples only the RECOVERABLE kinds by default —
random plans drive in-process fuzz loops that expect the engine to
survive every event; kill drills opt in via ``kinds=``.

Plans are plain data — no engine imports — so tests can build them by
hand or sample them from a seed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

RECOVERABLE_KINDS = ("squeeze", "evict", "drop", "poison")
FAULT_KINDS = RECOVERABLE_KINDS + ("kill",)


class EngineKilled(RuntimeError):
    """Raised by ``PagedEngine._apply_faults`` when a ``kill`` event
    fires: the simulated process death.  ``tick`` records the tick the
    kill pre-empted — the tick's work never ran, exactly like a SIGKILL
    between ticks — so the recovery driver can re-arm the plan with
    ``FaultPlan.without_kills_through(tick)``."""

    def __init__(self, tick: int):
        super().__init__(f"engine killed by fault plan at tick {tick}")
        self.tick = tick


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault: fires when the engine's tick counter reaches
    ``tick``.  ``slot`` targets a slot index where the kind needs one
    (evict/drop/poison; -1 = engine picks / all slots), ``pages`` and
    ``duration`` parameterize squeezes."""
    tick: int
    kind: str
    slot: int = -1
    pages: int = 0
    duration: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind: {self.kind!r} "
                             f"(choices: {FAULT_KINDS})")
        if self.tick < 1:
            raise ValueError("fault tick must be >= 1 (ticks are counted "
                             "from the first step() call)")
        if self.kind == "squeeze" and (self.pages < 1 or self.duration < 1):
            raise ValueError("squeeze needs pages >= 1 and duration >= 1")


class FaultPlan:
    """An immutable, replayable schedule of ``FaultEvent``s.  Arm it with
    ``PagedEngine.install_faults(plan)``; the engine pulls
    ``events_at(tick)`` at the top of every tick."""

    def __init__(self, events: List[FaultEvent] = ()):  # noqa: B006
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.tick, e.kind, e.slot)))
        self._by_tick: Dict[int, List[FaultEvent]] = {}
        for ev in self.events:
            self._by_tick.setdefault(ev.tick, []).append(ev)

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        kinds = {}
        for ev in self.events:
            kinds[ev.kind] = kinds.get(ev.kind, 0) + 1
        return f"FaultPlan({len(self.events)} events: {kinds})"

    def events_at(self, tick: int) -> List[FaultEvent]:
        return self._by_tick.get(tick, [])

    @property
    def last_tick(self) -> int:
        return self.events[-1].tick if self.events else 0

    def without_kills_through(self, tick: int) -> "FaultPlan":
        """A new plan with every ``kill`` at or before ``tick`` removed.
        The recovery driver re-arms the restored engine with this:
        restored ticks replay the recoverable events deterministically
        (the restored state at the snapshot tick is identical to the
        original, so squeezes/evicts/drops/poisons between snapshot and
        kill re-fire and re-resolve identically), but the already-fired
        kill cannot loop the drill forever."""
        return FaultPlan([ev for ev in self.events
                          if not (ev.kind == "kill" and ev.tick <= tick)])

    @classmethod
    def random(cls, seed: int, *, n_events: int = 6, max_tick: int = 40,
               max_batch: int = 4, max_pages: int = 4,
               max_duration: int = 6, deep_squeeze: float = 0.25,
               kinds: Tuple[str, ...] = RECOVERABLE_KINDS) -> "FaultPlan":
        """Sample a deterministic plan: ``n_events`` events uniformly over
        ticks [1, max_tick], kinds from ``kinds``, slots from
        [-1, max_batch) (-1 = engine picks / all), squeeze sizes up to
        ``max_pages`` pages for up to ``max_duration`` ticks.  With
        probability ``deep_squeeze`` a squeeze asks for 4x ``max_pages`` —
        deliberately more than the free list usually holds, so the seizure
        must drain the cross-lifetime RETAINED pool (refcount-0 frozen
        prefixes are reclaimable by definition; the fuzz profile covers
        squeeze/evict against a warm retained pool).  Same seed, same plan
        — the fuzz harness logs the seed, so every failure replays."""
        rng = np.random.RandomState(seed)
        events = []
        for _ in range(n_events):
            kind = kinds[rng.randint(len(kinds))]
            tick = int(rng.randint(1, max_tick + 1))
            slot = int(rng.randint(-1, max_batch))
            if kind == "squeeze":
                pages = int(rng.randint(1, max_pages + 1))
                if rng.rand() < deep_squeeze:
                    pages = 4 * max_pages
                events.append(FaultEvent(
                    tick, kind, pages=pages,
                    duration=int(rng.randint(1, max_duration + 1))))
            else:
                events.append(FaultEvent(tick, kind, slot=slot))
        return cls(events)
