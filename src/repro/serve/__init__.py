from repro.serve.engine import (  # noqa: F401
    ContinuousBatchingEngine, PagedEngine, PagedKVCache, ServeConfig,
    ServingEngine)
