from repro.serve.cache import PagedKVCache  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    PagedEngine, Request, RequestStatus, ServeConfig, ServingEngine,
    TERMINAL_STATUSES)
from repro.serve.faults import FaultEvent, FaultPlan  # noqa: F401
from repro.serve.scheduler import TickPlan, TickScheduler  # noqa: F401
