from repro.serve.cache import PagedKVCache  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    PagedEngine, Request, ServeConfig, ServingEngine)
from repro.serve.scheduler import TickPlan, TickScheduler  # noqa: F401
