from repro.serve.cache import PagedKVCache  # noqa: F401
from repro.serve.engine import (  # noqa: F401
    PagedEngine, Request, RequestStatus, ServeConfig, ServingEngine,
    TERMINAL_STATUSES)
from repro.serve.faults import (  # noqa: F401
    EngineKilled, FaultEvent, FaultPlan)
from repro.serve.scheduler import TickPlan, TickScheduler  # noqa: F401
from repro.serve.snapshot import (  # noqa: F401
    SnapshotCorruptError, SnapshotError, SnapshotMismatchError,
    latest_snapshot, load_header, restore_engine, save_snapshot,
    snapshot_path)
