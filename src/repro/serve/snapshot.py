"""Crash-consistent snapshot/restore of the COMPLETE paged serving state.

The paper's discipline — when the platform cannot observe a behavior,
build the measurement yourself — extends across process lifetimes: a
production engine that loses every in-flight request, every retained
prefix page, and every draft cache on a process death is not
production-scale, and no benchmark of the live tick can show what a
restart costs.  This module makes the engine's full state a durable,
verifiable artifact:

  * ``save_snapshot(engine, path)`` serializes EVERYTHING the next tick
    depends on — the target and draft ``PagedKVCache`` pools verbatim
    (bf16 rows, or int8 rows + f32 scale pools), block tables, lengths,
    free lists, refcounts, pending-COW reservations, the retained pool
    (tokens + pages + stamps + hit counts; digests recompute), the
    seized set with its release schedule, every slot (feed token, forced
    queue, output, history, budget), the request table (status, emitted
    tokens, deadlines, preempt counts), the queue order, both RNG keys,
    and the tick/idle/stat counters — to ONE file with a versioned
    header and a CRC32 over the body.  The write is ATOMIC
    (temp file + fsync + ``os.replace``): a crash mid-write leaves the
    previous snapshot intact, and a truncated or bit-flipped file fails
    the checksum instead of restoring garbage.

  * ``restore_engine(engine, path)`` rebuilds a FRESHLY CONSTRUCTED
    engine (weights are the caller's; a snapshot carries state, not
    parameters) into the snapshotted tick: pools re-upload via one host
    array per pool, every table row is marked dirty so the existing
    dirty-row patcher rebuilds the device mirrors on the next tick, the
    live prefix-sharing index and the retained digest index are
    RECOMPUTED from the restored token histories (indexes are derived
    state — recomputing them is self-validating), and in-flight
    requests simply resume: a queued request re-admits through the
    prefill lane, a running slot keeps decoding from its restored feed
    token.  Greedy decode is deterministic and the restore is verbatim,
    so the continuation is bit-identical to the uninterrupted run — the
    property suite pins exactly that, under int8 pools, speculation,
    prefix sharing, retained-page adoption, and random fault plans.

  * A ``fingerprint`` in the header names every shape-determining knob
    (arch dims, kv dtype, pool geometry, spec_k).  Restoring into an
    engine built from a different config raises a typed
    ``SnapshotMismatchError`` at load time, not a shape error deep in a
    tick.

File layout (all integers little-endian)::

    MAGIC "RPSNAP01" | u64 header_len | header JSON | body
    body = state JSON (state_len bytes) | raw array bytes, manifest order
    header = {version, tick, state_len, body_len, body_crc32, fingerprint}

Array bytes are raw ``tobytes()`` with dtype NAME + shape in the
manifest — bfloat16 pools round-trip through ``ml_dtypes`` without a
float32 detour, int8 pools and their f32 scales byte-verbatim.

Directory management (``snapshot_path`` / ``latest_snapshot`` /
``prune_snapshots``) keeps ``snap-<tick>.bin`` files under a configured
dir; ``latest_snapshot`` SKIPS corrupt files, so the kill-and-recover
drill falls back to the newest snapshot that checks out.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.cache import (PagedKVCache, RetainedPrefix,
                               prefix_digests)

MAGIC = b"RPSNAP01"
VERSION = 1

# PagedKVCache counters that ride along so a restored engine's stats and
# bench sections stay continuous across the restart (none affect output)
_CACHE_COUNTERS = (
    "cow_copies", "cow_bytes", "cow_dispatches", "shared_pages",
    "retained_hits", "retained_hit_tokens", "retained_reclaimed_pages",
    "retained_dropped")

# engine scalar counters restored verbatim (same continuity argument)
_ENGINE_COUNTERS = (
    "steps_run", "ticks", "_idle", "no_progress_ticks", "_next_rid",
    "preemptions", "recompute_tokens", "rejected", "cancelled",
    "deadline_exceeded", "quarantines", "dropped_grants", "tokens_out",
    "tokens_appended", "spec_proposed", "spec_accepted",
    "spec_trunc_tokens", "draft_dispatches", "verify_dispatches",
    "shared_tokens", "joins", "stalls", "table_upload_bytes",
    "forced_upload_bytes", "prefill_upload_bytes", "upload_bytes",
    "snapshots_written")


class SnapshotError(RuntimeError):
    """Base for snapshot failures (all typed, none a bare crash)."""


class SnapshotCorruptError(SnapshotError):
    """Bad magic, truncated file, or checksum mismatch — the file is not
    a usable snapshot (a mid-write crash lands here, never in a partial
    restore)."""


class SnapshotMismatchError(SnapshotError):
    """The snapshot is intact but was taken from an engine whose
    shape-determining config differs from the restore target."""


# -- fingerprint --------------------------------------------------------------

def fingerprint(engine) -> Dict[str, Any]:
    """Every knob that determines the SHAPES of the serialized state.
    Two engines with equal fingerprints can exchange snapshots; anything
    else is a typed mismatch at load time."""
    acfg, scfg = engine.model.cfg, engine.cfg
    fp = {
        "arch": acfg.name,
        "n_layers": acfg.n_layers,
        "d_model": acfg.d_model,
        "n_heads": acfg.n_heads,
        "n_kv_heads": acfg.n_kv_heads,
        "d_head": acfg.d_head,
        "vocab_size": acfg.vocab_size,
        "kv_dtype": acfg.kv_dtype,
        "max_batch": scfg.max_batch,
        "max_seq": scfg.max_seq,
        "page_size": engine.kv.page,
        "max_blocks": engine.kv.max_blocks,
        "num_pages": engine.kv.num_pages,
        "spec_k": scfg.spec_k,
        "prefill_lane": bool(scfg.prefill_lane),
        "temperature": scfg.temperature,
        "seed": scfg.seed,
        "draft_arch": engine.draft_model.cfg.name
        if engine.draft_model is not None else None,
    }
    return fp


# -- array codec --------------------------------------------------------------

def _np_dtype(name: str) -> np.dtype:
    """dtype NAME -> dtype, routing the ml_dtypes extension types (e.g.
    "bfloat16") that ``np.dtype`` alone does not know."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _put(arrays: Dict[str, np.ndarray], name: str, arr) -> None:
    arrays[name] = np.ascontiguousarray(np.asarray(arr))


def _encode_arrays(arrays: Dict[str, np.ndarray]):
    """(manifest, concatenated raw bytes) in insertion order."""
    manifest: List[Dict[str, Any]] = []
    blobs: List[bytes] = []
    for name, arr in arrays.items():
        raw = arr.tobytes()
        manifest.append({"name": name, "dtype": arr.dtype.name,
                         "shape": list(arr.shape), "nbytes": len(raw)})
        blobs.append(raw)
    return manifest, b"".join(blobs)


def _decode_arrays(manifest, raw: bytes) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    off = 0
    for ent in manifest:
        n = int(ent["nbytes"])
        if off + n > len(raw):
            raise SnapshotCorruptError(
                f"array {ent['name']!r} runs past the body "
                f"({off + n} > {len(raw)} bytes)")
        dt = _np_dtype(ent["dtype"])
        # frombuffer views are read-only; copy so restored host mirrors
        # (table/length/refcount) stay writable
        out[ent["name"]] = np.frombuffer(
            raw, dtype=dt, count=n // dt.itemsize,
            offset=off).copy().reshape(ent["shape"])
        off += n
    return out


# -- cache (de)serialization ---------------------------------------------------

def _cache_state(kv: PagedKVCache, tag: str,
                 arrays: Dict[str, np.ndarray]) -> Dict[str, Any]:
    _put(arrays, f"{tag}.k", kv.k)
    _put(arrays, f"{tag}.v", kv.v)
    if kv.quantized:
        _put(arrays, f"{tag}.k_scale", kv.k_scale)
        _put(arrays, f"{tag}.v_scale", kv.v_scale)
    _put(arrays, f"{tag}.table", kv.table)
    _put(arrays, f"{tag}.length", kv.length)
    _put(arrays, f"{tag}.refcount", kv.refcount)
    _put(arrays, f"{tag}.retained_refs", kv.retained_refs)
    return {
        "quantized": bool(kv.quantized),
        "owned": [[int(p) for p in o] for o in kv.owned],
        "free": [int(p) for p in kv.free],
        "seized": sorted(int(p) for p in kv.seized),
        # pending COW reservations restore VERBATIM: the source pages are
        # snapshotted pre-flush, so re-running the flush after restore
        # performs the exact copies the dead process never issued
        "pending_cow": [[int(x) for x in t] for t in kv._pending_cow],
        "retain_clock": int(kv._retain_clock),
        # digest keys recompute from tokens on restore (derived state)
        "retained": [{"tokens": [int(t) for t in e.tokens],
                      "pages": [int(p) for p in e.pages],
                      "stamp": int(e.stamp), "hits": int(e.hits)}
                     for e in kv.retained],
        "counters": {c: int(getattr(kv, c)) for c in _CACHE_COUNTERS},
    }


def _restore_cache(kv: PagedKVCache, tag: str, state: Dict[str, Any],
                   arrays: Dict[str, np.ndarray]) -> None:
    if bool(state["quantized"]) != bool(kv.quantized):
        raise SnapshotMismatchError(
            f"{tag}: snapshot quantized={state['quantized']} but engine "
            f"pool quantized={kv.quantized}")
    kv.k = jnp.asarray(arrays[f"{tag}.k"])
    kv.v = jnp.asarray(arrays[f"{tag}.v"])
    if kv.quantized:
        kv.k_scale = jnp.asarray(arrays[f"{tag}.k_scale"])
        kv.v_scale = jnp.asarray(arrays[f"{tag}.v_scale"])
    kv.table = arrays[f"{tag}.table"].astype(np.int32)
    kv.length = arrays[f"{tag}.length"].astype(np.int32)
    kv.refcount = arrays[f"{tag}.refcount"].astype(np.int32)
    kv.retained_refs = arrays[f"{tag}.retained_refs"].astype(np.int32)
    kv.owned = [list(o) for o in state["owned"]]
    kv.free = [int(p) for p in state["free"]]
    kv.seized = set(int(p) for p in state["seized"])
    kv._pending_cow = [tuple(int(x) for x in t)
                       for t in state["pending_cow"]]
    kv._retain_clock = int(state["retain_clock"])
    kv.retained = []
    kv._retained_keys = {}
    for ent in state["retained"]:
        toks = [int(t) for t in ent["tokens"]]
        digests = prefix_digests(toks, kv.page)
        entry = RetainedPrefix(
            tokens=toks, pages=[int(p) for p in ent["pages"]],
            keys=[(j + 1, d) for j, d in enumerate(digests)],
            stamp=int(ent["stamp"]), hits=int(ent["hits"]))
        kv.retained.append(entry)
        for key in entry.keys:
            kv._retained_keys.setdefault(key, []).append(entry)
    for c in _CACHE_COUNTERS:
        setattr(kv, c, int(state["counters"][c]))
    # every device mirror row rebuilds through the existing dirty-row
    # patcher on the next tick — restore never grows a second upload path
    kv.dirty = set(range(kv.table.shape[0]))


# -- engine (de)serialization --------------------------------------------------

def _engine_state(engine,
                  arrays: Dict[str, np.ndarray]) -> Dict[str, Any]:
    _put(arrays, "engine.feed", engine._feed)
    _put(arrays, "engine.key", jax.random.key_data(engine.key))
    if engine.dkv is not None:
        _put(arrays, "engine.dkey", jax.random.key_data(engine._dkey))
    st = {
        "slots": [{"rid": s.rid, "forced": [int(t) for t in s.forced],
                   "out": [int(t) for t in s.out],
                   "history": [int(t) for t in s.history],
                   "budget": s.budget, "served": s.served,
                   "prompt_left": s.prompt_left, "active": s.active}
                  for s in engine.slots],
        "queue": [r.rid for r in engine.queue],
        "reqs": {str(rid): {"prompt": [int(t) for t in r.prompt],
                            "max_new_tokens": r.max_new_tokens,
                            "deadline_tick": r.deadline_tick,
                            "emitted": [int(t) for t in r.emitted],
                            "preempts": r.preempts}
                 for rid, r in engine._reqs.items()},
        "status": {str(rid): s.value for rid, s in engine.status.items()},
        "reject_reason": {str(rid): r
                          for rid, r in engine.reject_reason.items()},
        "results": {str(rid): [int(t) for t in toks]
                    for rid, toks in engine.results.items()},
        "quarantined": {str(i): t
                        for i, t in engine._quarantined.items()},
        "squeezed": [[until, [int(p) for p in pages]]
                     for until, pages in engine._squeezed],
        "fault_counts": dict(engine.fault_counts),
        "counters": {c: int(getattr(engine, c))
                     for c in _ENGINE_COUNTERS},
    }
    return st


def _restore_engine_state(engine, state: Dict[str, Any],
                          arrays: Dict[str, np.ndarray]) -> None:
    from repro.serve.engine import RequestStatus, Request, _Slot

    engine._feed = arrays["engine.feed"].astype(np.int32)
    engine.key = jax.random.wrap_key_data(
        jnp.asarray(arrays["engine.key"]))
    if engine.dkv is not None:
        if "engine.dkey" not in arrays:
            raise SnapshotMismatchError(
                "speculative engine cannot restore from a snapshot "
                "without a draft RNG key (spec_k mismatch)")
        engine._dkey = jax.random.wrap_key_data(
            jnp.asarray(arrays["engine.dkey"]))
    engine.slots = [
        _Slot(rid=s["rid"], forced=list(s["forced"]), out=list(s["out"]),
              history=list(s["history"]), budget=s["budget"],
              served=s["served"], prompt_left=s["prompt_left"],
              active=s["active"])
        for s in state["slots"]]
    engine._reqs = {
        int(rid): Request(int(rid), np.asarray(r["prompt"], np.int32),
                          r["max_new_tokens"],
                          deadline_tick=r["deadline_tick"],
                          emitted=list(r["emitted"]),
                          preempts=r["preempts"])
        for rid, r in state["reqs"].items()}
    engine.queue = [engine._reqs[rid] for rid in state["queue"]]
    engine.status = {int(rid): RequestStatus(v)
                     for rid, v in state["status"].items()}
    engine.reject_reason = {int(rid): r
                            for rid, r in state["reject_reason"].items()}
    engine.results = {int(rid): list(toks)
                      for rid, toks in state["results"].items()}
    engine._quarantined = {int(i): int(t)
                           for i, t in state["quarantined"].items()}
    engine._squeezed = [(int(until), [int(p) for p in pages])
                        for until, pages in state["squeezed"]]
    engine.fault_counts = {str(k): int(v)
                           for k, v in state["fault_counts"].items()}
    engine._drop_slots = set()
    engine._poison_slots = set()
    for c in _ENGINE_COUNTERS:
        setattr(engine, c, int(state["counters"][c]))
    # the live prefix index is DERIVED state: rebuild it from the
    # restored histories exactly as the ticks that built it would have
    engine._pindex.__init__()
    if engine.cfg.prefix_sharing:
        for i, slot in enumerate(engine.slots):
            if slot.active and slot.history:
                engine._pindex.add(i, slot.history)


# -- container ----------------------------------------------------------------

def save_snapshot(engine, path: str) -> str:
    """Serialize ``engine`` to ``path`` ATOMICALLY (temp + fsync +
    rename): readers only ever see the previous complete snapshot or the
    new complete snapshot, never a partial write.  Returns ``path``."""
    arrays: Dict[str, np.ndarray] = {}
    state: Dict[str, Any] = {
        "engine": _engine_state(engine, arrays),
        "kv": _cache_state(engine.kv, "kv", arrays),
        "dkv": _cache_state(engine.dkv, "dkv", arrays)
        if engine.dkv is not None else None,
    }
    manifest, blob = _encode_arrays(arrays)
    state["arrays"] = manifest
    state_b = json.dumps(state).encode("utf-8")
    body = state_b + blob
    header = {
        "version": VERSION,
        "tick": int(engine.ticks),
        "state_len": len(state_b),
        "body_len": len(body),
        "body_crc32": zlib.crc32(body) & 0xFFFFFFFF,
        "fingerprint": fingerprint(engine),
    }
    header_b = json.dumps(header).encode("utf-8")
    d = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(d, exist_ok=True)
    tmp = os.path.join(d, f".{os.path.basename(path)}.tmp")
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(len(header_b).to_bytes(8, "little"))
        f.write(header_b)
        f.write(body)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return path


def _read_container(path: str) -> Tuple[Dict[str, Any], bytes]:
    """(header, body) with magic/length/checksum fully validated —
    truncation and bit flips land in ``SnapshotCorruptError`` here, never
    in a partially-applied restore."""
    try:
        with open(path, "rb") as f:
            data = f.read()
    except OSError as e:
        raise SnapshotError(f"cannot read snapshot {path}: {e}") from e
    if data[:len(MAGIC)] != MAGIC:
        raise SnapshotCorruptError(
            f"{path}: bad magic {data[:len(MAGIC)]!r} "
            f"(want {MAGIC!r})")
    off = len(MAGIC)
    if len(data) < off + 8:
        raise SnapshotCorruptError(f"{path}: truncated before header")
    hlen = int.from_bytes(data[off:off + 8], "little")
    off += 8
    if len(data) < off + hlen:
        raise SnapshotCorruptError(f"{path}: truncated header")
    try:
        header = json.loads(data[off:off + hlen])
    except ValueError as e:
        raise SnapshotCorruptError(f"{path}: header is not JSON") from e
    if header.get("version") != VERSION:
        raise SnapshotMismatchError(
            f"{path}: snapshot version {header.get('version')} != "
            f"reader version {VERSION}")
    off += hlen
    body = data[off:]
    if len(body) != int(header["body_len"]):
        raise SnapshotCorruptError(
            f"{path}: body is {len(body)} bytes, header says "
            f"{header['body_len']} (truncated write)")
    crc = zlib.crc32(body) & 0xFFFFFFFF
    if crc != int(header["body_crc32"]):
        raise SnapshotCorruptError(
            f"{path}: body checksum {crc:#010x} != recorded "
            f"{int(header['body_crc32']):#010x}")
    return header, body


def load_header(path: str) -> Dict[str, Any]:
    """Validated header only (cheap relative to a restore) — the launch
    fast-fail compares ``header['fingerprint']`` before building an
    engine."""
    header, _ = _read_container(path)
    return header


def load_snapshot(path: str):
    """(header, state, arrays) — fully decoded and checksum-verified."""
    header, body = _read_container(path)
    state_len = int(header["state_len"])
    try:
        state = json.loads(body[:state_len])
    except ValueError as e:
        raise SnapshotCorruptError(f"{path}: state is not JSON") from e
    arrays = _decode_arrays(state["arrays"], body[state_len:])
    return header, state, arrays


def restore_engine(engine, path: str):
    """Restore ``engine`` (freshly constructed, same configs as the
    snapshotting engine) to the snapshotted tick.  Fingerprints must
    match exactly; pools, tables, slots, queue, requests, RNG keys and
    counters come back verbatim; derived indexes (live prefix index,
    retained digest keys, device mirrors) rebuild from the restored
    state.  Returns ``engine``."""
    header, state, arrays = load_snapshot(path)
    want, got = fingerprint(engine), header["fingerprint"]
    if want != got:
        diff = {k: (got.get(k), want.get(k))
                for k in set(want) | set(got)
                if got.get(k) != want.get(k)}
        raise SnapshotMismatchError(
            f"{path}: snapshot fingerprint does not match this engine "
            f"(snapshot vs engine): {diff}")
    _restore_cache(engine.kv, "kv", state["kv"], arrays)
    if engine.dkv is not None:
        if state["dkv"] is None:
            raise SnapshotMismatchError(
                f"{path}: engine has a draft pool but the snapshot "
                "carries none")
        _restore_cache(engine.dkv, "dkv", state["dkv"], arrays)
    _restore_engine_state(engine, state["engine"], arrays)
    engine._last_snapshot_tick = int(header["tick"])
    return engine


# -- snapshot directories ------------------------------------------------------

def snapshot_path(snap_dir: str, tick: int) -> str:
    return os.path.join(snap_dir, f"snap-{tick:08d}.bin")


def list_snapshots(snap_dir: str) -> List[str]:
    """All snapshot files under ``snap_dir``, oldest tick first."""
    try:
        names = os.listdir(snap_dir)
    except OSError:
        return []
    return [os.path.join(snap_dir, n) for n in sorted(names)
            if n.startswith("snap-") and n.endswith(".bin")]


def latest_snapshot(snap_dir: str) -> Optional[str]:
    """Newest snapshot that passes checksum validation, or None.  A
    truncated newest file (mid-write crash on a filesystem without
    atomic rename, or operator damage) is SKIPPED — recovery falls back
    to the previous complete snapshot instead of failing."""
    for path in reversed(list_snapshots(snap_dir)):
        try:
            _read_container(path)
        except SnapshotError:
            continue
        return path
    return None


def prune_snapshots(snap_dir: str, keep: int) -> List[str]:
    """Drop all but the newest ``keep`` snapshots; returns removed
    paths.  ``keep`` < 1 keeps everything (a retention floor of one live
    snapshot is the point of the exercise)."""
    removed: List[str] = []
    if keep < 1:
        return removed
    snaps = list_snapshots(snap_dir)
    for path in snaps[:-keep] if len(snaps) > keep else []:
        try:
            os.remove(path)
            removed.append(path)
        except OSError:
            pass
    return removed
