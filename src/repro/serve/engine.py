"""Batched serving engine: fused device-resident decode + continuous batching.

The decode hot path is ONE compiled HLO module (``Model.decode_many``: a
``lax.scan`` over decode steps with on-device sampling and per-slot stop
conditions), jitted with ``donate_argnums`` so the KV cache and sampler key
are updated in place instead of re-materialized every token.  That makes the
decode cell a single program `core.hlo_counters` can census and place on the
instruction roofline — and removes the per-token host round-trip the legacy
loop pays (kept as ``fused=False`` for the measured comparison in
``benchmark_decode`` / benchmarks/serve_bench.py).

``ContinuousBatchingEngine`` adds slot-level scheduling on top of the same
compiled single step: finished sequences release their slot and queued
requests join mid-flight with NO recompilation — the new prompt is fed
through the already-compiled decode step (prefill-by-decode) while the
slot's ``start`` entry masks the previous occupant's KV rows.

CPU-runnable end-to-end (examples/serve_demo.py); the same step functions are
what launch/serve.py lowers for the production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model, sample_token


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4
    max_seq: int = 128
    max_new_tokens: int = 16
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0
    eos_id: int = -1                  # < 0: no stop condition
    pad_id: int = 0                   # emitted by finished slots
    fused: bool = True                # decode_many scan vs per-token loop


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Greedy/temperature sampling over a shared batched KV cache."""

    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        # donate the cache through BOTH decode paths: XLA aliases the input
        # buffer to the output, so each step updates the cache in place
        # instead of allocating a full copy per token
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        self._prefill = jax.jit(model.prefill)
        self._decode_many = jax.jit(
            model.decode_many,
            static_argnames=("num_steps", "temperature", "eos_id", "pad_id"),
            donate_argnums=(2, 3))          # cache + sampler key
        self._key = jax.random.key(cfg.seed)

    # -- sampling ---------------------------------------------------------------

    def _sample(self, logits: jax.Array, key: jax.Array):
        """One sampling step (models.model.sample_token, the shared helper,
        so legacy and fused paths are token-identical for a given seed)."""
        return sample_token(logits, key, self.cfg.temperature)

    # -- prefill ---------------------------------------------------------------

    def _prefill_cache(self, prompts: List[np.ndarray], mnt: int):
        """Left-pads prompts to a common length, prefills once, scatters the
        prefill KV into a fresh (donatable) decode cache."""
        B = len(prompts)
        S = max(len(p) for p in prompts)
        toks = np.zeros((B, S), np.int32)
        for i, p in enumerate(prompts):            # right-align
            toks[i, S - len(p):] = p
        batch = {"tokens": jnp.asarray(toks)}
        last_logits, cache_parts = self._prefill(self.params, batch)

        cache = self.model.init_cache(B, S + mnt)
        for k in cache_parts or {}:
            src = cache_parts[k]
            dst = cache[k]
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            cache[k] = jnp.pad(src.astype(dst.dtype), pad)
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return last_logits, cache

    # -- generation ---------------------------------------------------------------

    def generate_batch(self, prompts: List[np.ndarray],
                       max_new_tokens: Optional[int] = None,
                       fused: Optional[bool] = None) -> List[List[int]]:
        """Prefill once, then decode all sequences in lockstep (the
        decode_32k cell's shape).  ``fused=True`` (default) runs the whole
        token loop on device; ``fused=False`` is the legacy per-token host
        loop (same tokens, one dispatch + sync per step)."""
        cfg = self.cfg
        mnt = max_new_tokens or cfg.max_new_tokens
        fused = cfg.fused if fused is None else fused
        B = len(prompts)

        last_logits, cache = self._prefill_cache(prompts, mnt)
        key = self._key
        first, key = self._sample(last_logits, key)

        if fused:
            toks, cache, key, _done = self._decode_many(
                self.params, first[:, None], cache, key,
                num_steps=mnt - 1, temperature=cfg.temperature,
                eos_id=cfg.eos_id, pad_id=cfg.pad_id)
            all_toks = np.concatenate(
                [np.asarray(first)[None], np.asarray(toks)], axis=0)
        else:
            tok = first[:, None]
            rows = [np.asarray(first)]
            for _ in range(mnt - 1):
                logits, cache = self._decode(self.params, tok, cache)
                t, key = self._sample(logits, key)
                tok = t[:, None]
                rows.append(np.asarray(t))         # per-token host sync
            all_toks = np.stack(rows, axis=0)
        self._key = key

        outs: List[List[int]] = []
        for i in range(B):
            col = [int(t) for t in all_toks[:, i]]
            if cfg.eos_id >= 0 and cfg.eos_id in col:
                col = col[: col.index(cfg.eos_id) + 1]
            outs.append(col)
        return outs

    # -- benchmarking ---------------------------------------------------------------

    def benchmark_decode(self, batch: int, seq: int, steps: int = 8
                         ) -> Dict[str, float]:
        """Wall-clock decode throughput on this host (CPU here; the TPU
        numbers come from the dry-run roofline): the fused device-resident
        loop vs the legacy per-step loop, both with donated caches."""
        assert seq // 2 + 2 * steps + 2 <= seq, \
            f"steps={steps} overruns the cache (seq={seq})"

        def fresh_cache():
            cache = self.model.init_cache(batch, seq)
            cache["pos"] = jnp.asarray(seq // 2, jnp.int32)
            return cache

        tok0 = jnp.zeros((batch, 1), jnp.int32)

        # legacy: one dispatch + argmax + host sync per token
        cache = fresh_cache()
        logits, cache = self._decode(self.params, tok0, cache)  # compile
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        t0 = time.perf_counter()
        for _ in range(steps):
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            np.asarray(tok)                        # the per-token round-trip
        dt_loop = (time.perf_counter() - t0) / steps

        # fused: one dispatch for the whole token loop
        key = jax.random.key(self.cfg.seed)
        cache = fresh_cache()
        toks, cache, key, _ = self._decode_many(   # compile
            self.params, tok0, cache, key, num_steps=steps,
            temperature=0.0, eos_id=-1, pad_id=0)
        jax.block_until_ready(toks)
        t0 = time.perf_counter()
        toks, cache, key, _ = self._decode_many(
            self.params, tok0, cache, key, num_steps=steps,
            temperature=0.0, eos_id=-1, pad_id=0)
        jax.block_until_ready(toks)
        dt_fused = (time.perf_counter() - t0) / steps

        return {
            "s_per_step": dt_fused,
            "tokens_per_s": batch / dt_fused,
            "s_per_step_fused": dt_fused,
            "tokens_per_s_fused": batch / dt_fused,
            "s_per_step_loop": dt_loop,
            "tokens_per_s_loop": batch / dt_loop,
            "fused_speedup": dt_loop / dt_fused,
        }


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Slot:
    rid: int = -1
    forced: List[int] = dataclasses.field(default_factory=list)
    out: List[int] = dataclasses.field(default_factory=list)
    budget: int = 0
    active: bool = False


def _make_engine_step(model: Model):
    """One decode step + sampling + forced-token override, as a pure
    function of arrays (compiled exactly once per temperature)."""

    def step(params, tok, cache, key, forced_tok, forced_mask,
             temperature: float):
        logits, cache = model.decode_step(params, tok[:, None], cache)
        sampled, key = sample_token(logits, key, temperature)
        nxt = jnp.where(forced_mask, forced_tok, sampled)
        return nxt, cache, key

    return step


class ContinuousBatchingEngine:
    """Slot-scheduled decoding over ONE compiled step — no recompiles, ever.

    All ``max_batch`` slots advance in lockstep over a shared, donated,
    slot-paged KV cache (one (max_seq, KV, hd) page per slot).  A queued
    request joins the moment a slot frees:

      * the slot's ``start`` is set to the current shared position, masking
        the previous occupant's KV rows (per-slot attention window);
      * its prompt is fed through the SAME compiled decode step one token
        per engine step ("prefill-by-decode") — the sampled output is
        overridden by the next prompt token until the prompt is exhausted,
        after which sampled tokens are collected as output.

    Decoder-only LMs only (whisper needs per-request cross-attention caches;
    a joining SSM slot would inherit the previous occupant's state).
    """

    def __init__(self, model: Model, params, cfg: ServeConfig):
        if model.cfg.is_encoder_decoder or model.cfg.mamba_version:
            raise ValueError("continuous batching requires a decoder-only "
                             "attention LM (per-slot KV windows)")
        self.model = model
        self.params = params
        self.cfg = cfg
        B = cfg.max_batch
        self._step = jax.jit(_make_engine_step(model),
                             static_argnames=("temperature",),
                             donate_argnums=(2, 3))   # cache + key
        self.cache = model.init_cache(B, cfg.max_seq)
        self.key = jax.random.key(cfg.seed)
        self.pos = 0                                  # host mirror of pos
        self.slots = [_Slot() for _ in range(B)]
        self.queue: List[Request] = []
        self.results: Dict[int, List[int]] = {}
        self._feed = np.full((B,), cfg.pad_id, np.int32)
        self._next_rid = 0
        self.steps_run = 0
        self.joins = 0

    # -- request lifecycle -----------------------------------------------------

    def submit(self, prompt: np.ndarray,
               max_new_tokens: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt: a slot needs at least one "
                             "token to feed the decode step")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt,
                                  max_new_tokens or self.cfg.max_new_tokens))
        return rid

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = [int(t) for t in req.prompt]
            self.slots[i] = _Slot(rid=req.rid, forced=prompt[1:], out=[],
                                  budget=req.max_new_tokens, active=True)
            # window base: mask every cache row this slot wrote before
            self.cache["start"] = self.cache["start"].at[i].set(self.pos)
            self._feed[i] = prompt[0]
            self.joins += 1

    def _finish(self, i: int) -> None:
        slot = self.slots[i]
        self.results[slot.rid] = slot.out
        self.slots[i] = _Slot()
        self._feed[i] = self.cfg.pad_id

    # -- stepping ---------------------------------------------------------------

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s.active for s in self.slots)

    def step(self) -> None:
        """Admit waiting requests, advance every slot by one token."""
        cfg = self.cfg
        if self.pos + 1 >= cfg.max_seq:
            raise RuntimeError(
                f"KV cache exhausted at pos={self.pos} (max_seq="
                f"{cfg.max_seq}); page eviction is a recorded follow-up")
        self._admit()
        forced_tok = np.full((len(self.slots),), cfg.pad_id, np.int32)
        forced_mask = np.zeros((len(self.slots),), bool)
        for i, slot in enumerate(self.slots):
            if slot.active and slot.forced:
                forced_tok[i] = slot.forced.pop(0)
                forced_mask[i] = True
        nxt, self.cache, self.key = self._step(
            self.params, jnp.asarray(self._feed), self.cache, self.key,
            jnp.asarray(forced_tok), jnp.asarray(forced_mask),
            temperature=cfg.temperature)
        self.pos += 1
        self.steps_run += 1
        nxt_np = np.asarray(nxt)
        for i, slot in enumerate(self.slots):
            if not slot.active:
                continue
            if forced_mask[i]:                      # still catching up
                self._feed[i] = nxt_np[i]
                continue
            tok = int(nxt_np[i])                    # sampled: real output
            slot.out.append(tok)
            if (cfg.eos_id >= 0 and tok == cfg.eos_id) \
                    or len(slot.out) >= slot.budget:
                self._finish(i)
            else:
                self._feed[i] = nxt_np[i]

    def run(self) -> Dict[int, List[int]]:
        """Drain queue + slots; returns {rid: generated tokens}."""
        while self.busy:
            self.step()
        return self.results
