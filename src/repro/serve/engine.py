"""Batched serving engine: continuous-batching prefill + decode.

CPU-runnable end-to-end (examples/serve_demo.py); the same step functions are
what launch/serve.py lowers for the production mesh.  Requests join a slot
when one frees (continuous batching); each decode step advances every live
slot by one token.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4
    max_seq: int = 128
    max_new_tokens: int = 16
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Greedy/temperature sampling over a shared batched KV cache."""

    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self._key = jax.random.key(cfg.seed)

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(sub, logits / self.cfg.temperature,
                                      axis=-1)

    def generate_batch(self, prompts: List[np.ndarray],
                       max_new_tokens: Optional[int] = None
                       ) -> List[List[int]]:
        """Left-pads prompts to a common length, prefills once, then decodes
        all sequences in lockstep (the decode_32k cell's shape)."""
        cfg = self.cfg
        mnt = max_new_tokens or cfg.max_new_tokens
        B = len(prompts)
        S = max(len(p) for p in prompts)
        toks = np.zeros((B, S), np.int32)
        for i, p in enumerate(prompts):            # right-align
            toks[i, S - len(p):] = p
        batch = {"tokens": jnp.asarray(toks)}
        last_logits, cache_parts = self._prefill(self.params, batch)

        cache = self.model.init_cache(B, S + mnt)
        for k in cache_parts or {}:
            src = cache_parts[k]
            dst = cache[k]
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            cache[k] = jnp.pad(src.astype(dst.dtype), pad)
        cache["pos"] = jnp.asarray(S, jnp.int32)

        outs: List[List[int]] = [[] for _ in range(B)]
        tok = self._sample(last_logits)[:, None].astype(jnp.int32)
        for i in range(B):
            outs[i].append(int(tok[i, 0]))
        for _ in range(mnt - 1):
            logits, cache = self._decode(self.params, tok, cache)
            tok = self._sample(logits)[:, None].astype(jnp.int32)
            for i in range(B):
                outs[i].append(int(tok[i, 0]))
        return outs

    def benchmark_decode(self, batch: int, seq: int, steps: int = 8
                         ) -> Dict[str, float]:
        """Wall-clock decode throughput on this host (CPU here; the TPU
        numbers come from the dry-run roofline)."""
        cache = self.model.init_cache(batch, seq)
        cache["pos"] = jnp.asarray(seq // 2, jnp.int32)
        tok = jnp.zeros((batch, 1), jnp.int32)
        logits, cache = self._decode(self.params, tok, cache)  # compile
        jax.block_until_ready(logits)
        t0 = time.time()
        for _ in range(steps):
            logits, cache = self._decode(self.params, tok, cache)
        jax.block_until_ready(logits)
        dt = (time.time() - t0) / steps
        return {"s_per_step": dt, "tokens_per_s": batch / dt}
