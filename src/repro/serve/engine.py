"""Serving engines: fused device-resident decode and the paged
continuous-batching production path.

The decode hot path is ONE compiled HLO module (``Model.decode_many`` /
``Model.decode_many_paged``: a ``lax.scan`` over decode steps with on-device
sampling), jitted with ``donate_argnums`` so the KV cache and sampler key
are updated in place instead of re-materialized every token.  That makes the
decode cell a single program `core.hlo_counters` can census and place on the
instruction roofline — and removes the per-token host round-trip the legacy
loop pays (kept as ``fused=False`` for the measured comparison in
``benchmark_decode`` / benchmarks/serve_bench.py).

Two engines, one compiled-cell discipline (no recompiles, ever):

  * ``ServingEngine`` — whole-batch generation: prefill once, decode all
    sequences in lockstep.  Also the token-identity ORACLE the paged
    property harness fuzzes against.
  * ``PagedEngine`` — THE production path: non-lockstep continuous
    batching over a ``PagedKVCache`` (serve/cache.py: refcounted page pool
    + per-slot block tables + per-slot lengths) driven by a
    ``TickScheduler`` (serve/scheduler.py: prefill-lane + decode grants,
    partial grants, fairness, per-tick budget).  Every slot decodes at
    its own position on its own pages (rope is request-relative by
    construction), prompts stream through the RAGGED MULTI-TOKEN PREFILL
    LANE (``Model.prefill_many_paged``: one compiled kernel step appends
    and causally attends a page-aligned chunk of up to T prompt tokens
    per slot, so admitting a P-token prompt costs ceil(P / T) dispatches
    instead of P decode steps), and a request admitted with a prompt
    prefix already resident in a live slot's pages SHARES those pages
    (refcount bump, no recompute; the donor is found through a
    rolling-hash prefix index, not a linear LCP scan) — appends into a
    shared page copy-on-write privatize it first, all of a tick's copies
    batched into ONE device dispatch.  A request can outlive ``max_seq``
    total traffic (pages recycle), mid-flight joins reuse the compiled
    cells, and the decode kernel's transaction count scales with live
    tokens, not pool size — the engine's regression suite pins all three
    guarantees, migrated from the retired dense lockstep engine (its
    row-wraparound machinery is gone; per-slot pages make it
    unnecessary).

    The TICK is host-side as thin as the kernel: at most two compiled
    cells per tick (the ragged prefill lane for prompt chunks, the
    forced-token-free decode twin for generation — each compiled once; a
    legacy forced-token decode cell remains only for the measured
    ``prefill_lane=False`` baseline), a device-resident block table /
    length state patched only at DIRTY rows (a steady-state decode tick
    uploads zero table bytes and runs one dispatch), per-slot grants
    uploaded as B ints, prompt chunks uploaded as ONE ragged (B, T) token
    block (the per-step (chunk, B) forced-token/mask uploads are retired
    for prompt traffic — ``forced_upload_bytes`` stays 0 and verify.sh
    gates it), and per-tick host-cost traces (host ms, dispatches, upload
    bytes) feeding BENCH_serve.json's tick_overhead section.

CPU-runnable end-to-end (examples/serve_demo.py); the same step functions are
what launch/serve.py lowers for the production mesh.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model, sample_token
from repro.serve.cache import PagedKVCache, digest_step
from repro.serve.faults import EngineKilled
from repro.serve.scheduler import TickScheduler


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4
    max_seq: int = 128
    max_new_tokens: int = 16
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0
    eos_id: int = -1                  # < 0: no stop condition
    pad_id: int = 0                   # emitted by finished slots
    fused: bool = True                # decode_many scan vs per-token loop
    # --- paged engine ------------------------------------------------------
    page_size: int = 16               # tokens per KV page
    max_blocks: int = 0               # block-table width (0: ceil(max_seq/page))
    num_pages: int = 0                # pool size incl. null page (0: fit all slots)
    prefill_chunk: int = 4            # fused decode steps per PagedEngine tick
    # --- ragged multi-token prefill lane ------------------------------------
    prefill_lane: bool = True         # prompts go through the multi-token
                                      # prefill kernel (one compiled step
                                      # per chunk); False = legacy
                                      # prefill-by-decode (one step/token)
    prefill_chunk_tokens: int = 0     # prompt tokens per prefill-lane chunk
                                      # (0 = ArchConfig.prefill_chunk_tokens,
                                      # then auto: 2 x page_size; keep it a
                                      # multiple of page_size so chunk
                                      # grants stay page-aligned)
    # --- prefix sharing / scheduling ---------------------------------------
    prefix_sharing: bool = True       # share resident prompt prefixes on admit
    share_min_tokens: int = 1         # smallest common prefix worth sharing
    # --- cross-lifetime retention (needs prefix_sharing) ---------------------
    retain_prefixes: bool = True      # keep finished/evicted slots' page-
                                      # aligned prefix pages for digest-keyed
                                      # re-sharing after the donor is gone
    retain_pool_pages: int = 0        # max retained-ONLY pages held idle
                                      # (0: pool-bounded — pressure reclaims)
    retain_policy: str = "lru"        # reclamation order: "lru" |
                                      # "popularity" (fewest adoptions first)
    fairness: str = "least-served"    # page-grant order ("slot-order": legacy)
    tick_budget: int = 0              # max fresh tokens per tick (0: uncapped)
    trace_pool: bool = True           # record per-tick util/occupancy traces
                                      # (host-side pool walks; benchmarks
                                      # measuring the thin tick disable it)
    trace_ticks: bool = True          # record per-tick host-ms/dispatch/
                                      # upload traces (cheap scalars, but
                                      # unbounded — a long-lived server
                                      # disables them; counters stay on)
    # --- overload safety (preemption / admission / fault tolerance) ---------
    preempt: bool = True              # preempt-and-recompute when no slot
                                      # can get step capacity; False keeps
                                      # the legacy pool-exhausted raise
                                      # (measured/regression baseline only)
    preempt_policy: str = "fewest-tokens"  # victim selection (scheduler:
                                      # "fewest-tokens" | "most-pages")
    max_queue: int = 0                # submit() queue-depth bound (0:
                                      # unbounded); overflow -> REJECTED
    deadline_ticks: int = 0           # default per-request tick budget
                                      # (0: no deadline); overrun ->
                                      # DEADLINE_EXCEEDED with partial output
    quarantine_ticks: int = 2         # ticks a slot sits out after emitting
                                      # a poisoned (out-of-vocab) token
    wedge_ticks: int = 10_000         # consecutive idle-but-busy ticks
                                      # before the engine declares itself
                                      # wedged and raises (bookkeeping-bug
                                      # tripwire; fuzz harnesses shrink it
                                      # so a wedge fails in seconds)
    # --- crash consistency (serve/snapshot.py) -------------------------------
    snapshot_every_ticks: int = 0     # write a full-state snapshot every
                                      # N ticks (0 = off); restore via
                                      # snapshot.restore_engine — the
                                      # continuation is bit-identical to
                                      # the uninterrupted run
    snapshot_dir: str = ""            # where snap-<tick>.bin files land
                                      # (required when snapshotting)
    snapshot_keep: int = 2            # newest snapshots retained on disk
                                      # (>= 2 keeps a fallback if the
                                      # newest file is damaged; < 1
                                      # keeps everything)
    # --- speculative decoding (draft-and-verify) -----------------------------
    spec_k: int = 0                   # draft proposals per decode tick
                                      # (0 = off).  > 0 needs a draft
                                      # model/params handed to PagedEngine,
                                      # greedy serving (temperature == 0 —
                                      # a proposal is accepted iff it
                                      # equals the target argmax) and the
                                      # prefill lane (the target verifies
                                      # the ragged [feed, p_1..p_k] block
                                      # in ONE prefill-lane dispatch)


class RequestStatus(enum.Enum):
    """Request lifecycle.  Every submitted rid ends in a TERMINAL status —
    overload shows up as typed outcomes (REJECTED / DEADLINE_EXCEEDED /
    PREEMPTED_RESUMED), never as a hang or an engine raise."""
    QUEUED = "queued"                  # waiting for a slot (incl. requeued)
    RUNNING = "running"                # occupies a slot
    FINISHED = "finished"              # completed, never preempted
    PREEMPTED_RESUMED = "preempted_resumed"  # completed after >= 1 preemption
    REJECTED = "rejected"              # failed admission at submit()
    CANCELLED = "cancelled"            # explicit cancel(); partial output kept
    DEADLINE_EXCEEDED = "deadline_exceeded"  # tick budget ran out


TERMINAL_STATUSES = frozenset({
    RequestStatus.FINISHED, RequestStatus.PREEMPTED_RESUMED,
    RequestStatus.REJECTED, RequestStatus.CANCELLED,
    RequestStatus.DEADLINE_EXCEEDED})


@dataclasses.dataclass
class Request:
    """One submitted request.  ``prompt`` is the ORIGINAL prompt and never
    changes; ``emitted`` accumulates output tokens across preemptions (on
    re-admission they are replayed as forced prompt through the prefill
    lane, so the resumed request is token-identical to an uninterrupted
    run); ``max_new_tokens`` is the TOTAL output budget across resumes."""
    rid: int
    prompt: np.ndarray                # (S,) int32
    max_new_tokens: int
    deadline_tick: int = -1           # absolute engine tick; -1 = none
    emitted: List[int] = dataclasses.field(default_factory=list)
    preempts: int = 0                 # times this request lost its slot


class ServingEngine:
    """Greedy/temperature sampling over a shared batched KV cache."""

    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        # donate the cache through BOTH decode paths: XLA aliases the input
        # buffer to the output, so each step updates the cache in place
        # instead of allocating a full copy per token
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        self._prefill = jax.jit(model.prefill)
        self._decode_many = jax.jit(
            model.decode_many,
            static_argnames=("num_steps", "temperature", "eos_id", "pad_id"),
            donate_argnums=(2, 3))          # cache + sampler key
        self._key = jax.random.key(cfg.seed)

    # -- sampling ---------------------------------------------------------------

    def _sample(self, logits: jax.Array, key: jax.Array):
        """One sampling step (models.model.sample_token, the shared helper,
        so legacy and fused paths are token-identical for a given seed)."""
        return sample_token(logits, key, self.cfg.temperature)

    # -- prefill ---------------------------------------------------------------

    def _prefill_cache(self, prompts: List[np.ndarray], mnt: int):
        """Left-pads prompts to a common length, prefills once, scatters the
        prefill KV into a fresh (donatable) decode cache."""
        B = len(prompts)
        S = max(len(p) for p in prompts)
        toks = np.zeros((B, S), np.int32)
        for i, p in enumerate(prompts):            # right-align
            toks[i, S - len(p):] = p
        batch = {"tokens": jnp.asarray(toks)}
        last_logits, cache_parts = self._prefill(self.params, batch)

        cache = self.model.init_cache(B, S + mnt)
        for k in cache_parts or {}:
            src = cache_parts[k]
            dst = cache[k]
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            cache[k] = jnp.pad(src.astype(dst.dtype), pad)
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return last_logits, cache

    # -- generation ---------------------------------------------------------------

    def generate_batch(self, prompts: List[np.ndarray],
                       max_new_tokens: Optional[int] = None,
                       fused: Optional[bool] = None) -> List[List[int]]:
        """Prefill once, then decode all sequences in lockstep (the
        decode_32k cell's shape).  ``fused=True`` (default) runs the whole
        token loop on device; ``fused=False`` is the legacy per-token host
        loop (same tokens, one dispatch + sync per step)."""
        cfg = self.cfg
        mnt = max_new_tokens or cfg.max_new_tokens
        fused = cfg.fused if fused is None else fused
        B = len(prompts)

        last_logits, cache = self._prefill_cache(prompts, mnt)
        key = self._key
        first, key = self._sample(last_logits, key)

        if fused:
            toks, cache, key, _done = self._decode_many(
                self.params, first[:, None], cache, key,
                num_steps=mnt - 1, temperature=cfg.temperature,
                eos_id=cfg.eos_id, pad_id=cfg.pad_id)
            all_toks = np.concatenate(
                [np.asarray(first)[None], np.asarray(toks)], axis=0)
        else:
            tok = first[:, None]
            rows = [np.asarray(first)]
            for _ in range(mnt - 1):
                logits, cache = self._decode(self.params, tok, cache)
                t, key = self._sample(logits, key)
                tok = t[:, None]
                rows.append(np.asarray(t))         # per-token host sync
            all_toks = np.stack(rows, axis=0)
        self._key = key

        outs: List[List[int]] = []
        for i in range(B):
            col = [int(t) for t in all_toks[:, i]]
            if cfg.eos_id >= 0 and cfg.eos_id in col:
                col = col[: col.index(cfg.eos_id) + 1]
            outs.append(col)
        return outs

    # -- benchmarking ---------------------------------------------------------------

    def benchmark_decode(self, batch: int, seq: int, steps: int = 8
                         ) -> Dict[str, float]:
        """Wall-clock decode throughput on this host (CPU here; the TPU
        numbers come from the dry-run roofline): the fused device-resident
        loop vs the legacy per-step loop, both with donated caches."""
        assert seq // 2 + 2 * steps + 2 <= seq, \
            f"steps={steps} overruns the cache (seq={seq})"

        def fresh_cache():
            cache = self.model.init_cache(batch, seq)
            cache["pos"] = jnp.asarray(seq // 2, jnp.int32)
            return cache

        tok0 = jnp.zeros((batch, 1), jnp.int32)

        # legacy: one dispatch + argmax + host sync per token
        cache = fresh_cache()
        logits, cache = self._decode(self.params, tok0, cache)  # compile
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        t0 = time.perf_counter()
        for _ in range(steps):
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            np.asarray(tok)                        # the per-token round-trip
        dt_loop = (time.perf_counter() - t0) / steps

        # fused: one dispatch for the whole token loop
        key = jax.random.key(self.cfg.seed)
        cache = fresh_cache()
        toks, cache, key, _ = self._decode_many(   # compile
            self.params, tok0, cache, key, num_steps=steps,
            temperature=0.0, eos_id=-1, pad_id=0)
        jax.block_until_ready(toks)
        t0 = time.perf_counter()
        toks, cache, key, _ = self._decode_many(
            self.params, tok0, cache, key, num_steps=steps,
            temperature=0.0, eos_id=-1, pad_id=0)
        jax.block_until_ready(toks)
        dt_fused = (time.perf_counter() - t0) / steps

        return {
            "s_per_step": dt_fused,
            "tokens_per_s": batch / dt_fused,
            "s_per_step_fused": dt_fused,
            "tokens_per_s_fused": batch / dt_fused,
            "s_per_step_loop": dt_loop,
            "tokens_per_s_loop": batch / dt_loop,
            "fused_speedup": dt_loop / dt_fused,
        }


# ---------------------------------------------------------------------------
# paged continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Slot:
    """One schedulable slot: ``forced`` holds the prompt tokens queued
    behind the feed token (consumed by the prefill lane in chunks, or
    forced into the decode stream one per step when the lane is off);
    ``prompt_left`` counts prompt tokens not yet appended (feed + forced
    while prefilling, 0 once the first output is sampled — the scheduler's
    lane selector); ``history`` mirrors the tokens whose K/V rows are
    resident in the slot's pages (the prefix-sharing donor index —
    ``len(history) == kv.length[i]`` always); ``served`` counts fresh
    tokens appended (the fairness key)."""
    rid: int = -1
    forced: List[int] = dataclasses.field(default_factory=list)
    out: List[int] = dataclasses.field(default_factory=list)
    history: List[int] = dataclasses.field(default_factory=list)
    budget: int = 0
    served: int = 0
    prompt_left: int = 0
    active: bool = False


def _lcp(a: List[int], b: List[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


def _patch_rows(table, length, rows, t_rows, l_rows):
    """Patch the device table/length mirrors at ``rows`` (donated, so the
    update is in place — the upload cost is the DIRTY rows, never the whole
    (B, max_blocks) table)."""
    return table.at[rows].set(t_rows), length.at[rows].set(l_rows)


class _PrefixIndex:
    """Rolling-hash index over every live slot's token-history PREFIXES.

    Admission donor lookup used to be an O(slots x prompt) LCP scan per
    request; this index makes it O(matched prefix): each live slot
    registers the rolling digest of history[:n] for every n (extended
    incrementally, a few entries per appended token), and a lookup walks
    the prompt's own rolling digest outward, stopping at the FIRST length
    with no registered match — a prompt sharing nothing with any live slot
    costs one probe, independent of its length.  Digest collisions are
    survivable: the engine verifies the winning (slot, n) against the real
    token history and falls back to the exact scan on a mismatch.

    The digest recurrence is shared with the RETAINED pool
    (serve/cache.py ``digest_step``/``prefix_digests``): a prefix hashes
    identically whether its donor is live or long dead."""

    def __init__(self):
        self._map: Dict[tuple, set] = {}      # (n, digest) -> slot ids
        self._keys: Dict[int, List[tuple]] = {}
        self._digest: Dict[int, int] = {}
        self._len: Dict[int, int] = {}

    def add(self, slot: int, tokens) -> None:
        """Extend slot's indexed history by ``tokens`` (incremental)."""
        h = self._digest.get(slot, 0)
        n = self._len.get(slot, 0)
        keys = self._keys.setdefault(slot, [])
        for t in tokens:
            h = digest_step(h, t)
            n += 1
            key = (n, h)
            self._map.setdefault(key, set()).add(slot)
            keys.append(key)
        self._digest[slot] = h
        self._len[slot] = n

    def drop(self, slot: int) -> None:
        for key in self._keys.pop(slot, ()):
            owners = self._map.get(key)
            if owners is not None:
                owners.discard(slot)
                if not owners:
                    del self._map[key]
        self._digest.pop(slot, None)
        self._len.pop(slot, None)

    def lookup(self, prompt: List[int], cap: int):
        """Longest n <= cap with a live slot whose indexed history starts
        with prompt[:n]; returns (slot, n) or (-1, 0).  Walks outward and
        stops at the first unmatched length (a slot matching n+1 tokens
        also matches n, so no longer match can exist past a miss)."""
        h, best, donor = 0, 0, -1
        for n in range(1, cap + 1):
            h = digest_step(h, prompt[n - 1])
            owners = self._map.get((n, h))
            if not owners:
                break
            best, donor = n, next(iter(owners))
        return donor, best

    def check(self, slots) -> None:
        """Index/engine consistency (fuzz-asserted every tick by the
        property harness): every indexed slot is LIVE, its registered
        length equals its real history, its digest chain recomputes from
        that history, and every (n, digest) key's owner set round-trips —
        the staleness a preempt->requeue->recompute cycle could introduce
        if drop/add ever ran twice or not at all."""
        for slot_id, n in self._len.items():
            s = slots[slot_id]
            assert s.active, \
                f"prefix index holds entries for inactive slot {slot_id}"
            assert n == len(s.history), \
                f"slot {slot_id}: indexed length {n} != history " \
                f"{len(s.history)}"
            h = 0
            keys = self._keys.get(slot_id, [])
            assert len(keys) == n, \
                f"slot {slot_id}: {len(keys)} keys for {n} indexed tokens"
            for j, t in enumerate(s.history):
                h = digest_step(h, t)
                assert keys[j] == (j + 1, h), \
                    f"slot {slot_id}: key {j} drifted from history"
                assert slot_id in self._map.get((j + 1, h), ()), \
                    f"slot {slot_id}: key {(j + 1, h)} unregistered"
            assert self._digest.get(slot_id, 0) == h, \
                f"slot {slot_id}: digest accumulator drifted"
        for key, owners in self._map.items():
            assert owners, f"empty owner set left behind for {key}"
            for s_id in owners:
                assert s_id in self._len and self._len[s_id] >= key[0], \
                    f"key {key} names slot {s_id} beyond its indexed length"


class PagedEngine:
    """Non-lockstep continuous batching over the paged KV cache.

    Every engine tick runs at most TWO fused cells planned by the
    ``TickScheduler``:

      * the RAGGED PREFILL LANE (``prefill_many_paged``) — slots with
        unfed prompt tokens advance by a page-aligned chunk of up to
        ``prefill_chunk_tokens`` of them in ONE compiled kernel step
        (append + causal attention over history and the in-flight chunk),
        so admission latency scales with ceil(prompt / T) dispatches, not
        with prompt length; the chunk's single sampled token seeds the
        request's first output when the prompt drains;
      * the DECODE cell (``decode_many_paged``) — generating slots run
        ``cfg.prefill_chunk`` compiled scan steps under a per-step active
        mask: slot ``i`` advances for its granted ``steps[i] <= chunk``
        steps and idles for the rest (null-page appends, frozen length) —
        a slot short on pages runs a PARTIAL chunk instead of sitting out
        the tick.

    Each slot advances at its OWN position (per-slot ``length``), so a
    request admitted mid-flight starts at position 0 of its own pages and
    rope is request-relative by construction: outputs are token-identical
    to a fresh single-request run (property-fuzzed, lane on AND off),
    total traffic can outlive ``max_seq`` (pages recycle through the free
    list), and the jitted cells never recompile (regression-tested via
    their compile-cache sizes).

    PREFIX SHARING: admission matches the new prompt against the token
    history of every live slot; the longest common prefix (capped so at
    least one prompt token is always fed — its logits seed the first
    output) is mapped into the new slot's block table by reference
    (``PagedKVCache.share``).  Shared pages are immutable — the scheduler
    copy-on-write privatizes a shared block before any append touches it —
    and eviction only returns a page once its refcount drains.

    With ``prefill_lane=False`` prompts ride the decode cell as forced
    tokens (prefill-by-decode, one sequential step per prompt token) —
    the measured baseline the lane is benchmarked and gated against.
    Page lifecycle: admission allocates from the free list (or references
    shared pages), finished slots' references are dropped on finish, a
    slot that cannot get capacity STALLS until eviction frees pages, and
    ``defrag()`` compacts the pool.

    OVERLOAD SAFETY: the engine survives any admissible load by
    construction.  ``submit()`` validates capacity and queue depth (typed
    ``REJECTED``, never a stall), every request carries an optional tick
    deadline and ends in a typed terminal ``RequestStatus``, and a tick
    where NO slot can get step capacity preempts victims (fewest tokens
    generated, then most pages held) instead of raising — the victim
    requeues with its emitted output as forced prompt, recomputes through
    the ragged prefill lane, and finishes token-identical to an
    uninterrupted run (``PREEMPTED_RESUMED``).  A seeded ``FaultPlan``
    (serve/faults.py) can inject pool pressure, forced evictions, dropped
    grants and poisoned logits; the always-on out-of-vocab output guard
    quarantines a poisoned slot and requeues its request.  The legacy
    pool-exhausted ``RuntimeError`` survives only behind
    ``preempt=False``.

    SPECULATIVE DECODING (``cfg.spec_k > 0``, greedy-only): a small DRAFT
    model with its own page pool proposes up to k tokens per granted slot
    per tick (one forced-token decode dispatch; a slot the draft has not
    caught up with replays its history through the draft's prefill lane
    first), and the TARGET verifies the whole ragged [feed, p_1..p_k]
    block in ONE prefill-lane dispatch.  A proposal is accepted iff it
    equals the target's greedy argmax at its position, so the emitted
    stream is BIT-IDENTICAL to plain greedy decode while a tick emits up
    to k+1 tokens per slot.  Rejected rows roll back by length truncation
    on both caches (pages stay owned; nothing past a slot's length is
    read or shared), preemption rebuilds the draft by catch-up, and
    deadlines stay tick-denominated.

    Decoder-only attention LMs only (a joining SSM slot would inherit the
    previous occupant's state; whisper needs per-request cross caches).
    """

    def __init__(self, model: Model, params, cfg: ServeConfig,
                 draft_model: Optional[Model] = None, draft_params=None):
        if model.cfg.is_encoder_decoder or model.cfg.mamba_version:
            raise ValueError("paged serving requires a decoder-only "
                             "attention LM (per-slot page tables)")
        self.model = model
        self.params = params
        self.cfg = cfg
        B = cfg.max_batch
        # --- speculative decoding: draft-and-verify ----------------------
        self._spec = cfg.spec_k > 0
        if self._spec:
            if draft_model is None:
                raise ValueError(
                    "spec_k > 0 needs a draft model: "
                    "PagedEngine(model, params, cfg, draft_model=, "
                    "draft_params=)")
            if cfg.temperature != 0.0:
                raise ValueError(
                    "speculative decoding is greedy-only: a proposal is "
                    "accepted iff it equals the target argmax "
                    "(set temperature=0)")
            if not cfg.prefill_lane:
                raise ValueError(
                    "speculative decoding verifies through the ragged "
                    "prefill lane (set prefill_lane=True)")
            if draft_model.cfg.vocab_size != model.cfg.vocab_size:
                raise ValueError(
                    "draft and target must share a tokenizer: vocab "
                    f"{draft_model.cfg.vocab_size} != "
                    f"{model.cfg.vocab_size}")
        self.draft_model = draft_model
        self.draft_params = draft_params
        # decode chunk per tick: a speculative tick verifies up to
        # spec_k proposals plus the feed token in one ragged dispatch
        self._chunk = (cfg.spec_k + 1) if self._spec \
            else max(1, cfg.prefill_chunk)
        self._many = jax.jit(model.decode_many_paged,
                             static_argnames=("num_steps", "temperature"),
                             donate_argnums=(2, 3))   # cache + key
        # the forced-token-free twin: pure-decode ticks (no prompt in
        # flight) skip building and uploading the (chunk, B) forced
        # arrays entirely — a second compiled cell, compiled once
        self._many_plain = jax.jit(
            lambda params, tok, cache, key, steps, *, num_steps,
            temperature: model.decode_many_paged(
                params, tok, cache, key, steps, None, None,
                num_steps=num_steps, temperature=temperature),
            static_argnames=("num_steps", "temperature"),
            donate_argnums=(2, 3))
        # the ragged multi-token PREFILL LANE: one compiled step appends
        # and attends a (B, T) chunk of prompt tokens — a prompt costs
        # ceil(prompt / T) dispatches instead of `prompt` decode steps,
        # and prompt traffic stops paying the (chunk, B) forced-token
        # uploads entirely.  T = 0 disables the lane (legacy
        # prefill-by-decode through the forced decode cell).
        self._chunk_tokens = 0
        if cfg.prefill_lane:
            self._chunk_tokens = (cfg.prefill_chunk_tokens
                                  or model.cfg.prefill_chunk_tokens
                                  or 2 * cfg.page_size)
        self._prefill_lane = jax.jit(model.prefill_many_paged,
                                     static_argnames=("temperature",),
                                     donate_argnums=(2, 3))  # cache + key
        # dirty-row patcher for the device table/length mirrors
        self._patch = jax.jit(_patch_rows, donate_argnums=(0, 1))
        # cross-lifetime retention rides the sharing machinery: without
        # prefix_sharing nothing could ever adopt a retained page
        self._retain = bool(cfg.prefix_sharing and cfg.retain_prefixes)
        self.kv = PagedKVCache(model, B, cfg.max_seq,
                               page_size=cfg.page_size,
                               max_blocks=cfg.max_blocks,
                               num_pages=cfg.num_pages,
                               retain=self._retain,
                               retain_cap=cfg.retain_pool_pages,
                               retain_policy=cfg.retain_policy)
        # DEVICE-RESIDENT tick state: the block table and lengths live on
        # device across ticks; the host patches only rows the cache marked
        # dirty (admission/COW/eviction/defrag) instead of re-uploading the
        # whole (B, max_blocks) table every tick
        self._table_dev = jnp.zeros((B, self.kv.max_blocks), jnp.int32)
        self._length_dev = jnp.zeros((B,), jnp.int32)
        self.kv.dirty.clear()            # mirrors start in sync (all zero)
        # pre-compile every power-of-two patch variant (dirty-row batches
        # are pow2-padded) so a dirty-row sync never compiles mid-tick —
        # log2(B)+1 tiny programs, warmed with zero-on-zero patches
        n = 1
        while True:
            self._table_dev, self._length_dev = self._patch(
                self._table_dev, self._length_dev,
                jnp.zeros((n,), jnp.int32),
                jnp.zeros((n, self.kv.max_blocks), jnp.int32),
                jnp.zeros((n,), jnp.int32))
            if n >= B:
                break
            n = min(2 * n, 1 << (B - 1).bit_length())
        if cfg.prefix_sharing:
            # pre-compile the COW flush for every batch size up to the
            # per-tick bound (capped at 8; rarer, larger bursts compile
            # lazily once) so a COW tick never pays an XLA compile
            chunk = max(self._chunk, self._chunk_tokens)
            bound = B * (-(-chunk // self.kv.page) + 1)
            self.kv.warm_copy(tuple(range(1, min(bound, 8) + 1)))
        # --- draft-side state (speculative mode) --------------------------
        self.dkv: Optional[PagedKVCache] = None
        if self._spec:
            # the target's VERIFY cell: all k+1 positions unembedded at
            # f32, the accepted prefix reduced on device (no PRNG)
            self._verify = jax.jit(model.verify_many_paged,
                                   donate_argnums=(2,))
            # draft cells: the forced-token decode twin (PROPOSE — the
            # steady-state <=1-token history deficit replays as a forced
            # step 0) and the prefill lane (CATCH-UP after a fresh admit,
            # a prefix-share adoption, or a preempt-resume)
            self._draft_many = jax.jit(
                draft_model.decode_many_paged,
                static_argnames=("num_steps", "temperature"),
                donate_argnums=(2, 3))
            self._draft_prefill = jax.jit(
                draft_model.prefill_many_paged,
                static_argnames=("temperature",),
                donate_argnums=(2, 3))
            # the draft keeps its own page pool: no sharing and no
            # retention (rejected rows roll back by length truncation;
            # a preempted slot rebuilds through catch-up)
            self.dkv = PagedKVCache(draft_model, B, cfg.max_seq,
                                    page_size=cfg.page_size,
                                    max_blocks=cfg.max_blocks,
                                    num_pages=cfg.num_pages)
            self._dtable_dev = jnp.zeros((B, self.dkv.max_blocks),
                                         jnp.int32)
            self._dlength_dev = jnp.zeros((B,), jnp.int32)
            self.dkv.dirty.clear()       # mirrors start in sync (all zero)
            self._dkey = jax.random.key(cfg.seed + 1)
        self._pindex = _PrefixIndex()
        self.scheduler = TickScheduler(fairness=cfg.fairness,
                                       tick_budget=cfg.tick_budget,
                                       preempt_policy=cfg.preempt_policy)
        self.key = jax.random.key(cfg.seed)
        self.slots = [_Slot() for _ in range(B)]
        self.queue: List[Request] = []
        self.results: Dict[int, List[int]] = {}
        self._feed = np.full((B,), cfg.pad_id, np.int32)
        self._next_rid = 0
        self.steps_run = 0                # engine ticks (chunks)
        # --- request lifecycle / overload state --------------------------
        self.ticks = 0                    # step() calls, incl. idle ticks
                                          # (the deadline / fault clock)
        self._idle = 0                    # consecutive no-work busy ticks
        self.no_progress_ticks = 0        # CUMULATIVE idle-but-busy ticks
                                          # (the wedge detector resets
                                          # _idle on progress; this one
                                          # survives as a health stat)
        self.snapshots_written = 0        # crash-consistency snapshots
        self._last_snapshot_tick = -1     # dedupe guard for the hook
        self._reqs: Dict[int, Request] = {}
        self.status: Dict[int, RequestStatus] = {}
        self.reject_reason: Dict[int, str] = {}
        self.preemptions = 0              # capacity preemptions + forced
                                          # evictions (fault-injected)
        self.recompute_tokens = 0         # tokens re-appended on resume
        self.rejected = 0
        self.cancelled = 0
        self.deadline_exceeded = 0
        self.quarantines = 0              # poison-triggered slot requeues
        self.dropped_grants = 0           # granted tokens a fault dropped
        self.fault_counts: Dict[str, int] = {}
        self._quarantined: Dict[int, int] = {}  # slot -> usable-again tick
        self._squeezed: List[Tuple[int, List[int]]] = []  # (release, pages)
        self._faults = None               # armed serve/faults.py FaultPlan
        self._drop_slots: Set[int] = set()
        self._poison_slots: Set[int] = set()
        self.tokens_out = 0               # kept (non-discarded) tokens
        self.tokens_appended = 0          # fresh K/V rows written (physical)
        # --- speculative decoding counters -------------------------------
        self.spec_proposed = 0            # draft tokens offered to verify
        self.spec_accepted = 0            # proposals the target accepted
        self.spec_trunc_tokens = 0        # target K/V rows rolled back
        self.draft_dispatches = 0         # draft catch-up + propose calls
        self.verify_dispatches = 0        # target verify calls
        self.draft_dispatch_trace: List[int] = []   # per busy tick
        self.verify_dispatch_trace: List[int] = []
        self.shared_tokens = 0            # prompt tokens served by reference
        self.joins = 0
        self.stalls = 0
        self.util_trace: List[float] = []        # per-tick page utilization
        self.occupancy_trace: List[float] = []   # per-tick row occupancy
        # --- tick-overhead accounting (the host side the roofline can't
        # see: BENCH_serve.json's tick_overhead section reads these) ------
        self.table_upload_bytes = 0       # dirty-row table/length patches
        self.forced_upload_bytes = 0      # forced-token arrays (legacy
                                          # prefill-by-decode only: stays 0
                                          # while the prefill lane routes
                                          # all prompt traffic — gated)
        self.prefill_upload_bytes = 0     # (B, T) chunk tokens + grants
        self.upload_bytes = 0             # all per-tick host->device bytes
        self.host_ms_trace: List[float] = []     # host work per tick (ms)
        self.dispatch_trace: List[int] = []      # device calls per tick
        self.upload_trace: List[int] = []        # bytes uploaded per tick

    # -- request lifecycle -----------------------------------------------------

    def _admissible(self, prompt: np.ndarray, mnt: int) -> Optional[str]:
        """None if the request can complete on this engine; otherwise the
        typed rejection reason.  Validated at submit() — an inadmissible
        request used to stall forever or raise deep inside a tick."""
        total = int(prompt.size) + mnt
        blocks = -(-total // self.kv.page)
        if blocks > self.kv.max_blocks:
            return (f"prompt+output needs {blocks} blocks > max_blocks="
                    f"{self.kv.max_blocks} (max_seq={self.cfg.max_seq})")
        if blocks > self.kv.num_pages - 1:
            return (f"prompt+output needs {blocks} blocks > pool of "
                    f"{self.kv.num_pages - 1} allocatable pages")
        if self.cfg.max_queue > 0 and len(self.queue) >= self.cfg.max_queue:
            return f"queue full ({self.cfg.max_queue} requests waiting)"
        return None

    def submit(self, prompt: np.ndarray,
               max_new_tokens: Optional[int] = None,
               deadline_ticks: Optional[int] = None) -> int:
        """Queue a request.  Admission is BOUNDED: a prompt+output that can
        never fit the slot table or the page pool, or a submit past
        ``max_queue`` depth, gets a typed ``REJECTED`` status (reason in
        ``reject_reason[rid]``) instead of a stall or a deep-tick raise.
        ``deadline_ticks`` (default ``cfg.deadline_ticks``; 0 = none)
        bounds the engine ticks the request may stay live."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt: a slot needs at least one "
                             "token to feed the decode step")
        rid = self._next_rid
        self._next_rid += 1
        mnt = max_new_tokens or self.cfg.max_new_tokens
        reason = self._admissible(prompt, mnt)
        if reason is not None:
            self.status[rid] = RequestStatus.REJECTED
            self.reject_reason[rid] = reason
            self.results[rid] = []
            self.rejected += 1
            return rid
        dl = self.cfg.deadline_ticks if deadline_ticks is None \
            else deadline_ticks
        req = Request(rid, prompt, mnt,
                      deadline_tick=self.ticks + dl if dl > 0 else -1)
        self._reqs[rid] = req
        self.status[rid] = RequestStatus.QUEUED
        self.queue.append(req)
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or running request: partial output is kept in
        ``results[rid]``, status becomes ``CANCELLED``, slot/pages are
        released.  False if the rid is unknown or already terminal."""
        st = self.status.get(rid)
        if st is None or st in TERMINAL_STATUSES:
            return False
        req = self._reqs[rid]
        if st is RequestStatus.QUEUED:
            self.queue.remove(req)
        else:
            i = next((j for j, s in enumerate(self.slots)
                      if s.active and s.rid == rid), -1)
            if i >= 0:
                req.emitted.extend(self.slots[i].out)
                self._release_slot(i)
        self.results[rid] = list(req.emitted)
        self.status[rid] = RequestStatus.CANCELLED
        self.cancelled += 1
        return True

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s.active for s in self.slots)

    def run(self) -> Dict[int, List[int]]:
        """Drain queue + slots; returns {rid: generated tokens}."""
        while self.busy:
            self.step()
        return self.results

    # -- fault injection (serve/faults.py) --------------------------------------

    def install_faults(self, plan) -> None:
        """Arm a ``FaultPlan``; its events fire at the top of their tick.
        Squeezed pages auto-release when their duration elapses (``step()``
        keeps processing releases even while idle, so a squeeze can starve
        ticks but never deadlock the engine)."""
        self._faults = plan

    def _apply_faults(self) -> None:
        now = self.ticks
        if self._squeezed:                # releases first: a squeeze never
            keep = []                     # outlives its scheduled duration
            for until, pages in self._squeezed:
                if until <= now:
                    self.kv.release_pages(pages)
                else:
                    keep.append((until, pages))
            self._squeezed = keep
        if self._faults is None:
            return
        events = self._faults.events_at(now)
        for ev in events:
            if ev.kind == "kill":         # process death pre-empts the
                self.fault_counts["kill"] = \
                    self.fault_counts.get("kill", 0) + 1
                raise EngineKilled(now)   # WHOLE tick: no state advanced
        for ev in events:
            self.fault_counts[ev.kind] = self.fault_counts.get(ev.kind, 0) + 1
            if ev.kind == "squeeze":      # pool pressure: free list shrinks
                pages = self.kv.seize_pages(ev.pages)
                if pages:
                    self._squeezed.append((now + max(1, ev.duration), pages))
            elif ev.kind == "evict":      # forced eviction -> requeue
                i = ev.slot
                if not (0 <= i < len(self.slots) and self.slots[i].active):
                    i = next((j for j, s in enumerate(self.slots)
                              if s.active), -1)
                if i >= 0:
                    self._preempt(i)
            elif ev.kind == "drop":       # this tick's grant vanishes
                self._drop_slots.update(
                    range(len(self.slots)) if ev.slot < 0 else (ev.slot,))
            elif ev.kind == "poison":     # nonfinite logits: the sampled
                self._poison_slots.update(  # token comes back out-of-vocab
                    range(len(self.slots)) if ev.slot < 0 else (ev.slot,))

    # -- preemption / expiry -----------------------------------------------------

    def _release_slot(self, i: int) -> None:
        """Return slot ``i`` to the pool: pages freed refcount-aware
        (shared pages survive for their other referents), prefix index
        dropped, feed reset.  With retention on, the slot's page-aligned
        token-history prefix moves to the RETAINED pool instead of the
        free list — finish and eviction alike (an evicted victim's resume
        is the hottest possible re-share)."""
        history = self.slots[i].history
        self.slots[i] = _Slot()
        self._feed[i] = self.cfg.pad_id
        self._pindex.drop(i)
        self.kv.free_slot(i, retain_tokens=history if self._retain else None)
        if self.dkv is not None:          # draft pages never retain
            self.dkv.free_slot(i)

    def _preempt(self, i: int, quarantine: bool = False) -> None:
        """Evict slot ``i`` and requeue its request AT THE FRONT with all
        output emitted so far: on re-admission the emitted tokens replay as
        forced prompt through the ragged prefill lane (recompute), so the
        resumed request finishes token-identical to an uninterrupted run
        (greedy decode is deterministic and the lane is pinned
        bit-identical to stepwise decode)."""
        slot = self.slots[i]
        req = self._reqs[slot.rid]
        req.emitted.extend(slot.out)
        req.preempts += 1
        self.status[req.rid] = RequestStatus.QUEUED
        self.queue.insert(0, req)
        self._release_slot(i)
        if quarantine:
            self._quarantined[i] = self.ticks \
                + max(1, self.cfg.quarantine_ticks)
            self.quarantines += 1
        else:
            self.preemptions += 1

    def _preempt_for_capacity(self) -> bool:
        """Victim selection when no slot can get step capacity.  Requires
        >= 2 active slots: preempting the survivors' blocker strictly
        advances total generated tokens, so the overload loop terminates; a
        LONE stuck slot is only possible under fault-injected pool pressure
        (its pages release on schedule — wait, don't thrash it)."""
        active = [i for i, s in enumerate(self.slots) if s.active]
        if len(active) < 2:
            return False
        gen = {i: len(self._reqs[self.slots[i].rid].emitted)
               + len(self.slots[i].out) for i in active}
        victim = self.scheduler.pick_victim(self.slots, self.kv,
                                            generated=gen)
        if victim < 0:
            return False
        self._preempt(victim)
        return True

    def _expire_deadlines(self) -> None:
        """Requests past their tick budget terminate as DEADLINE_EXCEEDED
        with whatever output they produced — queued and running alike (a
        preempted request's deadline keeps ticking while it waits)."""
        now = self.ticks
        for req in [r for r in self.queue if 0 <= r.deadline_tick < now]:
            self.queue.remove(req)
            self.results[req.rid] = list(req.emitted)
            self.status[req.rid] = RequestStatus.DEADLINE_EXCEEDED
            self.deadline_exceeded += 1
        for i, slot in enumerate(self.slots):
            if not slot.active:
                continue
            req = self._reqs[slot.rid]
            if 0 <= req.deadline_tick < now:
                req.emitted.extend(slot.out)
                self.results[req.rid] = list(req.emitted)
                self.status[req.rid] = RequestStatus.DEADLINE_EXCEEDED
                self.deadline_exceeded += 1
                self._release_slot(i)

    def _find_donor(self, prompt: List[int]):
        """Longest-common-prefix match of ``prompt`` against (a) the LIVE
        slots' resident token histories via the rolling-hash prefix index
        (O(matched prefix), not O(slots x prompt)) and (b) the RETAINED
        pool of dead donors' page-aligned prefixes (same digests, via
        ``kv.match_retained``).  Returns (kind, ref, n_shared) where kind
        is "live" (ref = slot index), "retained" (ref = RetainedPrefix)
        or None when nothing clears ``share_min_tokens``.  Live matches
        win ties: they can extend past page boundaries and keep feeding
        the index.  The cap at ``len(prompt) - 1`` keeps the last prompt
        token always fed (its logits seed the first sampled output)."""
        cap = len(prompt) - 1
        donor, best = self._pindex.lookup(prompt, cap)
        if donor >= 0 and not (self.slots[donor].active
                               and self.slots[donor].history[:best]
                               == prompt[:best]):
            # digest collision (or index drift): exact-scan fallback
            best, donor = 0, -1
            for j, s in enumerate(self.slots):
                if not s.active:
                    continue
                n = min(_lcp(prompt, s.history), cap)
                if n > best:
                    best, donor = n, j
        entry, n_ret = (None, 0)
        if self._retain:
            entry, n_ret = self.kv.match_retained(prompt, cap)
        min_share = max(1, self.cfg.share_min_tokens)
        if best >= n_ret and best >= min_share:
            return "live", donor, best
        if entry is not None and n_ret >= min_share:
            return "retained", entry, n_ret
        return None, None, 0

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            if self._quarantined.get(i, 0) > self.ticks:
                continue                   # poisoned slot sits out
            head = self.queue[0]
            # a resumed request replays its emitted output as forced
            # prompt: recompute rides the ragged prefill lane, and greedy
            # decode continues token-identically from where it left off
            prompt = [int(t) for t in head.prompt] + list(head.emitted)
            kind, ref, n_shared = (None, None, 0)
            if self.cfg.prefix_sharing:
                kind, ref, n_shared = self._find_donor(prompt)
            if n_shared == 0 and self.kv.allocatable == 0:
                break                      # pool dry: wait for eviction
            req = self.queue.pop(0)
            if kind == "live":
                self.kv.share(i, ref, n_shared)
                self.shared_tokens += n_shared
            elif kind == "retained":
                # cross-lifetime hit: the donor is gone, its pages are not
                self.kv.adopt_retained(i, ref, n_shared)
                self.shared_tokens += n_shared
            # no donor: the slot's length row is already 0 (free_slot
            # zeroed and dirty-marked it; a fresh engine starts at 0)
            # best-effort first page; a dry pool stalls (not deadlocks):
            # the scheduler re-tries every tick as evictions refill the list
            self.kv.ensure(i, n_shared + 1)
            self.slots[i] = _Slot(rid=req.rid, forced=prompt[n_shared + 1:],
                                  out=[], history=prompt[:n_shared],
                                  budget=req.max_new_tokens
                                  - len(req.emitted),
                                  prompt_left=len(prompt) - n_shared,
                                  active=True)
            if self.cfg.prefix_sharing:
                self._pindex.add(i, prompt[:n_shared])
            self._feed[i] = prompt[n_shared]
            self.status[req.rid] = RequestStatus.RUNNING
            if req.preempts:
                # re-appended work (prefix-shared tokens cost nothing) —
                # the bench's recompute-overhead fraction reads this
                self.recompute_tokens += len(prompt) - n_shared
            self.joins += 1

    def _finish(self, i: int) -> None:
        slot = self.slots[i]
        req = self._reqs[slot.rid]
        self.results[slot.rid] = req.emitted + slot.out
        self.status[slot.rid] = (RequestStatus.PREEMPTED_RESUMED
                                 if req.preempts else RequestStatus.FINISHED)
        self._release_slot(i)             # drop the slot's page references

    # -- stepping ---------------------------------------------------------------

    def defrag(self) -> None:
        self.kv.defrag()

    def _sync_dirty(self, kv: PagedKVCache, table_dev, length_dev):
        """Patch a device table/length mirror pair at ``kv``'s dirty rows.
        The row batch is padded to a power of two (repeating the first
        dirty row — an idempotent scatter) so the patcher's compile
        universe is log2(B)-bounded, not one program per distinct count.
        Returns the updated mirrors plus the bytes uploaded (0 = the
        mirrors were already in sync, no dispatch)."""
        if not kv.dirty:
            return table_dev, length_dev, 0
        rows = sorted(kv.dirty)
        kv.dirty.clear()
        pad = 1 << (len(rows) - 1).bit_length()
        rows = np.asarray(rows + rows[:1] * (pad - len(rows)), np.int32)
        table_dev, length_dev = self._patch(
            table_dev, length_dev, jnp.asarray(rows),
            jnp.asarray(kv.table[rows]), jnp.asarray(kv.length[rows]))
        return table_dev, length_dev, \
            int(rows.size) * (kv.max_blocks + 1) * 4

    # -- speculative decoding ----------------------------------------------------

    @property
    def accept_rate(self) -> float:
        """Fraction of draft proposals the target accepted (1.0 until the
        first speculative tick)."""
        return self.spec_accepted / max(1, self.spec_proposed)

    def _spec_decode(self, steps, chunk: int, cache):
        """One speculative decode tick over the granted slots:

          1. CATCH-UP — a slot whose draft cache is missing more than one
             history token (fresh admit, prefix-share adoption,
             preempt-resume: the draft never shares pages, it recomputes)
             replays the gap through the DRAFT prefill lane in fixed-width
             chunks until at most one token trails;
          2. PROPOSE — one draft forced-token decode dispatch, ``num_steps
             = spec_k + 1`` static: a slot with a 1-token deficit feeds
             the missing history token and forces the target feed in as
             step 0, so the deficit never costs an extra dispatch;
          3. host-sync the proposals (a device wait, reported as such);
          4. VERIFY — ONE ragged prefill-lane dispatch on the TARGET over
             [feed, p_1..p_k] per slot, all positions unembedded at f32,
             the accepted prefix reduced on device.

        Returns (greedy, accept, vgr, cache, upload_bytes, draft_disp,
        verify_disp, wait_s).  ``greedy``/``accept`` are the verify cell's
        device outputs (the caller syncs them with the tick's other
        outputs); ``vgr`` is the (B,) int32 K/V rows the verify actually
        appended — 1 + proposals, which drops below the planned grant only
        when the draft pool capped a slot (it then advances one verified
        token per tick until pages free up)."""
        cfg = self.cfg
        B = len(self.slots)
        dkv = self.dkv
        upload = 0
        draft_disp = 0
        rows = [i for i in range(B)
                if self.slots[i].active and steps[i] > 0]

        def dcache():
            c = {"k": dkv.k, "v": dkv.v, "table": self._dtable_dev,
                 "length": self._dlength_dev}
            if dkv.quantized:
                c["k_scale"] = dkv.k_scale
                c["v_scale"] = dkv.v_scale
            return c

        def writeback(c):
            dkv.k, dkv.v = c["k"], c["v"]
            if dkv.quantized:
                dkv.k_scale, dkv.v_scale = c["k_scale"], c["v_scale"]
            self._dtable_dev = c["table"]
            self._dlength_dev = c["length"]

        # --- catch-up: stream missing history through the draft lane -----
        Tc = max(self._chunk_tokens, chunk)
        while True:
            cg = np.zeros((B,), np.int32)
            tok_c = np.full((B, Tc), cfg.pad_id, np.int32)
            for i in rows:
                hist = self.slots[i].history
                dlen = int(dkv.length[i])
                miss = len(hist) - dlen
                if miss <= 1:
                    continue
                take = min(miss, Tc)
                if not dkv.ensure(i, dlen + take):
                    # draft pool dry: partial catch-up — the slot keeps
                    # verifying at grant 1 until draft pages free up
                    take = min(take,
                               len(dkv.owned[i]) * dkv.page - dlen)
                if take <= 0:
                    continue
                cg[i] = take
                tok_c[i, :take] = hist[dlen:dlen + take]
            if not cg.any():
                break
            self._dtable_dev, self._dlength_dev, b = self._sync_dirty(
                dkv, self._dtable_dev, self._dlength_dev)
            upload += b + B * (Tc + 1) * 4
            draft_disp += bool(b) + 1
            _, c, self._dkey = self._draft_prefill(
                self.draft_params, jnp.asarray(tok_c), dcache(),
                self._dkey, jnp.asarray(cg), temperature=0.0)
            writeback(c)
            dkv.length += cg

        # --- propose: grant = deficit (<= 1) + k proposals per slot ------
        off = np.zeros((B,), np.int32)
        dgr = np.zeros((B,), np.int32)
        k_prop = np.zeros((B,), np.int32)
        feed_d = np.full((B,), cfg.pad_id, np.int32)
        forced_tok = np.full((chunk, B), cfg.pad_id, np.int32)
        forced_mask = np.zeros((chunk, B), bool)
        for i in rows:
            hist = self.slots[i].history
            d = len(hist) - int(dkv.length[i])
            if d > 1:
                continue                  # still catching up: no proposals
            k = int(steps[i]) - 1
            if k and not dkv.ensure(i, len(hist) + k):
                k = max(0, len(dkv.owned[i]) * dkv.page - len(hist))
            if d == 0 and k == 0:
                continue                  # nothing for the draft to do
            off[i], k_prop[i], dgr[i] = d, k, d + k
            if d:                         # replay the missing history
                feed_d[i] = hist[-1]      # token, force the feed in as
                forced_tok[0, i] = self._feed[i]   # the step-0 output
                forced_mask[0, i] = True
            else:
                feed_d[i] = self._feed[i]
        toks_d = None
        if dgr.any():
            self._dtable_dev, self._dlength_dev, b = self._sync_dirty(
                dkv, self._dtable_dev, self._dlength_dev)
            # feed + grants + forced tok/mask — DRAFT-side traffic (the
            # gated forced_upload_bytes tracks prompt traffic only)
            upload += b + 2 * B * 4 + chunk * B * (4 + 1)
            draft_disp += bool(b) + 1
            toks_d, c, self._dkey = self._draft_many(
                self.draft_params, jnp.asarray(feed_d)[:, None], dcache(),
                self._dkey, jnp.asarray(dgr), jnp.asarray(forced_tok),
                jnp.asarray(forced_mask), num_steps=chunk,
                temperature=0.0)
            writeback(c)
            dkv.length += dgr

        # --- host-sync the proposals (device wait, not host work) --------
        w0 = time.perf_counter()
        toks_d_np = np.array(toks_d) if toks_d is not None else None
        wait = time.perf_counter() - w0

        # --- verify: ONE ragged prefill-lane dispatch on the target ------
        vocab = self.model.cfg.vocab_size
        vgr = np.zeros((B,), np.int32)
        tok_v = np.full((B, chunk), cfg.pad_id, np.int32)
        for i in rows:
            tok_v[i, 0] = self._feed[i]
            k = int(k_prop[i])
            for s in range(k):
                t = int(toks_d_np[int(off[i]) + s, i])
                # clamp: an out-of-range draft sample must not index past
                # the target embedding (it just gets rejected)
                tok_v[i, 1 + s] = min(max(t, 0), vocab - 1)
            vgr[i] = 1 + k
            self.spec_proposed += k
        upload += B * (chunk + 1) * 4
        greedy, accept, cache = self._verify(
            self.params, jnp.asarray(tok_v), cache, jnp.asarray(vgr))
        return greedy, accept, vgr, cache, upload, draft_disp, 1, wait

    def _spec_bookkeep(self, vgr, greedy_np, accept_np,
                       poisoned: Set[int]) -> None:
        """Post-verify bookkeeping for a speculative tick: emit the
        accepted prefix plus the bonus token per slot, TRUNCATE the
        target's rejected K/V rows (length rollback — the pages stay
        owned and the garbage rows rewrite on the next append; nothing
        past a slot's length is ever read or shared), and roll the draft
        cache back to the accepted frontier."""
        cfg = self.cfg
        for i, slot in enumerate(self.slots):
            v = int(vgr[i])
            if not slot.active or v == 0 or i in poisoned:
                continue
            a = int(accept_np[i])
            kept = a + 1                  # accepted proposals + bonus
            L = len(slot.history)
            if kept < v:                  # rejected rows roll back
                self.kv.length[i] -= v - kept
                self.kv.dirty.add(i)
                self.spec_trunc_tokens += v - kept
            fed = [int(self._feed[i])] \
                + [int(greedy_np[i, s]) for s in range(kept - 1)]
            slot.history.extend(fed)
            if cfg.prefix_sharing:
                self._pindex.add(i, fed)
            slot.served += kept
            self.spec_accepted += a
            # draft rollback: the propose dispatch appended [feed,
            # p_1..p_{k-1}] past the shared history — keep the prefix the
            # target accepted.  All-k accepted leaves a 1-token deficit
            # (p_k was sampled, never appended) that next tick's forced
            # replay absorbs.
            cur = int(self.dkv.length[i])
            dvalid = min(cur, L + min(kept, v - 1))
            if dvalid != cur:
                self.dkv.length[i] = dvalid
                self.dkv.dirty.add(i)
            finished = False
            for s in range(kept):
                tok = int(greedy_np[i, s])
                slot.out.append(tok)
                self.tokens_out += 1
                if (cfg.eos_id >= 0 and tok == cfg.eos_id) \
                        or len(slot.out) >= slot.budget:
                    finished = True
                    break
            if finished:
                self._finish(i)
            else:
                self._feed[i] = greedy_np[i, kept - 1]

    def step(self) -> None:
        """One engine tick: admit, plan (prefill-lane + decode grants /
        partial grants / batched COW / fairness), sync the dirty rows of
        the device-resident table state, then advance every granted slot —
        prompt chunks through the RAGGED PREFILL LANE (one compiled kernel
        step appends and attends up to T prompt tokens per slot; a prompt
        costs ceil(prompt / T) dispatches instead of ``prompt`` decode
        steps) and decode grants through the fused decode cell.

        The tick is kept as thin as the kernel: the tick's COW copies are
        ONE batched dispatch (flushed inside ``plan``), the block table and
        lengths live on device and only dirty rows are patched (a
        steady-state decode tick uploads zero table bytes), the per-slot
        grants go up as B ints (the per-step decode mask is built on
        device), prompt traffic moves as ONE ragged (B, T) token block per
        prefill chunk (the per-step (chunk, B) forced-token/mask uploads
        are retired for prompts — ``forced_upload_bytes`` stays 0 and is
        gated), and a pure-decode tick runs the forced-token-free twin
        cell."""
        cfg = self.cfg
        chunk = self._chunk
        T = self._chunk_tokens
        t0 = time.perf_counter()
        self.ticks += 1
        self._apply_faults()
        self._expire_deadlines()
        self._admit()
        cow_disp0 = self.kv.cow_dispatches
        plan = self.scheduler.plan(self.slots, self.kv, chunk,
                                   prefill_tokens=T)
        self.stalls += plan.stalled
        # PREEMPT-AND-RECOMPUTE: when no slot can get step capacity, evict
        # victims (fewest tokens generated, then most pages held) until the
        # survivors can advance — the victims requeue with their emitted
        # output as forced prompt and finish token-identical later.  Each
        # iteration drops one active slot, so the loop is bounded by B.
        while not plan.any_work and cfg.preempt \
                and self._preempt_for_capacity():
            plan = self.scheduler.plan(self.slots, self.kv, chunk,
                                       prefill_tokens=T)
            self.stalls += plan.stalled
        # dropped-grant fault: the victims' granted work vanishes AFTER
        # planning (a dropped grant must look like lost work, not trigger
        # preemption) — the scheduler simply re-grants next tick
        if self._drop_slots:
            for i in self._drop_slots:
                if 0 <= i < len(self.slots):
                    d = int(plan.steps[i]) + int(plan.prefill[i])
                    if d:
                        self.dropped_grants += d
                        plan.steps[i] = 0
                        plan.prefill[i] = 0
            self._drop_slots.clear()
        if not plan.any_work:
            self._poison_slots.clear()
            if self.busy:
                if not cfg.preempt:
                    raise RuntimeError(
                        f"page pool exhausted: {len(self.kv.free)} free "
                        f"pages cannot give any slot step capacity "
                        f"(num_pages={self.kv.num_pages}, "
                        f"page={self.kv.page})")
                # idle-but-busy ticks are BOUNDED: every queued request is
                # admissible, lone-slot stalls only ride out fault squeezes
                # (which release on schedule), so sustained idling means a
                # bookkeeping bug — fail loudly instead of spinning
                self._idle += 1
                self.no_progress_ticks += 1
                if self._idle > cfg.wedge_ticks:
                    raise RuntimeError(
                        f"engine wedged: {cfg.wedge_ticks} consecutive "
                        "idle ticks with work pending (queue="
                        f"{len(self.queue)}, active="
                        f"{sum(s.active for s in self.slots)}, free="
                        f"{len(self.kv.free)}, seized="
                        f"{len(self.kv.seized)})")
            self._maybe_snapshot()
            return
        self._idle = 0
        B = len(self.slots)
        steps = plan.steps
        pgr = plan.prefill
        dispatches = self.kv.cow_dispatches - cow_disp0   # batched COW: <= 1
        tick_upload = 0

        # dirty-row sync of the device table/length mirrors: only rows
        # admission/COW/eviction/defrag/truncation touched; nothing in
        # steady state.
        self._table_dev, self._length_dev, row_bytes = self._sync_dirty(
            self.kv, self._table_dev, self._length_dev)
        if row_bytes:
            self.table_upload_bytes += row_bytes
            tick_upload += row_bytes
            dispatches += 1

        cache = {"k": self.kv.k, "v": self.kv.v,
                 "table": self._table_dev, "length": self._length_dev}
        if self.kv.quantized:            # scale pools ride the cache pytree
            cache["k_scale"] = self.kv.k_scale
            cache["v_scale"] = self.kv.v_scale

        # --- prefill lane: one ragged (B, T) chunk of prompt tokens ------
        nxt = None
        if pgr.any():
            tok_mat = np.full((B, T), cfg.pad_id, np.int32)
            for i, slot in enumerate(self.slots):
                g = int(pgr[i])
                if g:
                    tok_mat[i, 0] = self._feed[i]
                    if g > 1:
                        tok_mat[i, 1:g] = slot.forced[:g - 1]
            pbytes = B * (T + 1) * 4          # token block + grant vector
            self.prefill_upload_bytes += pbytes
            tick_upload += pbytes
            nxt, cache, self.key = self._prefill_lane(
                self.params, jnp.asarray(tok_mat), cache, self.key,
                jnp.asarray(pgr), temperature=cfg.temperature)
            dispatches += 1

        # --- decode lane: the fused scan over decode grants, or (spec
        # mode) the draft-propose + target-verify pipeline ----------------
        toks = None
        greedy = accept = None
        vgr = steps                       # K/V rows the lane appends
        d_disp = v_disp = 0
        spec_wait = 0.0
        if steps.any():
            if self._spec:
                (greedy, accept, vgr, cache, d_up, d_disp, v_disp,
                 spec_wait) = self._spec_decode(steps, chunk, cache)
                tick_upload += d_up
                dispatches += d_disp + v_disp
                self.draft_dispatches += d_disp
                self.verify_dispatches += v_disp
            else:
                tick_upload += 2 * B * 4      # feed tokens + step grants
                feed = jnp.asarray(self._feed)[:, None]
                steps_dev = jnp.asarray(steps)
                prompt_in_flight = any(s.active and s.forced and steps[i]
                                       for i, s in enumerate(self.slots))
                if prompt_in_flight:
                    # legacy prefill-by-decode (lane disabled): prompts
                    # ride the decode cell as forced tokens
                    forced_tok = np.full((chunk, B), cfg.pad_id, np.int32)
                    forced_mask = np.zeros((chunk, B), bool)
                    for i, slot in enumerate(self.slots):
                        for s in range(min(len(slot.forced),
                                           int(steps[i]))):
                            forced_tok[s, i] = slot.forced[s]
                            forced_mask[s, i] = True
                    forced_bytes = chunk * B * (4 + 1)
                    self.forced_upload_bytes += forced_bytes
                    tick_upload += forced_bytes
                    toks, cache, self.key = self._many(
                        self.params, feed, cache, self.key, steps_dev,
                        jnp.asarray(forced_tok), jnp.asarray(forced_mask),
                        num_steps=chunk, temperature=cfg.temperature)
                else:
                    toks, cache, self.key = self._many_plain(
                        self.params, feed, cache, self.key, steps_dev,
                        num_steps=chunk, temperature=cfg.temperature)
                dispatches += 1
        self.kv.k = cache["k"]
        self.kv.v = cache["v"]
        if self.kv.quantized:
            self.kv.k_scale = cache["k_scale"]
            self.kv.v_scale = cache["v_scale"]
        self._table_dev = cache["table"]
        self._length_dev = cache["length"]    # device already advanced it
        self.kv.length += vgr + pgr           # host mirror of the increment
        self.tokens_appended += int(vgr.sum()) + int(pgr.sum())
        self.steps_run += 1
        if cfg.trace_pool:
            self.util_trace.append(self.kv.utilization())
            self.occupancy_trace.append(self.kv.occupancy())

        t1 = time.perf_counter()
        toks_np = np.array(toks) if toks is not None else None  # device wait
        nxt_np = np.array(nxt) if nxt is not None else None
        greedy_np = np.array(greedy) if greedy is not None else None
        accept_np = np.array(accept) if accept is not None else None
        t2 = time.perf_counter()
        # poison fault: nonfinite logits make the sampler return garbage —
        # modeled as an out-of-vocab sentinel overwriting the slot's
        # sampled tokens for this tick (in spec mode the WHOLE verified
        # window poisons — every kept token is garbage, not just one)
        if self._poison_slots:
            for i in self._poison_slots:
                if 0 <= i < B:
                    if toks_np is not None and steps[i]:
                        toks_np[:, i] = -1
                    if greedy_np is not None and vgr[i]:
                        greedy_np[i, :] = -1
                    if nxt_np is not None and pgr[i]:
                        nxt_np[i] = -1
            self._poison_slots.clear()
        # ALWAYS-ON output guard (not fault-plan-gated): a sampled token
        # outside the vocabulary means the slot's logits were garbage —
        # quarantine the slot and requeue the request with its PRE-TICK
        # output, skipping this tick's bookkeeping for it entirely.  A
        # speculative tick emits up to k+1 tokens per slot, so the guard
        # inspects EVERY kept token (accepted prefix + bonus), not one.
        vocab = self.model.cfg.vocab_size
        poisoned: Set[int] = set()
        for i, slot in enumerate(self.slots):
            if not slot.active:
                continue
            g, si = int(pgr[i]), int(vgr[i])
            if g and slot.prompt_left - g <= 0:   # sampled token is kept
                t = int(nxt_np[i])
                if t < 0 or t >= vocab:
                    poisoned.add(i)
            if si and i not in poisoned:
                if greedy_np is not None:         # speculative tick
                    for s in range(int(accept_np[i]) + 1):
                        t = int(greedy_np[i, s])
                        if t < 0 or t >= vocab:
                            poisoned.add(i)
                            break
                else:
                    for s in range(si):
                        t = int(toks_np[s, i])
                        if t < 0 or t >= vocab:
                            poisoned.add(i)
                            break
        # prefill-lane bookkeeping: the chunk's appended tokens are known
        # on the host (feed + forced prefix) — only the ONE sampled token
        # per slot came back, and it matters only when the prompt drained
        for i, slot in enumerate(self.slots):
            g = int(pgr[i])
            if not slot.active or g == 0 or i in poisoned:
                continue
            fed = [int(self._feed[i])] + [int(t) for t in slot.forced[:g - 1]]
            slot.history.extend(fed)
            if cfg.prefix_sharing:          # the index only feeds donor
                self._pindex.add(i, fed)    # lookup, gated the same way
            slot.served += g
            del slot.forced[:g - 1]
            slot.prompt_left -= g
            if slot.prompt_left > 0:
                # mid-prompt: the sampled token is a known prompt token's
                # prediction — discard it, feed the next prompt token
                self._feed[i] = slot.forced.pop(0)
                continue
            tok = int(nxt_np[i])            # the request's FIRST output
            slot.out.append(tok)
            self.tokens_out += 1
            if (cfg.eos_id >= 0 and tok == cfg.eos_id) \
                    or len(slot.out) >= slot.budget:
                self._finish(i)
            else:
                self._feed[i] = tok
        # decode-lane bookkeeping (legacy forced-prefill rides here too;
        # a speculative tick's multi-token emit/truncate/rollback lives in
        # _spec_bookkeep)
        if greedy_np is not None:
            self._spec_bookkeep(vgr, greedy_np, accept_np, poisoned)
        for i, slot in (enumerate(self.slots) if greedy_np is None
                        else ()):
            si = int(steps[i])
            if not slot.active or si == 0 or i in poisoned:
                continue
            # tokens fed this tick = this tick's K/V rows (donor index)
            fed = [int(self._feed[i])] \
                + [int(toks_np[s, i]) for s in range(si - 1)]
            slot.history.extend(fed)
            if cfg.prefix_sharing:
                self._pindex.add(i, fed)
            slot.served += si
            n_forced = min(len(slot.forced), si)
            del slot.forced[:n_forced]
            slot.prompt_left = max(0, slot.prompt_left - si)
            finished = False
            for s in range(n_forced, si):
                if finished:
                    break                      # chunk overshoot: discarded
                tok = int(toks_np[s, i])
                slot.out.append(tok)
                self.tokens_out += 1
                if (cfg.eos_id >= 0 and tok == cfg.eos_id) \
                        or len(slot.out) >= slot.budget:
                    finished = True
            if finished:
                self._finish(i)
            else:
                self._feed[i] = toks_np[si - 1, i]
        # quarantine poisoned slots: pages freed, request requeued with its
        # pre-tick output (the garbage tokens never reach results), the
        # slot index sits out cfg.quarantine_ticks admissions
        for i in sorted(poisoned):
            if self.slots[i].active:
                self._preempt(i, quarantine=True)
        t3 = time.perf_counter()
        if cfg.trace_ticks:
            # host cost of the tick = everything but the device waits
            # (the mid-tick proposal sync in spec mode is a device wait)
            self.host_ms_trace.append(
                ((t1 - t0 - spec_wait) + (t3 - t2)) * 1e3)
            self.dispatch_trace.append(dispatches)
            self.upload_trace.append(tick_upload)
            if self._spec:
                self.draft_dispatch_trace.append(d_disp)
                self.verify_dispatch_trace.append(v_disp)
        self.upload_bytes += tick_upload
        self._maybe_snapshot()

    # -- crash consistency (serve/snapshot.py) -----------------------------------

    def _maybe_snapshot(self) -> None:
        """Write a full-state snapshot at the END of every
        ``cfg.snapshot_every_ticks``-th tick (idle ticks included — the
        fault/deadline clock advanced, so the state did).  The write is
        atomic and old files are pruned to ``cfg.snapshot_keep``; lazy
        import keeps the engine importable without the snapshot layer."""
        cfg = self.cfg
        if cfg.snapshot_every_ticks <= 0 or not cfg.snapshot_dir \
                or self.ticks % cfg.snapshot_every_ticks != 0 \
                or self.ticks == self._last_snapshot_tick:
            return
        from repro.serve import snapshot as _snap
        _snap.save_snapshot(
            self, _snap.snapshot_path(cfg.snapshot_dir, self.ticks))
        _snap.prune_snapshots(cfg.snapshot_dir, cfg.snapshot_keep)
        self.snapshots_written += 1
        self._last_snapshot_tick = self.ticks

    # -- bookkeeping -------------------------------------------------------------

    @property
    def logical_tokens(self) -> int:
        """Tokens logically resident over the run: fresh appends plus
        prompt tokens served by page reference."""
        return self.tokens_appended + self.shared_tokens

    @property
    def logical_physical_ratio(self) -> float:
        """Prefix-sharing win: logical tokens per physically-written token
        (1.0 when nothing was shared)."""
        return self.logical_tokens / max(1, self.tokens_appended)
