"""Batched serving engines: fused device-resident decode, lockstep
continuous batching, and the paged non-lockstep engine.

The decode hot path is ONE compiled HLO module (``Model.decode_many`` /
``Model.decode_many_paged``: a ``lax.scan`` over decode steps with on-device
sampling), jitted with ``donate_argnums`` so the KV cache and sampler key
are updated in place instead of re-materialized every token.  That makes the
decode cell a single program `core.hlo_counters` can census and place on the
instruction roofline — and removes the per-token host round-trip the legacy
loop pays (kept as ``fused=False`` for the measured comparison in
``benchmark_decode`` / benchmarks/serve_bench.py).

Three engines, one compiled-cell discipline (no recompiles, ever):

  * ``ServingEngine`` — whole-batch generation: prefill once, decode all
    sequences in lockstep.
  * ``ContinuousBatchingEngine`` — slot scheduling over a LOCKSTEP dense
    cache (one shared position): finished sequences release their slot,
    queued requests join mid-flight through the same compiled decode step
    (prefill-by-decode) behind a per-slot ``start`` window.  When the
    shared position would exhaust ``max_seq`` the cache rows WRAP: live
    windows slide down by the smallest active ``start`` (finished slots'
    burned rows are reclaimed) while ``pos_base`` keeps the rope position
    stream absolute — a slot never reads rows below its ``start``, before
    or after wraparound (regression-tested).
  * ``PagedEngine`` — the NON-LOCKSTEP engine over a ``PagedKVCache``:
    a shared page pool + per-slot block tables + per-slot lengths.  Every
    slot decodes at its own position on its own pages (rope is
    request-relative by construction), admission allocates pages from a
    free list, finished slots' pages are evicted back to it, and
    ``defrag()`` compacts live pages to the pool prefix.  Each engine tick
    runs ``prefill_chunk`` fused steps of ``decode_many_paged``; prompts
    are CHUNK-PREFILLED through that same cell (forced-token override), so
    prefill + decode are one censusable module family and the decode
    kernel's transaction count scales with live tokens, not ``max_seq``.

CPU-runnable end-to-end (examples/serve_demo.py); the same step functions are
what launch/serve.py lowers for the production mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model, sample_token


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4
    max_seq: int = 128
    max_new_tokens: int = 16
    temperature: float = 0.0          # 0 = greedy
    seed: int = 0
    eos_id: int = -1                  # < 0: no stop condition
    pad_id: int = 0                   # emitted by finished slots
    fused: bool = True                # decode_many scan vs per-token loop
    # --- paged engine ------------------------------------------------------
    page_size: int = 16               # tokens per KV page
    max_blocks: int = 0               # block-table width (0: ceil(max_seq/page))
    num_pages: int = 0                # pool size incl. null page (0: fit all slots)
    prefill_chunk: int = 4            # fused steps per PagedEngine tick


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                # (S,) int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Greedy/temperature sampling over a shared batched KV cache."""

    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        # donate the cache through BOTH decode paths: XLA aliases the input
        # buffer to the output, so each step updates the cache in place
        # instead of allocating a full copy per token
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        self._prefill = jax.jit(model.prefill)
        self._decode_many = jax.jit(
            model.decode_many,
            static_argnames=("num_steps", "temperature", "eos_id", "pad_id"),
            donate_argnums=(2, 3))          # cache + sampler key
        self._key = jax.random.key(cfg.seed)

    # -- sampling ---------------------------------------------------------------

    def _sample(self, logits: jax.Array, key: jax.Array):
        """One sampling step (models.model.sample_token, the shared helper,
        so legacy and fused paths are token-identical for a given seed)."""
        return sample_token(logits, key, self.cfg.temperature)

    # -- prefill ---------------------------------------------------------------

    def _prefill_cache(self, prompts: List[np.ndarray], mnt: int):
        """Left-pads prompts to a common length, prefills once, scatters the
        prefill KV into a fresh (donatable) decode cache."""
        B = len(prompts)
        S = max(len(p) for p in prompts)
        toks = np.zeros((B, S), np.int32)
        for i, p in enumerate(prompts):            # right-align
            toks[i, S - len(p):] = p
        batch = {"tokens": jnp.asarray(toks)}
        last_logits, cache_parts = self._prefill(self.params, batch)

        cache = self.model.init_cache(B, S + mnt)
        for k in cache_parts or {}:
            src = cache_parts[k]
            dst = cache[k]
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            cache[k] = jnp.pad(src.astype(dst.dtype), pad)
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return last_logits, cache

    # -- generation ---------------------------------------------------------------

    def generate_batch(self, prompts: List[np.ndarray],
                       max_new_tokens: Optional[int] = None,
                       fused: Optional[bool] = None) -> List[List[int]]:
        """Prefill once, then decode all sequences in lockstep (the
        decode_32k cell's shape).  ``fused=True`` (default) runs the whole
        token loop on device; ``fused=False`` is the legacy per-token host
        loop (same tokens, one dispatch + sync per step)."""
        cfg = self.cfg
        mnt = max_new_tokens or cfg.max_new_tokens
        fused = cfg.fused if fused is None else fused
        B = len(prompts)

        last_logits, cache = self._prefill_cache(prompts, mnt)
        key = self._key
        first, key = self._sample(last_logits, key)

        if fused:
            toks, cache, key, _done = self._decode_many(
                self.params, first[:, None], cache, key,
                num_steps=mnt - 1, temperature=cfg.temperature,
                eos_id=cfg.eos_id, pad_id=cfg.pad_id)
            all_toks = np.concatenate(
                [np.asarray(first)[None], np.asarray(toks)], axis=0)
        else:
            tok = first[:, None]
            rows = [np.asarray(first)]
            for _ in range(mnt - 1):
                logits, cache = self._decode(self.params, tok, cache)
                t, key = self._sample(logits, key)
                tok = t[:, None]
                rows.append(np.asarray(t))         # per-token host sync
            all_toks = np.stack(rows, axis=0)
        self._key = key

        outs: List[List[int]] = []
        for i in range(B):
            col = [int(t) for t in all_toks[:, i]]
            if cfg.eos_id >= 0 and cfg.eos_id in col:
                col = col[: col.index(cfg.eos_id) + 1]
            outs.append(col)
        return outs

    # -- benchmarking ---------------------------------------------------------------

    def benchmark_decode(self, batch: int, seq: int, steps: int = 8
                         ) -> Dict[str, float]:
        """Wall-clock decode throughput on this host (CPU here; the TPU
        numbers come from the dry-run roofline): the fused device-resident
        loop vs the legacy per-step loop, both with donated caches."""
        assert seq // 2 + 2 * steps + 2 <= seq, \
            f"steps={steps} overruns the cache (seq={seq})"

        def fresh_cache():
            cache = self.model.init_cache(batch, seq)
            cache["pos"] = jnp.asarray(seq // 2, jnp.int32)
            return cache

        tok0 = jnp.zeros((batch, 1), jnp.int32)

        # legacy: one dispatch + argmax + host sync per token
        cache = fresh_cache()
        logits, cache = self._decode(self.params, tok0, cache)  # compile
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        t0 = time.perf_counter()
        for _ in range(steps):
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            np.asarray(tok)                        # the per-token round-trip
        dt_loop = (time.perf_counter() - t0) / steps

        # fused: one dispatch for the whole token loop
        key = jax.random.key(self.cfg.seed)
        cache = fresh_cache()
        toks, cache, key, _ = self._decode_many(   # compile
            self.params, tok0, cache, key, num_steps=steps,
            temperature=0.0, eos_id=-1, pad_id=0)
        jax.block_until_ready(toks)
        t0 = time.perf_counter()
        toks, cache, key, _ = self._decode_many(
            self.params, tok0, cache, key, num_steps=steps,
            temperature=0.0, eos_id=-1, pad_id=0)
        jax.block_until_ready(toks)
        dt_fused = (time.perf_counter() - t0) / steps

        return {
            "s_per_step": dt_fused,
            "tokens_per_s": batch / dt_fused,
            "s_per_step_fused": dt_fused,
            "tokens_per_s_fused": batch / dt_fused,
            "s_per_step_loop": dt_loop,
            "tokens_per_s_loop": batch / dt_loop,
            "fused_speedup": dt_loop / dt_fused,
        }


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Slot:
    """One schedulable slot (both engines): ``forced`` holds the prompt
    tokens still to be forced into the stream (prefill-by-decode)."""
    rid: int = -1
    forced: List[int] = dataclasses.field(default_factory=list)
    out: List[int] = dataclasses.field(default_factory=list)
    budget: int = 0
    active: bool = False


class _SlotQueueBase:
    """Request lifecycle shared by the slot-scheduled engines (lockstep
    dense and paged): submission queue, rid assignment, drain loop.
    Subclasses provide ``step()`` and initialize ``cfg``, ``queue``,
    ``slots``, ``results`` and ``_next_rid``."""

    def submit(self, prompt: np.ndarray,
               max_new_tokens: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt: a slot needs at least one "
                             "token to feed the decode step")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt,
                                  max_new_tokens or self.cfg.max_new_tokens))
        return rid

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(s.active for s in self.slots)

    def run(self) -> Dict[int, List[int]]:
        """Drain queue + slots; returns {rid: generated tokens}."""
        while self.busy:
            self.step()
        return self.results


def _make_engine_step(model: Model):
    """One decode step + sampling + forced-token override, as a pure
    function of arrays (compiled exactly once per temperature)."""

    def step(params, tok, cache, key, forced_tok, forced_mask,
             temperature: float):
        logits, cache = model.decode_step(params, tok[:, None], cache)
        sampled, key = sample_token(logits, key, temperature)
        nxt = jnp.where(forced_mask, forced_tok, sampled)
        return nxt, cache, key

    return step


def _shift_cache(cache, n):
    """Row wraparound for the lockstep dense cache: slide every live window
    down ``n`` rows.  Rolled-off rows (all < every active slot's ``start``,
    i.e. burned by finished occupants) wrap to the tail, where they stay
    masked by ``kv_len`` until overwritten.  ``pos_base`` absorbs the shift
    so the rope position stream stays absolute — the keys already in the
    cache were rotated with the old positions and relative distances must
    survive the rebase."""
    out = dict(cache)
    for name in ("k", "v"):
        out[name] = jnp.roll(cache[name], -n, axis=2)    # (L, B, T, KV, hd)
    out["start"] = jnp.maximum(cache["start"] - n, 0)
    out["pos"] = cache["pos"] - n
    out["pos_base"] = cache["pos_base"] + n
    return out


class ContinuousBatchingEngine(_SlotQueueBase):
    """Slot-scheduled decoding over ONE compiled step — no recompiles, ever.

    All ``max_batch`` slots advance in lockstep over a shared, donated,
    slot-paged KV cache (one (max_seq, KV, hd) page per slot).  A queued
    request joins the moment a slot frees:

      * the slot's ``start`` is set to the current shared position, masking
        the previous occupant's KV rows (per-slot attention window);
      * its prompt is fed through the SAME compiled decode step one token
        per engine step ("prefill-by-decode") — the sampled output is
        overridden by the next prompt token until the prompt is exhausted,
        after which sampled tokens are collected as output.

    Decoder-only LMs only (whisper needs per-request cross-attention caches;
    a joining SSM slot would inherit the previous occupant's state).
    """

    def __init__(self, model: Model, params, cfg: ServeConfig):
        if model.cfg.is_encoder_decoder or model.cfg.mamba_version:
            raise ValueError("continuous batching requires a decoder-only "
                             "attention LM (per-slot KV windows)")
        self.model = model
        self.params = params
        self.cfg = cfg
        B = cfg.max_batch
        self._step = jax.jit(_make_engine_step(model),
                             static_argnames=("temperature",),
                             donate_argnums=(2, 3))   # cache + key
        self._shift = jax.jit(_shift_cache, donate_argnums=(0,))
        self.cache = model.init_cache(B, cfg.max_seq)
        self.key = jax.random.key(cfg.seed)
        self.pos = 0                                  # host mirror of pos
        self._start = np.zeros((B,), np.int32)        # host mirror of start
        self.slots = [_Slot() for _ in range(B)]
        self.queue: List[Request] = []
        self.results: Dict[int, List[int]] = {}
        self._feed = np.full((B,), cfg.pad_id, np.int32)
        self._next_rid = 0
        self.steps_run = 0
        self.joins = 0
        self.wraps = 0

    # -- request lifecycle -----------------------------------------------------

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            req = self.queue.pop(0)
            prompt = [int(t) for t in req.prompt]
            self.slots[i] = _Slot(rid=req.rid, forced=prompt[1:], out=[],
                                  budget=req.max_new_tokens, active=True)
            # window base: mask every cache row this slot wrote before
            self.cache["start"] = self.cache["start"].at[i].set(self.pos)
            self._start[i] = self.pos
            self._feed[i] = prompt[0]
            self.joins += 1

    def _finish(self, i: int) -> None:
        slot = self.slots[i]
        self.results[slot.rid] = slot.out
        self.slots[i] = _Slot()
        self._feed[i] = self.cfg.pad_id

    # -- stepping ---------------------------------------------------------------

    def _wrap(self) -> None:
        """Reclaim burned rows when the shared position hits ``max_seq``:
        slide every live window down by the smallest active ``start`` (the
        rows below it belong to FINISHED occupants only).  A slot admitted
        at any engine step must never read rows below its ``start``, before
        or after wraparound — the shift translates start/pos uniformly so
        the per-slot window masks are preserved, and ``pos_base`` keeps
        rope positions absolute (see ``_shift_cache``)."""
        active = [i for i, s in enumerate(self.slots) if s.active]
        shift = int(min(self._start[i] for i in active)) if active \
            else self.pos
        if shift <= 0:
            raise RuntimeError(
                f"KV cache exhausted at pos={self.pos} (max_seq="
                f"{self.cfg.max_seq}): an active slot still spans row 0 — "
                f"use PagedEngine for workloads outliving max_seq")
        self.cache = self._shift(self.cache, jnp.int32(shift))
        self._start = np.maximum(self._start - shift, 0).astype(np.int32)
        self.pos -= shift
        self.wraps += 1

    def step(self) -> None:
        """Admit waiting requests, advance every slot by one token."""
        cfg = self.cfg
        if self.pos + 1 >= cfg.max_seq:
            self._wrap()
        self._admit()
        forced_tok = np.full((len(self.slots),), cfg.pad_id, np.int32)
        forced_mask = np.zeros((len(self.slots),), bool)
        for i, slot in enumerate(self.slots):
            if slot.active and slot.forced:
                forced_tok[i] = slot.forced.pop(0)
                forced_mask[i] = True
        nxt, self.cache, self.key = self._step(
            self.params, jnp.asarray(self._feed), self.cache, self.key,
            jnp.asarray(forced_tok), jnp.asarray(forced_mask),
            temperature=cfg.temperature)
        self.pos += 1
        self.steps_run += 1
        nxt_np = np.asarray(nxt)
        for i, slot in enumerate(self.slots):
            if not slot.active:
                continue
            if forced_mask[i]:                      # still catching up
                self._feed[i] = nxt_np[i]
                continue
            tok = int(nxt_np[i])                    # sampled: real output
            slot.out.append(tok)
            if (cfg.eos_id >= 0 and tok == cfg.eos_id) \
                    or len(slot.out) >= slot.budget:
                self._finish(i)
            else:
                self._feed[i] = nxt_np[i]


# ---------------------------------------------------------------------------
# paged (non-lockstep) serving
# ---------------------------------------------------------------------------

class PagedKVCache:
    """Host-side manager for the paged decode cache.

    Device state (``Model.init_paged_cache``): k/v page pools
    (L, num_pages, page, KV, hd), a block table (B, max_blocks) int32 and
    per-slot lengths (B,) int32.  The manager owns the host mirrors and the
    page FREE LIST; page 0 is the reserved NULL page — never allocated, the
    landing zone for inactive slots' appends and unallocated table entries
    (so the Pallas kernel's scalar-prefetched DMA address is always valid).

    Invariants (``check()``, fuzz-asserted by the property harness): the
    null page plus every slot's owned pages plus the free list partition
    [0, num_pages) exactly — no page is ever double-allocated or leaked.
    """

    def __init__(self, model: Model, max_batch: int, max_seq: int, *,
                 page_size: int = 16, max_blocks: int = 0,
                 num_pages: int = 0):
        self.page = page_size
        self.max_blocks = max_blocks or -(-max_seq // page_size)
        # default pool: every slot can hold its full table + the null page
        self.num_pages = num_pages or (max_batch * self.max_blocks + 1)
        self.B = max_batch
        arrays = model.init_paged_cache(max_batch, self.max_blocks,
                                        self.page, self.num_pages)
        self.k = arrays["k"]
        self.v = arrays["v"]
        self.table = np.zeros((max_batch, self.max_blocks), np.int32)
        self.length = np.zeros((max_batch,), np.int32)
        self.owned: List[List[int]] = [[] for _ in range(max_batch)]
        self.free: List[int] = list(range(self.num_pages - 1, 0, -1))
        self._gather = jax.jit(lambda pool, perm: pool[:, perm],
                               donate_argnums=(0,))

    # -- allocation ----------------------------------------------------------

    def ensure(self, i: int, n_tokens: int) -> bool:
        """Allocate pages so slot ``i`` can hold ``n_tokens`` tokens.
        Returns False (allocating nothing further) if the free list runs
        dry — the engine stalls the slot until eviction frees pages."""
        need = -(-n_tokens // self.page)
        if need > self.max_blocks:
            raise RuntimeError(
                f"slot {i} needs {need} blocks > max_blocks="
                f"{self.max_blocks} (request exceeds max_seq)")
        while len(self.owned[i]) < need:
            if not self.free:
                return False
            pg = self.free.pop()
            self.table[i, len(self.owned[i])] = pg
            self.owned[i].append(pg)
        return True

    def free_slot(self, i: int) -> None:
        """Eviction: a finished slot's pages go back to the free list."""
        self.free.extend(reversed(self.owned[i]))
        self.owned[i] = []
        self.table[i, :] = 0
        self.length[i] = 0

    # -- bookkeeping ----------------------------------------------------------

    @property
    def live_pages(self) -> int:
        return sum(len(o) for o in self.owned)

    def utilization(self) -> float:
        """Fraction of allocatable pages currently owned by live slots."""
        return self.live_pages / max(1, self.num_pages - 1)

    def check(self) -> None:
        """Free-list/table invariants (cheap; the property harness calls
        this every fuzz step)."""
        owned = [p for o in self.owned for p in o]
        assert 0 not in owned, "null page allocated"
        assert len(set(owned)) == len(owned), "page double-allocated"
        assert not set(owned) & set(self.free), "page both owned and free"
        assert len(set(self.free)) == len(self.free), "free-list duplicate"
        assert set(owned) | set(self.free) == set(range(1, self.num_pages)), \
            "page leaked"
        for i, o in enumerate(self.owned):
            assert list(self.table[i, :len(o)]) == o, "table/owned drift"
            assert not self.table[i, len(o):].any(), "stale table entry"

    # -- defrag ----------------------------------------------------------------

    def defrag(self) -> None:
        """Compact live pages to the contiguous pool prefix [1, live+1)
        (one donated device gather per pool) and rewrite the block tables.
        Purely physical: logical contents are untouched, so engine output
        is bit-identical across defrags (property-tested)."""
        perm = [0]                                    # new -> old; null stays
        for i in range(self.B):
            for j, pg in enumerate(self.owned[i]):
                self.table[i, j] = len(perm)
                perm.append(pg)
        live = set(perm)
        perm.extend(p for p in range(1, self.num_pages) if p not in live)
        for i in range(self.B):
            self.owned[i] = list(self.table[i, :len(self.owned[i])])
        self.free = list(range(self.num_pages - 1, self.live_pages, -1))
        perm_dev = jnp.asarray(np.asarray(perm, np.int32))
        self.k = self._gather(self.k, perm_dev)
        self.v = self._gather(self.v, perm_dev)


class PagedEngine(_SlotQueueBase):
    """Non-lockstep continuous batching over the paged KV cache.

    Every engine tick runs ONE fused ``decode_many_paged`` chunk
    (``cfg.prefill_chunk`` compiled scan steps).  Each slot advances at its
    OWN position (per-slot ``length``): a request admitted mid-flight
    starts at position 0 of its own freshly-allocated pages — no shared
    cache position to exhaust, no start-window masking, and rope positions
    request-relative by construction (so outputs are token-identical to a
    fresh single-request run, which the property harness fuzzes).

    Chunked prefill rides the SAME compiled cell: prompt tokens override
    the sampled output (forced mask) until the prompt drains, then sampled
    tokens are collected — prefill + decode are one censusable module
    family and never recompile.  Page lifecycle: admission allocates from
    the free list, finished slots' pages are EVICTED back to it, a slot
    that cannot get chunk capacity STALLS (active=False for the tick)
    until eviction frees pages, and ``defrag()`` compacts the pool.

    Decoder-only attention LMs only (a joining SSM slot would inherit the
    previous occupant's state; whisper needs per-request cross caches).
    """

    def __init__(self, model: Model, params, cfg: ServeConfig):
        if model.cfg.is_encoder_decoder or model.cfg.mamba_version:
            raise ValueError("paged serving requires a decoder-only "
                             "attention LM (per-slot page tables)")
        self.model = model
        self.params = params
        self.cfg = cfg
        B = cfg.max_batch
        self._many = jax.jit(model.decode_many_paged,
                             static_argnames=("num_steps", "temperature"),
                             donate_argnums=(2, 3))   # cache + key
        self.kv = PagedKVCache(model, B, cfg.max_seq,
                               page_size=cfg.page_size,
                               max_blocks=cfg.max_blocks,
                               num_pages=cfg.num_pages)
        self.key = jax.random.key(cfg.seed)
        self.slots = [_Slot() for _ in range(B)]
        self.queue: List[Request] = []
        self.results: Dict[int, List[int]] = {}
        self._feed = np.full((B,), cfg.pad_id, np.int32)
        self._next_rid = 0
        self.steps_run = 0                # engine ticks (chunks)
        self.tokens_out = 0               # kept (non-discarded) tokens
        self.joins = 0
        self.stalls = 0
        self.util_sum = 0.0
        self.util_max = 0.0

    # -- request lifecycle -----------------------------------------------------

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.active or not self.queue:
                continue
            if not self.kv.ensure(i, 1):      # first page for the new slot
                break                          # pool dry: wait for eviction
            req = self.queue.pop(0)
            prompt = [int(t) for t in req.prompt]
            self.slots[i] = _Slot(rid=req.rid, forced=prompt[1:], out=[],
                                  budget=req.max_new_tokens, active=True)
            self.kv.length[i] = 0
            self._feed[i] = prompt[0]
            self.joins += 1

    def _finish(self, i: int) -> None:
        slot = self.slots[i]
        self.results[slot.rid] = slot.out
        self.slots[i] = _Slot()
        self._feed[i] = self.cfg.pad_id
        self.kv.free_slot(i)                  # evict the slot's pages

    # -- stepping ---------------------------------------------------------------

    def defrag(self) -> None:
        self.kv.defrag()

    def step(self) -> None:
        """One engine tick: admit, then advance every slot with chunk
        capacity by ``prefill_chunk`` fused steps."""
        cfg = self.cfg
        chunk = max(1, cfg.prefill_chunk)
        self._admit()
        B = len(self.slots)
        active = np.zeros((B,), bool)
        for i, slot in enumerate(self.slots):
            if not slot.active:
                continue
            # reserve only the slot's REMAINING work, not the whole chunk:
            # a slot that finishes mid-chunk overshoots into the null page
            # (steps past its budget are discarded on the host), so pages
            # past its last kept token never need to exist — without the
            # cap a fitting workload could stall forever on pool capacity
            remaining = len(slot.forced) + slot.budget - len(slot.out)
            need = min(chunk, remaining)
            if self.kv.ensure(i, int(self.kv.length[i]) + need):
                active[i] = True
            else:
                self.stalls += 1              # waits for eviction next tick
        if not active.any():
            if self.busy:
                raise RuntimeError(
                    f"page pool exhausted: {len(self.kv.free)} free pages "
                    f"cannot give any slot chunk capacity (num_pages="
                    f"{self.kv.num_pages}, page={self.kv.page})")
            return

        forced_tok = np.full((chunk, B), cfg.pad_id, np.int32)
        forced_mask = np.zeros((chunk, B), bool)
        for i, slot in enumerate(self.slots):
            if not active[i]:
                continue
            for s in range(min(len(slot.forced), chunk)):
                forced_tok[s, i] = slot.forced[s]
                forced_mask[s, i] = True

        cache = {"k": self.kv.k, "v": self.kv.v,
                 "table": jnp.asarray(self.kv.table),
                 "length": jnp.asarray(self.kv.length)}
        toks, cache, self.key = self._many(
            self.params, jnp.asarray(self._feed)[:, None], cache, self.key,
            jnp.asarray(active), jnp.asarray(forced_tok),
            jnp.asarray(forced_mask),
            num_steps=chunk, temperature=cfg.temperature)
        self.kv.k = cache["k"]
        self.kv.v = cache["v"]
        self.kv.length[active] += chunk       # mirrors the device increment
        self.steps_run += 1
        util = self.kv.utilization()
        self.util_sum += util
        self.util_max = max(self.util_max, util)

        toks_np = np.asarray(toks)            # (chunk, B)
        for i, slot in enumerate(self.slots):
            if not active[i]:
                continue
            n_forced = min(len(slot.forced), chunk)
            del slot.forced[:n_forced]
            finished = False
            for s in range(n_forced, chunk):
                if finished:
                    break                      # chunk overshoot: discarded
                tok = int(toks_np[s, i])
                slot.out.append(tok)
                self.tokens_out += 1
                if (cfg.eos_id >= 0 and tok == cfg.eos_id) \
                        or len(slot.out) >= slot.budget:
                    finished = True
            if finished:
                self._finish(i)
            else:
                self._feed[i] = toks_np[-1, i]
