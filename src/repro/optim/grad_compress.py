"""Int8 error-feedback gradient compression for the data-parallel all-reduce.

At 1k+ nodes the DP gradient all-reduce is wire-bound; compressing the
payload to int8 with per-block scales cuts it 4x (vs f32) while error
feedback keeps the optimizer trajectory unbiased: the quantization residual
is carried and added to the next step's gradient, so errors cannot
accumulate.

Usage inside a shard_map'd step:
    g_q, scales = compress(g + residual)
    g_sum = lax.psum(g_q.astype(f32) * scales, "data")   # or int8 wire + local dequant
    residual = (g + residual) - dequantize(g_q, scales)

The unit tests validate the EF-SGD invariant (compressed-sum trajectory
converges to the uncompressed one) and exact shape round-trips.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def compress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (any shape) f32 -> (int8 blocks (nb, BLOCK), scales (nb, 1))."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blk = flat.reshape(-1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blk), axis=1, keepdims=True),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(blk / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def ef_step(grad: jax.Array, residual: jax.Array
            ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One error-feedback compression step.
    Returns (q, scale, new_residual, dequantized)."""
    comp_in = grad.astype(jnp.float32) + residual
    q, scale = compress(comp_in)
    deq = decompress(q, scale, grad.shape)
    new_residual = comp_in - deq
    return q, scale, new_residual, deq
