"""AdamW in pure JAX, with sharded (ZeRO-style) moments and optional
low-precision moment storage.

Moments inherit each parameter's sharding (params are already FSDP+TP
sharded via the schema, so optimizer state is fully sharded across the mesh
— the ZeRO-1/3 combination).  ``moment_dtype`` trades optimizer memory for
precision:

  float32  — default
  bfloat16 — halves moment memory (used by the grok-1 train cell, which
             does not fit v5e HBM with fp32 moments; see EXPERIMENTS.md)
  int8     — blockwise-quantized moments with fp32 per-block scales
             (8-bit-optimizer-style; error is bounded by block max)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]

_QBLOCK = 256


def _quantize_int8(x: jax.Array, sqrt_domain: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Blockwise signed-int8 quantization with fp32 per-block scales.

    ``sqrt_domain=True`` is used for the (non-negative) second moment: values
    are quantized on a sqrt scale, which compresses the dynamic range so
    small v entries don't collapse to zero (a v quantized to exactly 0 turns
    the Adam update into mh/eps ~ 1e8x — measured divergence)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % _QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blk = flat.reshape(-1, _QBLOCK)
    if sqrt_domain:
        blk = jnp.sqrt(jnp.maximum(blk, 0.0))
    scale = jnp.max(jnp.abs(blk), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blk / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize_int8(q: jax.Array, scale: jax.Array, shape,
                     sqrt_domain: bool = False) -> jax.Array:
    qf = q.astype(jnp.float32)
    if sqrt_domain:
        # half-LSB floor: a v that quantized to 0 is treated as half a
        # quantization step, bounding the worst-case update magnitude
        qf = jnp.maximum(qf, 0.5)
        flat = (qf * scale).reshape(-1)
        flat = jnp.square(flat)
    else:
        flat = (qf * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Union[float, Schedule] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"    # float32 | bfloat16 | int8

    def lr_at(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    cfg: AdamWConfig = AdamWConfig()

    # -- state ----------------------------------------------------------------
    def init(self, params) -> dict:
        def mk(p):
            if self.cfg.moment_dtype == "int8":
                q, s = _quantize_int8(jnp.zeros(p.shape, jnp.float32))
                return {"q": q, "scale": s}
            dt = (jnp.bfloat16 if self.cfg.moment_dtype == "bfloat16"
                  else jnp.float32)
            return jnp.zeros(p.shape, dt)
        return {"m": jax.tree.map(mk, params),
                "v": jax.tree.map(mk, params),
                "step": jnp.zeros((), jnp.int32)}

    def abstract_state(self, abstract_params) -> dict:
        def mk(p):
            if self.cfg.moment_dtype == "int8":
                n = 1
                for d in p.shape:
                    n *= d
                nb = -(-n // _QBLOCK)
                return {"q": jax.ShapeDtypeStruct((nb, _QBLOCK), jnp.int8),
                        "scale": jax.ShapeDtypeStruct((nb, 1), jnp.float32)}
            dt = (jnp.bfloat16 if self.cfg.moment_dtype == "bfloat16"
                  else jnp.float32)
            return jax.ShapeDtypeStruct(p.shape, dt)
        return {"m": jax.tree.map(mk, abstract_params),
                "v": jax.tree.map(mk, abstract_params),
                "step": jax.ShapeDtypeStruct((), jnp.int32)}

    def state_pspecs(self, param_pspecs) -> dict:
        from jax.sharding import PartitionSpec as P

        def mk(spec):
            if self.cfg.moment_dtype == "int8":
                # the (n_blocks, 256) quantized layout shards its block dim
                # over every mesh axis the parameter itself used (fully
                # sharded optimizer state, ZeRO-style)
                axes = []
                for entry in spec:
                    if entry is None:
                        continue
                    axes.extend(entry if isinstance(entry, tuple)
                                else (entry,))
                blk = tuple(axes) if len(axes) > 1 else (
                    axes[0] if axes else None)
                return {"q": P(blk, None), "scale": P(blk, None)}
            return spec
        return {"m": jax.tree.map(mk, param_pspecs,
                                  is_leaf=lambda x: isinstance(x, P)),
                "v": jax.tree.map(mk, param_pspecs,
                                  is_leaf=lambda x: isinstance(x, P)),
                "step": P()}

    # -- update ----------------------------------------------------------------
    def update(self, grads, state, params) -> Tuple[Any, dict, dict]:
        cfg = self.cfg
        step = state["step"] + 1
        lr = cfg.lr_at(step)

        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree.leaves(g32)))
        if cfg.grad_clip_norm is not None:
            scale = jnp.minimum(1.0, cfg.grad_clip_norm
                                / jnp.maximum(gnorm, 1e-9))
            g32 = jax.tree.map(lambda g: g * scale, g32)

        bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

        is_q = cfg.moment_dtype == "int8"

        def load(mom, p, sqrt_domain=False):
            if is_q:
                return _dequantize_int8(mom["q"], mom["scale"], p.shape,
                                        sqrt_domain)
            return mom.astype(jnp.float32)

        def store(x, sqrt_domain=False):
            if is_q:
                q, s = _quantize_int8(x, sqrt_domain)
                return {"q": q, "scale": s}
            dt = (jnp.bfloat16 if cfg.moment_dtype == "bfloat16"
                  else jnp.float32)
            return x.astype(dt)

        def one(p, g, m, v):
            m32 = cfg.b1 * load(m, p) + (1 - cfg.b1) * g
            v32 = (cfg.b2 * load(v, p, sqrt_domain=True)
                   + (1 - cfg.b2) * jnp.square(g))
            mh = m32 / bc1
            vh = v32 / bc2
            upd = mh / (jnp.sqrt(vh) + cfg.eps)
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            return new_p, store(m32), store(v32, sqrt_domain=True)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(g32)
        is_mom_leaf = (lambda x: isinstance(x, dict) and "q" in x) if is_q \
            else None
        flat_m = jax.tree.flatten(state["m"], is_leaf=is_mom_leaf)[0]
        flat_v = jax.tree.flatten(state["v"], is_leaf=is_mom_leaf)[0]
        outs = [one(p, g, m, v) for p, g, m, v
                in zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_m = jax.tree.unflatten(treedef, [o[1] for o in outs])
        new_v = jax.tree.unflatten(treedef, [o[2] for o in outs])
        new_state = {"m": new_m, "v": new_v, "step": step}
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, new_state, metrics
