from repro.optim.adamw import AdamW, AdamWConfig  # noqa: F401
from repro.optim.schedule import (  # noqa: F401
    constant, cosine_with_warmup, linear_warmup)
