"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)
    return fn


def linear_warmup(lr: float, warmup_steps: int):
    def fn(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(1, warmup_steps),
                           1.0)
        return jnp.asarray(lr, jnp.float32) * frac
    return fn


def cosine_with_warmup(lr: float, warmup_steps: int, total_steps: int,
                       min_ratio: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(1, warmup_steps), 1.0)
        prog = jnp.clip((s - warmup_steps) / max(1, total_steps - warmup_steps),
                        0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.asarray(lr, jnp.float32) * warm * cos
    return fn
