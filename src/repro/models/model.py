"""Model facade: one object per ArchConfig exposing init / abstract specs /
partition specs / loss / prefill / decode, plus ``input_specs`` for AOT
lowering (ShapeDtypeStructs — never allocates).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.dist.sharding import MeshRules
from repro.models import transformer as T
from repro.models.params import (
    ParamDecl, abstract_params, init_params, param_pspecs)


def sample_token(logits: jax.Array, key: jax.Array, temperature: float):
    """Greedy/temperature sampling step: returns (tokens (B,) int32, key).

    THE one sampler — the fused decode_many scan, the legacy per-token
    loop, and the continuous-batching engine step all call this, so the
    key-split discipline stays identical and the three paths remain
    token-identical for a given seed (tests assert it)."""
    if temperature > 0:
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(sub, logits / temperature, axis=-1)
    else:
        tok = jnp.argmax(logits, axis=-1)
    return tok.astype(jnp.int32), key


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """logits (B, S, V) fp32; labels (B, S) int32. Mean NLL."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    # -- parameters ----------------------------------------------------------
    def schema(self):
        return T.schema(self.cfg)

    def init(self, key: jax.Array):
        return init_params(self.schema(), key, self.cfg.param_dtype)

    def abstract_params(self):
        return abstract_params(self.schema(), self.cfg.param_dtype)

    def param_pspecs(self, rules: MeshRules):
        return param_pspecs(self.schema(), rules)

    # -- caches ---------------------------------------------------------------
    def cache_decls(self, batch: int, max_seq: int):
        return T.cache_decls(self.cfg, batch, max_seq)

    def init_cache(self, batch: int, max_seq: int):
        return init_params(self.cache_decls(batch, max_seq), jax.random.key(0),
                           self.cfg.param_dtype)

    def abstract_cache(self, batch: int, max_seq: int):
        return abstract_params(self.cache_decls(batch, max_seq),
                               self.cfg.param_dtype)

    def cache_pspecs(self, batch: int, max_seq: int, rules: MeshRules):
        return param_pspecs(self.cache_decls(batch, max_seq), rules)

    # -- paged caches ---------------------------------------------------------
    def paged_cache_decls(self, batch: int, max_blocks: int, page_size: int,
                          num_pages: int):
        return T.paged_cache_decls(self.cfg, batch, max_blocks, page_size,
                                   num_pages)

    def init_paged_cache(self, batch: int, max_blocks: int, page_size: int,
                         num_pages: int):
        return init_params(
            self.paged_cache_decls(batch, max_blocks, page_size, num_pages),
            jax.random.key(0), self.cfg.param_dtype)

    def abstract_paged_cache(self, batch: int, max_blocks: int,
                             page_size: int, num_pages: int):
        return abstract_params(
            self.paged_cache_decls(batch, max_blocks, page_size, num_pages),
            self.cfg.param_dtype)

    def paged_cache_pspecs(self, batch: int, max_blocks: int, page_size: int,
                           num_pages: int, rules: MeshRules):
        return param_pspecs(
            self.paged_cache_decls(batch, max_blocks, page_size, num_pages),
            rules)

    def prefill_cache_pspecs(self, shape: ShapeConfig, rules: MeshRules):
        """PartitionSpecs matching the cache-parts pytree that prefill()
        actually returns (a subset of the decode cache)."""
        full = self.cache_pspecs(shape.global_batch, shape.seq_len, rules)
        cfg = self.cfg
        if cfg.family == "ssm":
            return None
        if cfg.family == "hybrid":
            return {"attn_k": full["attn_k"], "attn_v": full["attn_v"]}
        keys = ["k", "v"]
        if cfg.is_encoder_decoder:
            keys += ["cross_k", "cross_v"]
        return {k: full[k] for k in keys}

    # -- steps ----------------------------------------------------------------
    def loss(self, params, batch: Dict[str, Any]) -> jax.Array:
        """batch: tokens|embeds (+frames for enc-dec), labels, positions?

        Uses the fused unembed + softmax-CE (never materializes full
        logits — see models/losses.py)."""
        from repro.models.losses import fused_unembed_xent
        cfg = self.cfg
        labels = batch["labels"]
        if cfg.is_encoder_decoder:
            hidden, aux, _ = T.whisper_forward(
                params, cfg, batch["frames"], batch["tokens"], mode="hidden")
        else:
            inputs = batch["tokens"] if cfg.embed_inputs else batch["embeds"]
            positions = batch.get("positions")
            if positions is None:
                B, S = labels.shape
                positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            hidden, aux, _ = T.lm_forward(params, cfg, inputs, positions,
                                          mode="hidden")
        table = params["embed"] if cfg.tie_embeddings else params["unembed"]
        ce = fused_unembed_xent(hidden, table, labels)
        return ce + cfg.router_aux_weight * aux

    def prefill(self, params, batch: Dict[str, Any]):
        """Returns (last-position logits (B, V), cache-parts).  The logits
        are f32 (exact unembed): they exist to pick the first generated
        token, and sampling at activation dtype flips argmax near-ties."""
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            logits, _, cache = T.whisper_forward(
                params, cfg, batch["frames"], batch["tokens"], mode="prefill")
        else:
            inputs = batch["tokens"] if cfg.embed_inputs else batch["embeds"]
            positions = batch.get("positions")
            if positions is None:
                if cfg.embed_inputs:
                    B, S = inputs.shape
                else:
                    B, S, _ = inputs.shape
                positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            logits, _, cache = T.lm_forward(params, cfg, inputs, positions,
                                            mode="prefill")
        return logits[:, -1], cache

    def decode_step(self, params, tokens, cache):
        """tokens (B, 1) int32 (always token ids — decode emits tokens even
        for stub-frontend archs); returns (logits (B, V), cache)."""
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            return T.whisper_decode(params, cfg, tokens, cache)
        if not cfg.embed_inputs:
            # stub-frontend archs decode text tokens through the embed table
            cfg2 = dataclasses.replace(cfg, embed_inputs=True)
            return T.lm_decode(params, cfg2, tokens, cache)
        return T.lm_decode(params, cfg, tokens, cache)

    def decode_step_paged(self, params, tokens, cache, active=None):
        """Non-lockstep decode step over the paged cache: tokens (B, 1)
        int32; cache from ``init_paged_cache``; active (B,) bool (None ->
        all slots advance).  Returns (logits (B, V), cache) — each slot's
        new K/V lands on its OWN pages at its OWN position."""
        cfg = self.cfg
        if active is None:
            active = jnp.ones((tokens.shape[0],), bool)
        if not cfg.embed_inputs:
            cfg = dataclasses.replace(cfg, embed_inputs=True)
        return T.lm_decode_paged(params, cfg, tokens, cache, active)

    def prefill_step_paged(self, params, tokens, cache, grants):
        """Ragged multi-token paged prefill step: tokens (B, T) int32 —
        each slot's next prompt chunk (row i's first ``grants[i]`` entries
        real, rest pad); cache from ``init_paged_cache``; grants (B,)
        int32 chunk tokens granted per slot (0 = idle).  Appends all
        granted rows and attends causally in ONE compiled step — a
        P-token prompt costs ceil(P / T) steps instead of P decode steps.
        Returns (logits (B, V) at each slot's last granted position,
        cache with length advanced by grants)."""
        cfg = self.cfg
        if not cfg.embed_inputs:
            cfg = dataclasses.replace(cfg, embed_inputs=True)
        return T.lm_prefill_paged(params, cfg, tokens, cache, grants)

    def prefill_many_paged(self, params, tokens, cache, key, grants, *,
                           temperature: float = 0.0):
        """The engine's prefill-lane cell: one ``prefill_step_paged`` plus
        on-device sampling of the ONE token the chunk produces — the
        logits at each slot's last granted position predict either the
        next (known) prompt token, which the host discards, or the
        request's FIRST output token when the grant drains the prompt.

        The sampler key splits once per prefill chunk (not once per
        token): prompt positions never consume randomness, so greedy
        serving is token-identical to the prefill-by-decode path, and
        temperature serving stays self-consistent within a lane.

        Returns (next_tok (B,) int32, cache, key)."""
        logits, cache = self.prefill_step_paged(params, tokens, cache,
                                                grants)
        nxt, key = sample_token(logits, key, temperature)
        return nxt, cache, key

    def verify_many_paged(self, params, tokens, cache, grants):
        """The engine's speculative VERIFY cell: one ragged prefill-lane
        step over tokens (B, T) = [feed, p_1 .. p_{k}] per slot (grants
        (B,) int32 = 1 + proposals granted, 0 = idle) that unembeds ALL T
        positions at f32 and reduces the accepted prefix on device.

        Greedy-only by design: a proposal is accepted iff it EQUALS the
        target's greedy argmax at its position, so the emitted stream
        ``greedy[:, :accept + 1]`` is bit-identical to plain greedy
        decode and no PRNG is consumed (the engine refuses speculation at
        temperature > 0).

        Returns (greedy (B, T) int32, accept (B,) int32, cache with
        length advanced by the FULL grant — the host rolls rejected rows
        back by truncating ``length``)."""
        cfg = self.cfg
        if not cfg.embed_inputs:
            cfg = dataclasses.replace(cfg, embed_inputs=True)
        return T.lm_verify_paged(params, cfg, tokens, cache, grants)

    def decode_many_paged(self, params, tokens, cache, key, active,
                          forced_tok=None, forced_mask=None, *,
                          num_steps: int, temperature: float = 0.0):
        """Fused multi-token paged decode: one compiled ``lax.scan`` over
        ``num_steps`` non-lockstep decode steps with on-device sampling —
        the SAME cell serves chunked prefill and decode, so the whole
        serving path is one module family ``core.hlo_counters`` can census.

        tokens (B, 1) int32 — each slot's last emitted token.
        active — the per-step activity plan, in one of three forms: a
        (num_steps, B) bool PER-STEP mask; a (B,) bool mask broadcast to
        every step; or a (B,) INTEGER grant vector ``steps`` (slot ``i``
        active for the first ``steps[i]`` steps of the chunk — the tick
        scheduler's native form, expanded to the mask ON DEVICE so the
        host uploads B ints instead of num_steps x B bools every tick).
        An inactive slot writes only the null page, does not advance its
        length, and its token stream is FROZEN (the carry re-emits its
        last token) so the host reads a stable value at the slot's final
        active step regardless of later steps.
        forced_tok / forced_mask (num_steps, B) — where the mask is set the
        emitted token is OVERRIDDEN by forced_tok (prompt feeding: chunked
        prefill routes prompt tokens through the decode cell); None means
        nothing forced.  eos handling is the caller's (the engine truncates
        on the host — per-slot attention means post-eos steps of one slot
        cannot perturb any other slot).

        Returns (out_tokens (num_steps, B) int32, cache, key).
        """
        B = tokens.shape[0]
        if forced_tok is None:
            forced_tok = jnp.zeros((num_steps, B), jnp.int32)
            forced_mask = jnp.zeros((num_steps, B), bool)
        active = jnp.asarray(active)
        if active.dtype != jnp.bool_:
            # (B,) per-slot step grants -> per-step mask, built on device
            active = (jnp.arange(num_steps, dtype=active.dtype)[:, None]
                      < active[None, :])
        elif active.ndim == 1:
            active = jnp.broadcast_to(active[None], (num_steps, B))

        def step(carry, xs):
            tok, cache, key = carry
            f_tok, f_mask, act = xs
            logits, cache = self.decode_step_paged(params, tok, cache, act)
            nxt, key = sample_token(logits, key, temperature)
            nxt = jnp.where(f_mask, f_tok, nxt)
            nxt = jnp.where(act, nxt, tok[:, 0])
            return (nxt[:, None], cache, key), nxt

        (_, cache, key), toks = jax.lax.scan(
            step, (tokens, cache, key), (forced_tok, forced_mask, active),
            length=num_steps)
        return toks, cache, key

    def decode_many(self, params, tokens, cache, key, num_steps: int,
                    temperature: float = 0.0, eos_id: int = -1,
                    pad_id: int = 0):
        """Fused multi-token decode: one compiled ``lax.scan`` over
        ``num_steps`` decode steps with ON-DEVICE sampling and per-slot stop
        conditions — no host round-trip per token, and (jitted with
        ``donate_argnums``) the KV cache is updated in place instead of
        re-materialized every step.

        tokens (B, 1) int32 — the last already-sampled token per slot.
        key — sampler PRNG key (carried and split per step; unused when
        ``temperature <= 0``).  ``eos_id < 0`` disables stop conditions.
        Finished slots keep advancing the cache in lockstep but emit
        ``pad_id`` (their output is frozen).

        Returns (out_tokens (num_steps, B) int32, cache, key, done (B,)).
        """
        B = tokens.shape[0]
        done0 = (tokens[:, 0] == eos_id) if eos_id >= 0 else \
            jnp.zeros((B,), bool)

        def step(carry, _):
            tok, cache, key, done = carry
            logits, cache = self.decode_step(params, tok, cache)
            nxt, key = sample_token(logits, key, temperature)
            nxt = jnp.where(done, jnp.int32(pad_id), nxt)
            if eos_id >= 0:
                done = done | (nxt == eos_id)
            return (nxt[:, None], cache, key, done), nxt

        (_, cache, key, done), toks = jax.lax.scan(
            step, (tokens, cache, key, done0), None, length=num_steps)
        return toks, cache, key, done

    # -- AOT input specs -------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every step input (no allocation)."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32, bf = jnp.int32, cfg.param_dtype
        sds = jax.ShapeDtypeStruct
        if shape.kind in ("train", "prefill"):
            batch: Dict[str, Any] = {"labels": sds((B, S), i32)}
            if cfg.is_encoder_decoder:
                batch["frames"] = sds((B, S, cfg.d_model), bf)
                batch["tokens"] = sds((B, S), i32)
            elif cfg.embed_inputs:
                batch["tokens"] = sds((B, S), i32)
            else:
                batch["embeds"] = sds((B, S, cfg.d_model), bf)
                if cfg.mrope_sections:
                    batch["positions"] = sds((3, B, S), i32)
            if shape.kind == "prefill":
                batch.pop("labels")
            return batch
        # decode: one token + cache
        return {"tokens": sds((B, 1), i32),
                "cache": self.abstract_cache(B, S)}

    def batch_pspecs(self, shape: ShapeConfig, rules: MeshRules):
        cfg = self.cfg
        specs: Dict[str, Any] = {}
        b = rules.resolve("batch")
        if shape.kind in ("train", "prefill"):
            if shape.kind == "train":
                specs["labels"] = P(b, None)
            if cfg.is_encoder_decoder:
                specs["frames"] = P(b, None, None)
                specs["tokens"] = P(b, None)
            elif cfg.embed_inputs:
                specs["tokens"] = P(b, None)
            else:
                specs["embeds"] = P(b, None, None)
                if cfg.mrope_sections:
                    specs["positions"] = P(None, b, None)
            return specs
        return {"tokens": P(b, None),
                "cache": self.cache_pspecs(shape.global_batch, shape.seq_len,
                                           rules)}

    # -- roofline bookkeeping ---------------------------------------------------
    def model_flops(self, shape: ShapeConfig) -> float:
        """Algorithmic FLOPs for one step: 6·N_active·D for train,
        2·N_active·D for prefill/decode forward (D = processed tokens)."""
        n_active = self.cfg.active_params()
        tokens = shape.global_batch * (shape.seq_len
                                       if shape.kind != "decode" else 1)
        mult = 6.0 if shape.kind == "train" else 2.0
        return mult * n_active * tokens


def get_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
