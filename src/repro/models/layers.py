"""Core layers: norms, dense, embeddings, rotary (standard + M-RoPE).

Parameters are built from a *schema* (see models/params.py): every leaf is
declared once with shape + logical sharding axes + init kind, and the same
schema yields (a) rng-initialized arrays, (b) ShapeDtypeStructs for AOT
lowering, (c) PartitionSpecs for the mesh.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with fp32 variance but bf16 application.

    PERF(it.2, llama4 train): every op that touches x directly stays in
    x's dtype — when the first consumer of the remat-saved residual slice
    is a pure bf16->f32 convert, XLA hoists the conversion of the ENTIRE
    (L, B, S, d) saved stack out of the backward loop (measured +8 GiB of
    f32 temp on llama4).  The reduction itself still accumulates in fp32;
    applying the (B, S, 1) rsqrt factor in bf16 costs <0.4% relative error
    on the normalized output."""
    var = jnp.mean(jnp.square(x).astype(jnp.float32), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * r * scale.astype(x.dtype)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(dt)


def dense(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
          ) -> jax.Array:
    # Plain same-dtype dot: the TPU MXU accumulates in fp32 internally for
    # bf16 operands, and XLA:CPU's thunk runtime cannot execute mixed
    # bf16 x bf16 -> f32 dots inside while bodies.
    y = jnp.dot(x, w)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def unembed(h: jax.Array, table: jax.Array) -> jax.Array:
    """Logits via the (possibly tied) embedding table: (…, d) @ (V, d)^T.

    Output stays in the activation dtype (bf16): the MXU accumulates fp32
    internally, and keeping the cotangent path bf16 prevents reverse-mode AD
    from materializing f32 copies of every residual buffer.  The loss
    upcasts to f32 before softmax."""
    return jnp.dot(h, table.T)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (..., S) -> (cos, sin) of shape (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions: jax.Array, head_dim: int, theta: float,
                 sections: Tuple[int, ...]) -> Tuple[jax.Array, jax.Array]:
    """M-RoPE (Qwen2-VL): positions (3, B, S); the rotary half-dim is split
    into (temporal, height, width) sections, each driven by its own position
    stream."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang_all = positions.astype(jnp.float32)[..., None] * freqs  # (3,B,S,half)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang_all[i, ..., start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                       # (B,S,half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, D); cos/sin (B, S, D/2). Rotate-half convention."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x32[..., :half], x32[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings: positions (..., S) ->
    (..., S, d_model)."""
    half = d_model // 2
    scale = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (math.log(10000.0) / max(1, half - 1)))
    ang = positions.astype(jnp.float32)[..., None] * scale
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def swiglu(x, w_gate, w_up, w_down):
    g = dense(x, w_gate)
    u = dense(x, w_up)
    return dense(jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    h = jax.nn.gelu(dense(x, w_up, b_up), approximate=True)
    return dense(h, w_down, b_down)
