"""Parameter schema: declare every leaf once; derive init / abstract specs /
partition specs from the same declaration (no tree drift).

Leaves are ``ParamDecl(shape, axes, init, dtype)`` where ``axes`` are logical
sharding names per dimension ("fsdp" | "model" | None), resolved by the
active ``MeshRules``.  Stacked (scan) parameters get a leading layer dim with
axis None.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import MeshRules


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"             # normal | zeros | ones | embed | ssm_a | ssm_dt
    dtype: Any = None                # None -> cfg param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stacked(decl: ParamDecl, n: int) -> ParamDecl:
    return ParamDecl((n,) + decl.shape, (None,) + decl.axes, decl.init,
                     decl.dtype)


# --- tree utilities ---------------------------------------------------------

def _is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def map_schema(fn: Callable[[ParamDecl], Any], schema) -> Any:
    return jax.tree.map(fn, schema, is_leaf=_is_decl)


def abstract_params(schema, default_dtype=jnp.bfloat16):
    def mk(d: ParamDecl):
        return jax.ShapeDtypeStruct(d.shape, d.dtype or default_dtype)
    return map_schema(mk, schema)


def param_pspecs(schema, rules: MeshRules):
    def mk(d: ParamDecl):
        return P(*(rules.resolve(a) for a in d.axes))
    return map_schema(mk, schema)


def init_params(schema, key: jax.Array, default_dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(schema, is_leaf=_is_decl)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        dt = d.dtype or default_dtype
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        elif d.init == "embed":
            out.append(jax.random.normal(k, d.shape, dt) * 0.02)
        elif d.init == "ssm_a":
            # mamba A_log init: log(1..N) broadcast over channels
            n = d.shape[-1]
            a = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
            out.append(jnp.broadcast_to(a, d.shape).astype(dt))
        elif d.init == "ssm_a_scalar":
            out.append(jnp.zeros(d.shape, dt))      # A = -exp(0) = -1 per head
        elif d.init == "ssm_dt":
            # dt bias init so softplus(dt) spans ~[1e-3, 1e-1]
            lo, hi = math.log(1e-3), math.log(1e-1)
            u = jax.random.uniform(k, d.shape, jnp.float32)
            out.append(jnp.log(jnp.expm1(jnp.exp(lo + u * (hi - lo))) + 1e-9
                               ).astype(dt))
        else:                                        # fan-in normal
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            std = 1.0 / math.sqrt(max(1, fan_in))
            out.append(jax.random.normal(k, d.shape, jnp.float32).astype(dt)
                       * jnp.asarray(std, dt))
    return jax.tree.unflatten(treedef, out)
