"""Fused unembed + softmax cross-entropy, sequence-chunked, custom VJP.

The naive path materializes logits three times around the loss —
(B, S, V) bf16 from the unembed, an f32 copy for logsumexp, and an f32
cotangent — ~6 GiB/device for a 4k x 16 local batch at V=152k.  This
implementation never materializes logits for more than one sequence chunk:

  forward: scan over S-chunks; per chunk compute h_c @ E^T in f32, reduce to
           (lse_c, gold_c), discard the chunk logits.  Residuals: h, E,
           labels, per-position lse — O(B*S) instead of O(B*S*V).
  backward: recompute chunk logits, form d_logits = (softmax - onehot)/N
           chunk-by-chunk, accumulate dh (emitted per chunk) and dE (carry).

Works under GSPMD with the vocab dim of E sharded on the model axis (the
logsumexp/gather reductions over V become partial + all-reduce of (B, c)
vectors).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain


@functools.lru_cache(maxsize=16)
def _make_fused_xent(chunk: int):

    def _chunk_stats(h_c, table, labels_c):
        # h_c (B,c,d); table (V,d); labels (B,c).  The dot stays in the
        # activation dtype (MXU accumulates fp32; a pure astype(f32) of h_c
        # makes XLA hoist an f32 copy of the microbatch-saved hidden stack);
        # the softmax statistics are fp32.
        logits = jnp.dot(h_c, table.T).astype(jnp.float32)  # (B,c,V)
        lse = jax.nn.logsumexp(logits, axis=-1)             # (B,c)
        gold = jnp.take_along_axis(logits, labels_c[..., None],
                                   axis=-1)[..., 0]
        return lse, gold

    def fwd_impl(h, table, labels):
        B, S, d = h.shape
        n = S // chunk
        hc = h.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)

        def step(_, xs):
            h_c, l_c = xs
            return None, _chunk_stats(h_c, table, l_c)

        _, (lse, gold) = jax.lax.scan(step, None, (hc, lc))
        loss = jnp.mean(lse - gold)                        # over B*S
        return loss, lse

    @jax.custom_vjp
    def fused(h, table, labels):
        return fwd_impl(h, table, labels)[0]

    def fused_fwd(h, table, labels):
        loss, lse = fwd_impl(h, table, labels)
        return loss, (h, table, labels, lse)

    def fused_bwd(res, g):
        h, table, labels, lse = res
        B, S, d = h.shape
        V = table.shape[0]
        n = S // chunk
        denom = B * S
        hc = h.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)
        lsec = lse                                          # (n, B, chunk)

        # keep the (V, d) f32 embedding-grad accumulator SHARDED through the
        # scan: unsharded it is gigabytes per device (llama4: 2 x 4.1 GB
        # carry buffers — the cell's memory overage)
        dE0 = constrain(jnp.zeros(table.shape, jnp.float32),
                        "model", "fsdp")

        def step(dE, xs):
            h_c, l_c, lse_c = xs
            logits = jnp.dot(h_c, table.T).astype(jnp.float32)
            p = jnp.exp(logits - lse_c[..., None])         # softmax (B,c,V)
            onehot = jax.nn.one_hot(l_c, V, dtype=jnp.float32)
            dlog = (p - onehot) * (g / denom)
            dh_c = jnp.einsum("bcv,vd->bcd", dlog.astype(table.dtype),
                              table).astype(jnp.float32)
            dE = dE + jnp.einsum("bcv,bcd->vd", dlog.astype(h_c.dtype),
                                 h_c)
            return constrain(dE, "model", "fsdp"), dh_c

        dE, dh_chunks = jax.lax.scan(step, dE0, (hc, lc, lsec))
        dh = dh_chunks.transpose(1, 0, 2, 3).reshape(B, S, d).astype(h.dtype)
        return dh, dE.astype(table.dtype), None

    fused.defvjp(fused_fwd, fused_bwd)
    return fused


def fused_unembed_xent(h: jax.Array, table: jax.Array, labels: jax.Array,
                       chunk: int = 512) -> jax.Array:
    """Mean token NLL of softmax(h @ table^T) against labels, computed
    without materializing full logits.  h (B,S,d); table (V,d);
    labels (B,S) int32."""
    B, S, d = h.shape
    c = min(chunk, S)
    while S % c != 0:
        c //= 2
    return _make_fused_xent(max(1, c))(h, table, labels)
