"""Memory-efficient chunked attention with a custom VJP (pure-jnp flash).

Why this exists: reverse-mode AD through a naive online-softmax scan saves
every (cq x ck) probability block — the full S x S matrix — as scan
residuals, which destroys flash attention's O(S) memory property (measured
~95 GB/layer-iteration of residual traffic on the qwen2 train cell).  The
custom VJP saves only (q, k, v, out, L = rowwise logsumexp) and recomputes
score blocks in the backward pass.

Structure notes (they matter for the roofline):

  * Causal blocks are enumerated STATICALLY as a triangular (i, j) list —
    fully-masked blocks are never emitted, so the causal saving is
    structural (visible to the compiler and the HLO census), not a runtime
    branch.  For S = 32k / chunk 1k this halves attention FLOPs.
  * The scans carry only ONE chunk's accumulator state and EMIT finished
    chunks through scan ys (combined with a segment-sum): carrying stacked
    (nq, ...) accumulators makes XLA shuffle the full buffer through the
    loop carry every iteration.
  * The backward uses the standard two-pass split (dq pass over i-ordered
    blocks; dk/dv pass over j-ordered blocks) so neither pass carries a
    cross-chunk accumulator; scores are recomputed in each pass.

This is also the pure-jnp oracle for the Pallas flash kernel
(repro/kernels/flash_attention).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pairs_by_i(nq, nk, causal, cq, ck):
    pairs = [(i, j) for i in range(nq) for j in range(nk)
             if not (causal and j * ck > i * cq + cq - 1)]
    ii = jnp.asarray([p[0] for p in pairs], jnp.int32)
    jj = jnp.asarray([p[1] for p in pairs], jnp.int32)
    last = jnp.asarray([t == len(pairs) - 1 or pairs[t + 1][0] != pairs[t][0]
                        for t in range(len(pairs))], jnp.bool_)
    first = jnp.asarray([t == 0 or pairs[t - 1][0] != pairs[t][0]
                         for t in range(len(pairs))], jnp.bool_)
    return ii, jj, first, last


def _pairs_by_j(nq, nk, causal, cq, ck):
    pairs = [(i, j) for j in range(nk) for i in range(nq)
             if not (causal and j * ck > i * cq + cq - 1)]
    ii = jnp.asarray([p[0] for p in pairs], jnp.int32)
    jj = jnp.asarray([p[1] for p in pairs], jnp.int32)
    last = jnp.asarray([t == len(pairs) - 1 or pairs[t + 1][1] != pairs[t][1]
                        for t in range(len(pairs))], jnp.bool_)
    first = jnp.asarray([t == 0 or pairs[t - 1][1] != pairs[t][1]
                         for t in range(len(pairs))], jnp.bool_)
    return ii, jj, first, last


def _mask(s, i, j, cq, ck):
    qpos = i * cq + jnp.arange(cq)
    kpos = j * ck + jnp.arange(ck)
    keep = qpos[:, None] >= kpos[None, :]
    return jnp.where(keep[None, :, None, :], s, NEG_INF)


def _scores(qb, kb, scale, causal, i, j, cq, ck):
    s = jnp.einsum("bshd,bthd->bsht", qb.astype(jnp.float32),
                   kb.astype(jnp.float32)) * scale
    if causal:
        s = _mask(s, i, j, cq, ck)
    return s


@functools.lru_cache(maxsize=64)
def _make_flash(causal: bool, cq: int, ck: int):
    """Builds the custom-vjp flash function for given static block sizes."""

    def fwd_impl(q, k, v):
        # q (B,S,H,D); k,v (B,T,H,D) — kv already repeated to H heads
        B, S, H, D = q.shape
        T = k.shape[1]
        nq, nk = S // cq, T // ck
        scale = 1.0 / math.sqrt(D)
        ii, jj, first, last = _pairs_by_i(nq, nk, causal, cq, ck)

        qc = q.reshape(B, nq, cq, H, D)
        kc = k.reshape(B, nk, ck, H, D)
        vc = v.reshape(B, nk, ck, H, D)

        m0 = jnp.full((B, cq, H), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, cq, H), jnp.float32)
        a0 = jnp.zeros((B, cq, H, D), jnp.float32)

        def step(carry, xs):
            m, l, acc = carry
            i, j, fst, lst = xs
            m = jnp.where(fst, m0, m)
            l = jnp.where(fst, l0, l)
            acc = jnp.where(fst, a0, acc)
            qb = jax.lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)
            kb = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
            s = _scores(qb, kb, scale, causal, i, j, cq, ck)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bsht,bthd->bshd", p, vb.astype(jnp.float32))
            a_new = acc * corr[..., None] + pv
            lsafe = jnp.maximum(l_new, 1e-30)
            out_blk = jnp.where(lst, a_new / lsafe[..., None], 0.0)
            L_blk = jnp.where(lst, m_new + jnp.log(lsafe), 0.0)
            return (m_new, l_new, a_new), (out_blk, L_blk)

        _, (out_blocks, L_blocks) = jax.lax.scan(
            step, (m0, l0, a0), (ii, jj, first, last))
        # only the last-j step of each q chunk emitted non-zero: segment-sum
        out = jax.ops.segment_sum(out_blocks, ii, nq)       # (nq,B,cq,H,D)
        L = jax.ops.segment_sum(L_blocks, ii, nq)           # (nq,B,cq,H)
        out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D).astype(q.dtype)
        L = L.transpose(1, 0, 2, 3).reshape(B, S, H)
        return out, L

    def bwd_impl(q, k, v, out, L, dout):
        B, S, H, D = q.shape
        T = k.shape[1]
        nq, nk = S // cq, T // ck
        scale = 1.0 / math.sqrt(D)

        qc = q.reshape(B, nq, cq, H, D)
        kc = k.reshape(B, nk, ck, H, D)
        vc = v.reshape(B, nk, ck, H, D)
        doc = dout.astype(jnp.float32).reshape(B, nq, cq, H, D)
        Lc = L.reshape(B, nq, cq, H)
        # D_i = rowsum(dO * O)
        Dc = jnp.sum(out.astype(jnp.float32).reshape(B, nq, cq, H, D) * doc,
                     axis=-1)                               # (B,nq,cq,H)

        def block(i, j):
            qb = jax.lax.dynamic_index_in_dim(qc, i, 1, keepdims=False)
            kb = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)
            vb = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
            Lb = jax.lax.dynamic_index_in_dim(Lc, i, 1, keepdims=False)
            Db = jax.lax.dynamic_index_in_dim(Dc, i, 1, keepdims=False)
            dob = jax.lax.dynamic_index_in_dim(doc, i, 1, keepdims=False)
            s = _scores(qb, kb, scale, causal, i, j, cq, ck)
            p = jnp.exp(s - Lb[..., None])                  # (B,cq,H,ck)
            dp = jnp.einsum("bshd,bthd->bsht", dob, vb.astype(jnp.float32))
            ds = p * (dp - Db[..., None]) * scale
            return qb, kb, vb, p, ds, dob

        # pass 1: dq, blocks ordered by i (carry = one chunk's dq)
        ii, jj, first, last = _pairs_by_i(nq, nk, causal, cq, ck)

        def step_dq(carry, xs):
            i, j, fst, lst = xs
            carry = jnp.where(fst, 0.0, carry)
            qb, kb, vb, p, ds, dob = block(i, j)
            dqi = carry + jnp.einsum("bsht,bthd->bshd", ds,
                                     kb.astype(jnp.float32))
            return dqi, jnp.where(lst, dqi, 0.0)

        dq0 = jnp.zeros((B, cq, H, D), jnp.float32)
        _, dq_blocks = jax.lax.scan(step_dq, dq0, (ii, jj, first, last))
        dq = jax.ops.segment_sum(dq_blocks, ii, nq)
        dq = dq.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D).astype(q.dtype)

        # pass 2: dk/dv, blocks ordered by j (carry = one chunk's dk/dv)
        ii2, jj2, first2, last2 = _pairs_by_j(nq, nk, causal, cq, ck)

        def step_dkv(carry, xs):
            i, j, fst, lst = xs
            dk_c, dv_c = carry
            dk_c = jnp.where(fst, 0.0, dk_c)
            dv_c = jnp.where(fst, 0.0, dv_c)
            qb, kb, vb, p, ds, dob = block(i, j)
            dv_c = dv_c + jnp.einsum("bsht,bshd->bthd", p, dob)
            dk_c = dk_c + jnp.einsum("bsht,bshd->bthd", ds,
                                     qb.astype(jnp.float32))
            return (dk_c, dv_c), (jnp.where(lst, dk_c, 0.0),
                                  jnp.where(lst, dv_c, 0.0))

        z = jnp.zeros((B, ck, H, D), jnp.float32)
        _, (dk_blocks, dv_blocks) = jax.lax.scan(
            step_dkv, (z, z), (ii2, jj2, first2, last2))
        dk = jax.ops.segment_sum(dk_blocks, jj2, nk)
        dv = jax.ops.segment_sum(dv_blocks, jj2, nk)
        dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, T, H, D).astype(k.dtype)
        dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, T, H, D).astype(v.dtype)
        return dq, dk, dv

    @jax.custom_vjp
    def flash(q, k, v):
        out, _ = fwd_impl(q, k, v)
        return out

    def flash_fwd(q, k, v):
        out, L = fwd_impl(q, k, v)
        return out, (q, k, v, out, L)

    def flash_bwd(res, dout):
        q, k, v, out, L = res
        return bwd_impl(q, k, v, out, L, dout)

    flash.defvjp(flash_fwd, flash_bwd)
    return flash


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool, chunk_q: int = 1024,
                        chunk_kv: int = 1024) -> jax.Array:
    """Public entry: q (B,S,H,D); k,v (B,T,H,D) with H == q heads."""
    B, S, H, D = q.shape
    T = k.shape[1]
    cq = min(chunk_q, S)
    ck = min(chunk_kv, T)
    assert S % cq == 0 and T % ck == 0, (S, cq, T, ck)
    return _make_flash(bool(causal), cq, ck)(q, k, v)
