"""Attention: GQA/MQA multi-head attention with a chunked, online-softmax
("flash-style") path for long sequences and a direct path for short/decode.

The chunked path is also the pure-jnp oracle (``ref``) for the Pallas flash
kernel in ``repro.kernels.flash_attention``; tests assert all three paths
(direct, chunked, Pallas-interpret) agree.

Layout convention: q (B, S, H, D); k, v (B, T, KV, D).  KV heads are
broadcast to H before the einsums (keeps GSPMD propagation trivial: H is
sharded on the model axis, KV stays replicated when KV < TP).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, T, KV, D) -> (B, T, H, D) by repeating each kv head H/KV times."""
    kv = k.shape[2]
    if kv == n_heads:
        return k
    reps = n_heads // kv
    return jnp.repeat(k, reps, axis=2)


def direct_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     causal: bool,
                     q_offset: Optional[jax.Array] = None,
                     kv_len: Optional[jax.Array] = None,
                     kv_start: Optional[jax.Array] = None) -> jax.Array:
    """Materializes (B, KV, G, S, T) scores — fine for decode (S == 1) and
    smoke shapes.  GQA/MQA via grouped einsums: the kv heads are NEVER
    materialized repeated (repeating a 32k MQA cache to 48 heads costs
    ~3 GB/layer).  ``q_offset`` is the absolute position of q[0] (decode);
    ``kv_len`` masks cache positions >= kv_len — a scalar for the lockstep
    dense cache, or a (B,) vector of per-slot lengths for the paged cache
    (every slot decodes at its own position); ``q_offset`` likewise is a
    scalar for lockstep decode or a (B,) vector of per-slot offsets for
    the ragged paged-prefill chunk (slot b's query row t sits at absolute
    position q_offset[b] + t); ``kv_start`` (B,) masks cache positions
    < kv_start[b] — the per-slot window of the continuous-batching engine
    (a slot joining mid-flight must not attend to the previous occupant's
    KV rows)."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D)
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    tpos = jnp.arange(T)
    if causal:
        qpos = jnp.arange(S)
        if q_offset is not None and jnp.ndim(q_offset) == 1:
            # per-slot offsets: (B, S) query positions -> (B, S, T) mask
            qpos = qpos[None, :] + jnp.asarray(q_offset)[:, None]
            mask = qpos[:, :, None] >= tpos[None, None, :]
            s = jnp.where(mask[:, None, None], s, NEG_INF)
        else:
            if q_offset is not None:
                qpos = qpos + q_offset
            mask = qpos[:, None] >= tpos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        if kvl.ndim == 0:
            s = jnp.where((tpos < kvl)[None, None, None, None], s, NEG_INF)
        else:                                    # per-slot (B,) lengths
            live = tpos[None, :] < kvl[:, None]             # (B, T)
            s = jnp.where(live[:, None, None, None], s, NEG_INF)
    if kv_start is not None:
        live = tpos[None, :] >= kv_start[:, None]            # (B, T)
        s = jnp.where(live[:, None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool, chunk_q: int = 1024,
                      chunk_kv: int = 1024) -> jax.Array:
    """Flash-style two-level scan with online softmax; peak memory
    O(chunk_q x chunk_kv) per (B, H).  Baseline computes every (qi, kj)
    block and masks — the causal-block skip lives in the Pallas kernel (and
    the wasted half shows up in the useful-flops roofline column, by
    design)."""
    B, S, H, D = q.shape
    T = k.shape[1]
    k = repeat_kv(k, H)
    v = repeat_kv(v, H)
    cq = min(chunk_q, S)
    ck = min(chunk_kv, T)
    assert S % cq == 0 and T % ck == 0, (S, cq, T, ck)
    nq, nk = S // cq, T // ck
    scale = 1.0 / math.sqrt(D)

    qc = q.reshape(B, nq, cq, H, D).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nk, ck, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, ck, H, D).transpose(1, 0, 2, 3, 4)

    def one_q_chunk(qi, qblk):
        # qblk (B, cq, H, D)
        m0 = jnp.full((B, H, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, cq, H, D), jnp.float32)

        def inner(carry, inputs):
            m, l, acc = carry
            kj, kblk, vblk = inputs
            s = jnp.einsum("bshd,bthd->bhst", qblk.astype(jnp.float32),
                           kblk.astype(jnp.float32)) * scale
            if causal:
                qpos = qi * cq + jnp.arange(cq)
                kpos = kj * ck + jnp.arange(ck)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhst,bthd->bshd", p, vblk.astype(jnp.float32))
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            inner, (m0, l0, a0), (jnp.arange(nk), kc, vc))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
        return out.astype(q.dtype)

    out_chunks = jax.lax.map(lambda args: one_q_chunk(*args),
                             (jnp.arange(nq), qc))
    return out_chunks.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, causal: bool,
              chunk_q: int = 1024, chunk_kv: int = 1024,
              q_offset: Optional[jax.Array] = None,
              kv_len: Optional[jax.Array] = None,
              impl: str = "reference") -> jax.Array:
    """Dispatch: decode and small shapes -> direct; long -> flash (custom-vjp
    chunked jnp, or the Pallas kernel when impl == 'pallas')."""
    B, S, H, D = q.shape
    T = k.shape[1]
    if impl == "pallas" and S > 1 and kv_len is None:
        from repro.kernels.flash_attention import ops as flash_ops
        return flash_ops.flash_attention(q, repeat_kv(k, H), repeat_kv(v, H),
                                         causal=causal)
    if S == 1 or (S * T <= chunk_q * chunk_kv) or kv_len is not None:
        return direct_attention(q, k, v, causal, q_offset, kv_len)
    from repro.models.flash import flash_attention_ref
    return flash_attention_ref(q, repeat_kv(k, H), repeat_kv(v, H),
                               causal, chunk_q, chunk_kv)
