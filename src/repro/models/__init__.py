from repro.models.model import Model, cross_entropy, get_model  # noqa: F401
