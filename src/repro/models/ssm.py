"""State-space blocks: Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2).

Both are implemented as chunked scans so the HLO stays compact (one chunk
body inside a ``while``) and the materialized state tensors stay bounded:

  * Mamba1: per-chunk associative scan over the diagonal recurrence
    h_t = exp(dt_t * A) . h_{t-1} + dt_t * B_t x_t, with the chunk-entry
    state propagated by the cumulative decay (which is <= 1, so no overflow).
  * Mamba2 (SSD): scalar-per-head decay; within-chunk attention-like form
    (the L matrix), across-chunk state recurrence.

Decode paths are single-step recurrences carrying (conv_state, ssm_state).
All state math runs in fp32 regardless of activation dtype.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense, rmsnorm


# ---------------------------------------------------------------------------
# depthwise causal conv (k taps), shift-and-add formulation
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array, b: Optional[jax.Array]
                ) -> jax.Array:
    """x (B, S, C); w (C, K) depthwise causal; returns (B, S, C)."""
    B, S, C = x.shape
    K = w.shape[1]
    out = x * w[:, K - 1]
    for i in range(K - 1):
        shift = K - 1 - i
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :S]
        out = out + xs * w[:, i]
    if b is not None:
        out = out + b
    return out


def conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
              b: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Single decode step.  x_t (B, C); conv_state (B, K-1, C) holds the
    previous K-1 inputs (oldest first)."""
    K = w.shape[1]
    full = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,ck->bc", full, w)
    if b is not None:
        y = y + b
    new_state = full[:, 1:]
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba1 selective scan
# ---------------------------------------------------------------------------

class Mamba1State(NamedTuple):
    conv: jax.Array                  # (B, K-1, d_in)
    ssm: jax.Array                   # (B, d_in, N) fp32


def mamba1_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bc: jax.Array,
                Cc: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """x, dt (B, S, d_in); A (d_in, N); Bc, Cc (B, S, N).
    Returns (y (B, S, d_in), h_final (B, d_in, N)).

    PERF(it.1, falcon train): sequential-time scan, one small fused step per
    token.  The previous log-depth ``associative_scan`` moved the full
    (B, S, d_in, N) tensor through slice/pad/concat chains at every level —
    measured 57 TB/device of HBM traffic on the falcon train cell (11M slice
    ops); the per-step recurrence touches only h (B, d_in, N) plus one
    token's inputs (~60x less).  The Pallas selective-scan kernel
    (repro/kernels/ssm_scan) is the VMEM-resident version of this loop."""
    B, S, d_in = x.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((B, d_in, N), jnp.float32)
    A32 = A.astype(jnp.float32)

    xs = (x.astype(jnp.float32).transpose(1, 0, 2),     # (S, B, d_in)
          dt.astype(jnp.float32).transpose(1, 0, 2),
          Bc.astype(jnp.float32).transpose(1, 0, 2),    # (S, B, N)
          Cc.astype(jnp.float32).transpose(1, 0, 2))

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        dA = jnp.exp(dt_t[..., None] * A32)             # (B, d_in, N)
        h = dA * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y_t = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y_t

    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2)
    return y.astype(x.dtype), h_final


def mamba1_step(x_t, dt_t, A, B_t, C_t, h):
    """Single decode step: x_t, dt_t (B, d_in); B_t, C_t (B, N);
    h (B, d_in, N) -> (y (B, d_in), h')."""
    dA = jnp.exp(dt_t.astype(jnp.float32)[..., None] * A.astype(jnp.float32))
    dBx = (dt_t * x_t).astype(jnp.float32)[..., None] * B_t.astype(
        jnp.float32)[:, None, :]
    h_new = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h_new, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), h_new


def mamba1_block(x: jax.Array, p: dict, cfg: ArchConfig,
                 state: Optional[Mamba1State] = None
                 ) -> Tuple[jax.Array, Optional[Mamba1State]]:
    """Full Mamba1 mixer.  x (B, S, d) train/prefill (state None) or
    (B, 1, d) decode with state."""
    B, S, d = x.shape
    d_in = d * cfg.ssm_expand
    N = cfg.ssm_state
    dt_rank = max(1, d // 16)

    xz = dense(x, p["in_proj"])                      # (B, S, 2*d_in)
    xr, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        xr = causal_conv(xr, p["conv_w"], p["conv_b"])
        xr = jax.nn.silu(xr)
        dbc = dense(xr, p["x_proj"])                 # (B,S,rank+2N)
        dt_r = dbc[..., :dt_rank]
        Bc = dbc[..., dt_rank:dt_rank + N]
        Cc = dbc[..., dt_rank + N:]
        dt = jax.nn.softplus(dense(dt_r, p["dt_proj"]) + p["dt_bias"])
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        y, h_fin = mamba1_scan(xr, dt, A, Bc, Cc, cfg.ssm_chunk)
        y = y + xr * p["D"]
        out = dense(y * jax.nn.silu(z), p["out_proj"])
        return out, None

    x_t, new_conv = conv_step(xr[:, 0], state.conv, p["conv_w"], p["conv_b"])
    x_t = jax.nn.silu(x_t)
    dbc = dense(x_t, p["x_proj"])
    dt_r = dbc[..., :dt_rank]
    B_t = dbc[..., dt_rank:dt_rank + N]
    C_t = dbc[..., dt_rank + N:]
    dt_t = jax.nn.softplus(dense(dt_r, p["dt_proj"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_new = mamba1_step(x_t, dt_t, A, B_t, C_t, state.ssm)
    y = y + x_t * p["D"]
    out = dense(y * jax.nn.silu(z[:, 0]), p["out_proj"])[:, None]
    return out, Mamba1State(conv=new_conv, ssm=h_new)


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

class Mamba2State(NamedTuple):
    conv: jax.Array                  # (B, K-1, d_in)
    ssm: jax.Array                   # (B, H, hd, N) fp32


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bc: jax.Array,
             Cc: jax.Array, chunk: int,
             h0: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """SSD chunked algorithm (Mamba2).

    x (B, S, H, hd); dt (B, S, H) (post-softplus); A (H,) negative;
    Bc, Cc (B, S, N) shared across heads (ngroups == 1).
    Returns (y (B, S, H, hd), h_final (B, H, hd, N))."""
    B, S, H, hd = x.shape
    N = Bc.shape[-1]
    c = min(chunk, S)
    assert S % c == 0
    nch = S // c
    if h0 is None:
        h0 = jnp.zeros((B, H, hd, N), jnp.float32)

    xf = x.astype(jnp.float32).reshape(B, nch, c, H, hd)
    dtf = dt.astype(jnp.float32).reshape(B, nch, c, H)
    Bf = Bc.astype(jnp.float32).reshape(B, nch, c, N)
    Cf = Cc.astype(jnp.float32).reshape(B, nch, c, N)
    A32 = A.astype(jnp.float32)

    def body(h, inp):
        xc, dtc, bc, cc = inp
        # per-step log decay  a_t = dt_t * A  (negative)
        la = dtc * A32                              # (B, c, H)
        cum = jnp.cumsum(la, axis=1)                # (B, c, H)
        # L[t, j] = exp(cum_t - cum_j) for j <= t else 0.  Mask BEFORE exp:
        # above-diagonal differences are positive and would overflow.
        diff = cum[:, :, None, :] - cum[:, None, :, :]
        tri = jnp.tril(jnp.ones((c, c), jnp.bool_))
        Lm = jnp.exp(jnp.where(tri[None, :, :, None], diff, -jnp.inf))
        # within-chunk (diagonal) term:
        # scores[t,j] = (C_t . B_j) L[t,j] dt_j
        cb = jnp.einsum("btn,bjn->btj", cc, bc)     # (B, c, c)
        scores = cb[..., None] * Lm * dtc[:, None]  # (B, c, c, H)
        y_diag = jnp.einsum("btjh,bjhd->bthd", scores, xc)
        # chunk-exit decay per head and state contribution
        total = cum[:, -1]                          # (B, H)
        # decay from step j to chunk end: exp(total - cum_j)
        dec_j = jnp.exp(total[:, None] - cum)       # (B, c, H)
        dBx = jnp.einsum("bjh,bjn,bjhd->bhdn",
                         dec_j * dtc, bc, xc)       # (B, H, hd, N)
        h_new = jnp.exp(total)[..., None, None] * h + dBx
        # off-diagonal term: y_t += C_t . (exp(cum_t) h_in)
        y_off = jnp.einsum("btn,bhdn,bth->bthd", cc, h,
                           jnp.exp(cum))
        return h_new, y_diag + y_off

    h_final, ys = jax.lax.scan(
        body, h0,
        (xf.transpose(1, 0, 2, 3, 4), dtf.transpose(1, 0, 2, 3),
         Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd)
    return y.astype(x.dtype), h_final


def ssd_step(x_t, dt_t, A, B_t, C_t, h):
    """x_t (B, H, hd); dt_t (B, H); B_t, C_t (B, N); h (B, H, hd, N)."""
    a = jnp.exp(dt_t.astype(jnp.float32) * A.astype(jnp.float32))  # (B, H)
    dBx = jnp.einsum("bh,bn,bhd->bhdn", dt_t.astype(jnp.float32),
                     B_t.astype(jnp.float32), x_t.astype(jnp.float32))
    h_new = a[..., None, None] * h + dBx
    y = jnp.einsum("bhdn,bn->bhd", h_new, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), h_new


def mamba2_block(x: jax.Array, p: dict, cfg: ArchConfig,
                 state: Optional[Mamba2State] = None
                 ) -> Tuple[jax.Array, Optional[Mamba2State]]:
    """Mamba2 mixer.  Projections are stored separately (w_zx, w_bc, w_dt)
    so each can carry its own sharding."""
    B, S, d = x.shape
    d_in = d * cfg.ssm_expand
    hd = cfg.ssm_head_dim
    H = d_in // hd
    N = cfg.ssm_state

    zx = dense(x, p["w_zx"])                         # (B, S, 2*d_in)
    z, xr = jnp.split(zx, 2, axis=-1)
    bc = dense(x, p["w_bc"])                         # (B, S, 2N)
    B_c, C_c = jnp.split(bc, 2, axis=-1)
    dt_raw = dense(x, p["w_dt"])                     # (B, S, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))     # (H,)

    if state is None:
        xr = jax.nn.silu(causal_conv(xr, p["conv_w"], p["conv_b"]))
        dt = jax.nn.softplus(dt_raw + p["dt_bias"])
        xh = xr.reshape(B, S, H, hd)
        y, _ = ssd_scan(xh, dt, A, B_c, C_c, cfg.ssm_chunk)
        y = y + xh * p["D"][None, None, :, None]
        y = y.reshape(B, S, d_in)
        y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
        return dense(y, p["out_proj"]), None

    x_t, new_conv = conv_step(xr[:, 0], state.conv, p["conv_w"], p["conv_b"])
    x_t = jax.nn.silu(x_t)
    dt_t = jax.nn.softplus(dt_raw[:, 0] + p["dt_bias"])
    xh = x_t.reshape(B, H, hd)
    y, h_new = ssd_step(xh, dt_t, A, B_c[:, 0], C_c[:, 0], state.ssm)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, d_in)
    y = rmsnorm(y * jax.nn.silu(z[:, 0]), p["norm"], cfg.norm_eps)
    out = dense(y, p["out_proj"])[:, None]
    return out, Mamba2State(conv=new_conv, ssm=h_new)
