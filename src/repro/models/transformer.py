"""Model assembly for all assigned families.

  * decoder-only LM (dense / moe / vlm[stub frontend])  — ``lm_*``
  * encoder-decoder (whisper, stub frontend)            — ``whisper_*``
  * pure SSM LM (falcon-mamba)                          — handled by ``lm_*``
    via mamba blocks
  * hybrid (zamba2: mamba2 trunk + shared attn block)   — ``lm_*`` grouped

Layers are ``lax.scan``-stacked (compact HLO, fast 512-device compiles).
Every forward works in three modes:
  train    — full sequence, no cache, returns (logits, aux)
  prefill  — full sequence, returns (logits, aux, cache)
  decode   — one token + cache, returns (logits, cache)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.sharding import constrain
from repro.models import ssm as ssm_mod
from repro.models.attention import attention, direct_attention
from repro.models.kv_quant import quantize_rows
from repro.models.layers import (
    apply_rope, dense, embed, gelu_mlp, layernorm, mrope_angles, rmsnorm,
    rope_angles, sinusoidal_positions, swiglu, unembed)
from repro.models.moe import moe_ffn
from repro.models.params import ParamDecl, stacked


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def apply_norm(h, p, cfg: ArchConfig):
    if cfg.norm_kind == "layernorm":
        return layernorm(h, p["scale"], p["bias"], cfg.norm_eps)
    return rmsnorm(h, p["scale"], cfg.norm_eps)


def _norm_decl(d: int) -> Dict[str, ParamDecl]:
    return {"scale": ParamDecl((d,), (None,), "ones"),
            "bias": ParamDecl((d,), (None,), "zeros")}


# ---------------------------------------------------------------------------
# schemas
# ---------------------------------------------------------------------------

def _attn_decls(cfg: ArchConfig) -> Dict[str, ParamDecl]:
    d, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    decls = {
        "wq": ParamDecl((d, H * hd), ("fsdp", "model")),
        "wk": ParamDecl((d, KV * hd), ("fsdp", None)),
        "wv": ParamDecl((d, KV * hd), ("fsdp", None)),
        "wo": ParamDecl((H * hd, d), ("model", "fsdp")),
    }
    if cfg.qkv_bias:
        decls["bq"] = ParamDecl((H * hd,), ("model",), "zeros")
        decls["bk"] = ParamDecl((KV * hd,), (None,), "zeros")
        decls["bv"] = ParamDecl((KV * hd,), (None,), "zeros")
    return decls


def _ffn_decls(cfg: ArchConfig) -> Dict[str, ParamDecl]:
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.ffn_kind == "swiglu":
        return {"w_gate": ParamDecl((d, ff), ("fsdp", "model")),
                "w_up": ParamDecl((d, ff), ("fsdp", "model")),
                "w_down": ParamDecl((ff, d), ("model", "fsdp"))}
    return {"w_up": ParamDecl((d, ff), ("fsdp", "model")),
            "b_up": ParamDecl((ff,), ("model",), "zeros"),
            "w_down": ParamDecl((ff, d), ("model", "fsdp")),
            "b_down": ParamDecl((d,), (None,), "zeros")}


def _moe_decls(cfg: ArchConfig) -> Dict[str, ParamDecl]:
    d, ff, E, G = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.ep_shards
    ffp = E * ff // G
    decls = {
        "router": ParamDecl((d, E), ("fsdp", None)),
        "w1": ParamDecl((G, d, ffp), ("ep", "moe_fsdp", None)),
        "w2": ParamDecl((G, ffp, d), ("ep", None, "moe_fsdp")),
    }
    if cfg.ffn_kind == "swiglu":
        decls["w3"] = ParamDecl((G, d, ffp), ("ep", "moe_fsdp", None))
    return decls


def _mamba_decls(cfg: ArchConfig) -> Dict[str, ParamDecl]:
    d = cfg.d_model
    d_in = d * cfg.ssm_expand
    N, K = cfg.ssm_state, cfg.ssm_conv
    if cfg.mamba_version == 1:
        dt_rank = max(1, d // 16)
        return {
            "in_proj": ParamDecl((d, 2 * d_in), ("fsdp", "model")),
            "conv_w": ParamDecl((d_in, K), ("model", None)),
            "conv_b": ParamDecl((d_in,), ("model",), "zeros"),
            "x_proj": ParamDecl((d_in, dt_rank + 2 * N), ("model", None)),
            "dt_proj": ParamDecl((dt_rank, d_in), (None, "model")),
            "dt_bias": ParamDecl((d_in,), ("model",), "ssm_dt"),
            "A_log": ParamDecl((d_in, N), ("model", None), "ssm_a"),
            "D": ParamDecl((d_in,), ("model",), "ones"),
            "out_proj": ParamDecl((d_in, d), ("model", "fsdp")),
        }
    hd = cfg.ssm_head_dim
    H = d_in // hd
    return {
        "w_zx": ParamDecl((d, 2 * d_in), ("fsdp", "model")),
        "w_bc": ParamDecl((d, 2 * N), ("fsdp", None)),
        "w_dt": ParamDecl((d, H), ("fsdp", "model")),
        "conv_w": ParamDecl((d_in, K), ("model", None)),
        "conv_b": ParamDecl((d_in,), ("model",), "zeros"),
        "dt_bias": ParamDecl((H,), ("model",), "ssm_dt"),
        "A_log": ParamDecl((H,), ("model",), "ssm_a_scalar"),
        "D": ParamDecl((H,), ("model",), "ones"),
        "norm": ParamDecl((d_in,), ("model",), "ones"),
        "out_proj": ParamDecl((d_in, d), ("model", "fsdp")),
    }


def _block_decls(cfg: ArchConfig) -> Dict[str, Any]:
    if cfg.mamba_version:
        return {"ln1": _norm_decl(cfg.d_model),
                "mixer": _mamba_decls(cfg)}
    block = {"ln1": _norm_decl(cfg.d_model), "ln2": _norm_decl(cfg.d_model),
             "attn": _attn_decls(cfg)}
    block["moe" if cfg.n_experts else "ffn"] = (
        _moe_decls(cfg) if cfg.n_experts else _ffn_decls(cfg))
    return block


def _shared_attn_decls(cfg: ArchConfig) -> Dict[str, Any]:
    """zamba2's shared transformer block (attention + FFN, one weight set)."""
    return {"ln1": _norm_decl(cfg.d_model), "ln2": _norm_decl(cfg.d_model),
            "attn": _attn_decls(cfg), "ffn": _ffn_decls(cfg)}


def _stack_tree(tree, n: int):
    return jax.tree.map(lambda d: stacked(d, n),
                        tree, is_leaf=lambda x: isinstance(x, ParamDecl))


def schema(cfg: ArchConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab_size
    sch: Dict[str, Any] = {}
    # the token embedding table is always present: stub-frontend archs
    # (audio/vlm) still decode text tokens through it
    sch["embed"] = ParamDecl((V, d), ("model", "fsdp"), "embed")
    if not cfg.tie_embeddings:
        sch["unembed"] = ParamDecl((V, d), ("model", "fsdp"), "embed")
    sch["ln_f"] = _norm_decl(d)
    if cfg.is_encoder_decoder:
        enc_block = {"ln1": _norm_decl(d), "ln2": _norm_decl(d),
                     "attn": _attn_decls(cfg), "ffn": _ffn_decls(cfg)}
        dec_block = {"ln1": _norm_decl(d), "ln2": _norm_decl(d),
                     "ln3": _norm_decl(d), "attn": _attn_decls(cfg),
                     "cross": _attn_decls(cfg), "ffn": _ffn_decls(cfg)}
        sch["encoder"] = _stack_tree(enc_block, cfg.encoder_layers)
        sch["decoder"] = _stack_tree(dec_block, cfg.n_layers)
        sch["ln_enc"] = _norm_decl(d)
        return sch
    if cfg.family == "hybrid":
        sch["blocks"] = _stack_tree(_block_decls(cfg), cfg.n_layers)
        sch["shared_attn"] = _shared_attn_decls(cfg)
        return sch
    sch["blocks"] = _stack_tree(_block_decls(cfg), cfg.n_layers)
    return sch


# ---------------------------------------------------------------------------
# attention block application
# ---------------------------------------------------------------------------

def _rope(cfg: ArchConfig, positions) -> Optional[Tuple]:
    if not cfg.rope_theta:
        return None
    if cfg.mrope_sections:
        return mrope_angles(positions, cfg.head_dim, cfg.rope_theta,
                            cfg.mrope_sections)
    return rope_angles(positions, cfg.head_dim, cfg.rope_theta)


def _qkv(hn, p, cfg: ArchConfig, rope, decode: bool = False):
    B, S, d = hn.shape
    hd = cfg.head_dim
    q = dense(hn, p["wq"], p.get("bq")).reshape(B, S, cfg.n_heads, hd)
    k = dense(hn, p["wk"], p.get("bk")).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(hn, p["wv"], p.get("bv")).reshape(B, S, cfg.n_kv_heads, hd)
    if rope is not None:
        cos, sin = rope
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    if decode:
        # decode attends against a sequence-sharded cache; keep the tiny
        # q/k/v replicated across the model axis so GSPMD keeps the cache
        # stationary and all-reduces only softmax partials.
        q = constrain(q, "batch", None, None, None)
    else:
        q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, None, None)
    v = constrain(v, "batch", None, None, None)
    return q, k, v


def attn_apply(h, p, cfg: ArchConfig, rope, causal=True):
    """train/prefill path: p is the block param dict (ln1 + attn).
    Returns (out, (k, v))."""
    hn = apply_norm(h, p["ln1"], cfg)
    a = p["attn"]
    q, k, v = _qkv(hn, a, cfg, rope)
    out = attention(q, k, v, causal=causal, chunk_q=cfg.attn_chunk_q,
                    chunk_kv=cfg.attn_chunk_kv, impl=cfg.attention_impl)
    B, S, _, _ = out.shape
    out = dense(out.reshape(B, S, -1), a["wo"])
    return constrain(out, "batch", None, None), (k, v)


def attn_decode(h, p, cfg: ArchConfig, rope, k_cache, v_cache, pos,
                start=None):
    """decode path: h (B, 1, d); k_cache/v_cache (B, T, KV, hd); updates at
    ``pos`` and attends over [start[b], pos] (``start`` is the per-slot
    window base of the continuous-batching engine, None -> 0)."""
    hn = apply_norm(h, p["ln1"], cfg)
    a = p["attn"]
    q, k, v = _qkv(hn, a, cfg, rope, decode=True)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    # keep the cache sequence-sharded through the in-place update — without
    # this GSPMD may replicate the updated cache across the model axis
    k_cache = constrain(k_cache, "batch", "cache_seq", None, None)
    v_cache = constrain(v_cache, "batch", "cache_seq", None, None)
    if cfg.attention_impl == "pallas":
        from repro.kernels.decode_attention.ops import decode_attention
        out = decode_attention(q, k_cache, v_cache, kv_len=pos + 1,
                               kv_start=start)
    else:
        out = direct_attention(q, k_cache, v_cache, causal=True,
                               q_offset=pos, kv_len=pos + 1, kv_start=start)
    B = h.shape[0]
    out = dense(out.reshape(B, 1, -1), a["wo"])
    return out, k_cache, v_cache


def attn_decode_paged(h, p, cfg: ArchConfig, rope, k_pool, v_pool, layer,
                      table, lengths, active, k_scale=None, v_scale=None):
    """decode path over the paged KV pool: h (B, 1, d); k_pool/v_pool are
    the STACKED (L, num_pages, page, KV, hd) pools — appended to and
    gathered from with an explicit (layer, page) scatter/gather so no
    pool-sized per-layer slice is ever materialized (that slice is exactly
    the max_seq-proportional traffic the paged cache removes; the HLO
    census asserts the step's bytes scale with live pages).  table
    (B, max_blocks) int32 physical page ids (page 0 = reserved null page,
    where inactive slots' writes land); lengths (B,) int32 per-slot token
    counts; active (B,) bool.

    Appends this step's K/V at each slot's OWN position (page
    ``table[b, lengths[b] // page]``, row ``lengths[b] % page``) and attends
    positions [0, lengths[b]] — no shared cache position, no start-window
    masking: a slot's window is exactly the pages its table references.

    COW-aware append invariant: with prefix sharing a physical page may be
    referenced by SEVERAL block tables.  The append path assumes the page
    at the slot's write position is exclusively owned — the host scheduler
    copy-on-write privatizes any shared page before granting the steps
    that would write it, so a write through one table can never reach rows
    another table still exposes.  Reads need no such care: rope positions
    are request-relative, so the K/V rows of an identical token prefix are
    bit-identical whichever slot computed them, and rows past a sharer's
    ``length`` in a shared trailing page are masked by its own kv_len.

    With quantized pools (k_scale/v_scale not None) the appended row is
    int8-quantized per KV head and the row's f32 scale lands at the same
    (layer, page, row) address — the scale travels with its page."""
    hn = apply_norm(h, p["ln1"], cfg)
    a = p["attn"]
    q, k, v = _qkv(hn, a, cfg, rope, decode=True)
    B = h.shape[0]
    page = k_pool.shape[2]
    nb = table.shape[1]
    blk = jnp.minimum(lengths // page, nb - 1)
    phys = jnp.where(active, table[jnp.arange(B), blk], 0)
    off = lengths % page
    if k_scale is not None:                # quantize the appended row
        kq, ks = quantize_rows(k[:, 0])    # (B, KV, hd) int8, (B, KV) f32
        vq, vs = quantize_rows(v[:, 0])
        k_pool = k_pool.at[layer, phys, off].set(kq)
        v_pool = v_pool.at[layer, phys, off].set(vq)
        k_scale = k_scale.at[layer, phys, off].set(ks)
        v_scale = v_scale.at[layer, phys, off].set(vs)
        k_scale = constrain(k_scale, None, "cache_seq", None, None)
        v_scale = constrain(v_scale, None, "cache_seq", None, None)
    else:
        k_pool = k_pool.at[layer, phys, off].set(k[:, 0].astype(k_pool.dtype))
        v_pool = v_pool.at[layer, phys, off].set(v[:, 0].astype(v_pool.dtype))
    # keep the pool page-sharded through the in-place update
    k_pool = constrain(k_pool, None, "cache_seq", None, None, None)
    v_pool = constrain(v_pool, None, "cache_seq", None, None, None)
    kv_len = lengths + 1
    if cfg.attention_impl == "pallas":
        from repro.kernels.decode_attention.ops import paged_decode_attention
        out = paged_decode_attention(q, k_pool, v_pool, table, kv_len, layer,
                                     pages_per_step=cfg.pages_per_step,
                                     k_scale=k_scale, v_scale=v_scale)
    else:
        from repro.kernels.decode_attention.ref import (
            paged_decode_attention_ref)
        out = paged_decode_attention_ref(q, k_pool, v_pool, table, kv_len,
                                         layer, k_scale=k_scale,
                                         v_scale=v_scale)
    out = dense(out.reshape(B, 1, -1), a["wo"])
    return out, k_pool, v_pool, k_scale, v_scale


def attn_prefill_paged(h, p, cfg: ArchConfig, rope, k_pool, v_pool, layer,
                       table, base, new_len, k_scale=None, v_scale=None):
    """Ragged multi-token CHUNKED-PREFILL path over the paged KV pool:
    h (B, T, d) — a chunk of up to T prompt tokens per slot; base (B,)
    int32 tokens resident before the chunk; new_len (B,) int32 = base +
    the slot's granted chunk tokens (rows past the grant are dead: their
    K/V appends land on the null page and their outputs are ignored).

    Scatters ALL the chunk's K/V rows in one (layer, page) scatter — row t
    of slot b goes to page ``table[b, (base[b]+t) // page]``, row
    ``(base[b]+t) % page`` — then computes CAUSAL attention of the whole
    (T, ...) query block against the slot's live pages plus the in-flight
    chunk (query row t attends positions <= base[b]+t, so the pre-scattered
    future rows of the same chunk are invisible to earlier rows).  One
    kernel step appends and attends T tokens; the prefill-by-decode path
    paid T sequential decode-cell steps for the same rows.

    The COW-aware append invariant of ``attn_decode_paged`` carries over
    verbatim: the scheduler privatizes any shared page the chunk's rows
    would touch (and grants prefill in page-aligned token blocks) BEFORE
    the tick, so a chunk scatter can never reach rows another block table
    still exposes."""
    hn = apply_norm(h, p["ln1"], cfg)
    a = p["attn"]
    q, k, v = _qkv(hn, a, cfg, rope, decode=True)
    B, T, _ = h.shape
    page = k_pool.shape[2]
    nb = table.shape[1]
    tok_pos = base[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    blk = jnp.minimum(tok_pos // page, nb - 1)
    live = tok_pos < new_len[:, None]                       # granted rows
    phys = jnp.where(live, jnp.take_along_axis(table, blk, axis=1), 0)
    off = (tok_pos % page).reshape(B * T)
    phys = phys.reshape(B * T)
    KV, hd = k.shape[2], k.shape[3]
    if k_scale is not None:                # quantize all the chunk's rows
        kq, ks = quantize_rows(k.reshape(B * T, KV, hd))
        vq, vs = quantize_rows(v.reshape(B * T, KV, hd))
        k_pool = k_pool.at[layer, phys, off].set(kq)
        v_pool = v_pool.at[layer, phys, off].set(vq)
        k_scale = k_scale.at[layer, phys, off].set(ks)
        v_scale = v_scale.at[layer, phys, off].set(vs)
        k_scale = constrain(k_scale, None, "cache_seq", None, None)
        v_scale = constrain(v_scale, None, "cache_seq", None, None)
    else:
        k_pool = k_pool.at[layer, phys, off].set(
            k.reshape(B * T, KV, hd).astype(k_pool.dtype))
        v_pool = v_pool.at[layer, phys, off].set(
            v.reshape(B * T, KV, hd).astype(v_pool.dtype))
    # keep the pool page-sharded through the in-place update
    k_pool = constrain(k_pool, None, "cache_seq", None, None, None)
    v_pool = constrain(v_pool, None, "cache_seq", None, None, None)
    if cfg.attention_impl == "pallas":
        from repro.kernels.decode_attention.ops import paged_prefill_attention
        out = paged_prefill_attention(q, k_pool, v_pool, table, base,
                                      new_len, layer,
                                      pages_per_step=cfg.pages_per_step,
                                      k_scale=k_scale, v_scale=v_scale)
    else:
        from repro.kernels.decode_attention.ref import (
            paged_prefill_attention_ref)
        out = paged_prefill_attention_ref(q, k_pool, v_pool, table, base,
                                          new_len, layer, k_scale=k_scale,
                                          v_scale=v_scale)
    out = dense(out.reshape(B, T, -1), a["wo"])
    return out, k_pool, v_pool, k_scale, v_scale


def ffn_apply(h, p, cfg: ArchConfig):
    hn = apply_norm(h, p["ln2"], cfg)
    f = p["ffn"]
    if cfg.ffn_kind == "swiglu":
        out = swiglu(hn, f["w_gate"], f["w_up"], f["w_down"])
    else:
        out = gelu_mlp(hn, f["w_up"], f["b_up"], f["w_down"], f["b_down"])
    return constrain(out, "batch", None, None)


def decode_ffn(h, p, cfg: ArchConfig):
    """The post-attention residual of a decode/prefill step: routed MoE
    (aux dropped — no load-balance loss at inference) or the dense FFN.
    Shared by the dense decode, paged decode and paged prefill bodies so
    the lanes cannot silently diverge."""
    if cfg.n_experts:
        m = p["moe"]
        hn = apply_norm(h, p["ln2"], cfg)
        o, _ = moe_ffn(hn, m["router"], m["w1"], m["w2"], m.get("w3"), cfg)
        return h + o
    return h + ffn_apply(h, p, cfg)


def dense_block(h, p, cfg: ArchConfig, rope):
    out, kv = attn_apply(h, p, cfg, rope, causal=cfg.causal)
    h = h + out
    if cfg.n_experts:
        m = p["moe"]
        hn = apply_norm(h, p["ln2"], cfg)
        out, aux = moe_ffn(hn, m["router"], m["w1"], m["w2"],
                           m.get("w3"), cfg)
        h = h + out
    else:
        aux = jnp.zeros((), jnp.float32)
        h = h + ffn_apply(h, p, cfg)
    return constrain(h, "batch", None, None), aux, kv


def mamba_block_apply(h, p, cfg: ArchConfig, state=None):
    hn = apply_norm(h, p["ln1"], cfg)
    fn = (ssm_mod.mamba1_block if cfg.mamba_version == 1
          else ssm_mod.mamba2_block)
    out, new_state = fn(hn, p["mixer"], cfg, state)
    return constrain(h + out, "batch", None, None), new_state


def shared_attn_block(h, p, cfg: ArchConfig, rope):
    out, kv = attn_apply(h, p, cfg, rope, causal=True)
    h = h + out
    h = h + ffn_apply(h, p, cfg)
    return constrain(h, "batch", None, None), kv


def shared_attn_decode(h, p, cfg: ArchConfig, rope, k_c, v_c, pos,
                       start=None):
    out, k_c, v_c = attn_decode(h, p, cfg, rope, k_c, v_c, pos, start)
    h = h + out
    h = h + ffn_apply(h, p, cfg)
    return h, k_c, v_c


def _maybe_remat(fn, cfg: ArchConfig):
    if cfg.remat in ("block", "full"):
        return jax.checkpoint(fn)
    return fn


# ---------------------------------------------------------------------------
# decoder-only LM forward (dense / moe / vlm / ssm / hybrid)
# ---------------------------------------------------------------------------

def _embed_in(params, cfg: ArchConfig, inputs):
    if cfg.embed_inputs:
        h = embed(inputs, params["embed"]).astype(cfg.param_dtype)
    else:
        h = inputs.astype(cfg.param_dtype)
    return constrain(h, "batch", None, None)


def _logits(params, cfg: ArchConfig, h):
    h = apply_norm(h, params["ln_f"], cfg)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(h, table)
    return constrain(logits, "batch", None, "model")


def _logits_exact(params, cfg: ArchConfig, h):
    """f32 unembed for positions whose logits DECIDE a token (decode steps
    and the prefill last position).  The activation-dtype unembed rounds
    logits to bf16 (~2^-8 relative), coarse enough to flip an argmax
    near-tie between two numerically-equivalent lanes (batched prefill vs
    prefill-by-decode picked different tokens on ragged workloads); at f32
    the gap that could flip is ~1e-7 of the logit scale, below any real
    cross-lane divergence the harness would want to catch.  Full-sequence
    training logits stay in activation dtype — the loss path upcasts
    inside the fused unembed+CE and never samples."""
    h = apply_norm(h, params["ln_f"], cfg)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed(h.astype(jnp.float32), table.astype(jnp.float32))
    return constrain(logits, "batch", None, "model")


def lm_forward(params, cfg: ArchConfig, inputs, positions,
               mode: str = "train"):
    """inputs: tokens (B, S) int32 or embeddings (B, S, d).
    positions: (B, S) or (3, B, S) for M-RoPE.
    mode: train | prefill | hidden (hidden returns the post-ln_f hidden
    states instead of logits — the fused unembed+CE loss consumes that;
    prefill returns only the LAST position's logits, (B, 1, V) at f32 via
    _logits_exact, since their sole consumer samples the next token).
    Returns (logits_or_hidden, aux, cache_parts or None)."""
    assert mode in ("train", "prefill", "hidden")
    h = _embed_in(params, cfg, inputs)
    rope = _rope(cfg, positions)

    if cfg.family == "ssm":
        def body(carry, p):
            h, = carry
            h, _ = mamba_block_apply(h, p, cfg)
            return (h,), None
        (h,), _ = jax.lax.scan(_maybe_remat(body, cfg), (h,),
                               params["blocks"])
        if mode == "hidden":
            return (apply_norm(h, params["ln_f"], cfg),
                    jnp.zeros((), jnp.float32), None)
        if mode == "prefill":
            return (_logits_exact(params, cfg, h[:, -1:]),
                    jnp.zeros((), jnp.float32), None)
        return _logits(params, cfg, h), jnp.zeros((), jnp.float32), None

    if cfg.family == "hybrid":
        return _hybrid_forward(params, cfg, h, rope, mode)

    def body(carry, p):
        h, aux = carry
        h, aux_i, kv = dense_block(h, p, cfg, rope)
        return (h, aux + aux_i), (kv if mode == "prefill" else None)

    g = cfg.remat_group
    if g > 1 and cfg.n_layers % g == 0 and mode != "prefill":
        # layer-grouped remat: checkpoint every g layers — the saved
        # residual stack is (L/g, B, S, d) instead of (L, B, S, d); the
        # backward recomputes g layers per checkpoint (each layer still
        # recomputed exactly once).
        grouped = jax.tree.map(
            lambda x: x.reshape((cfg.n_layers // g, g) + x.shape[1:]),
            params["blocks"])

        def group_body(carry, gp):
            carry, _ = jax.lax.scan(body, carry, gp)
            return carry, None

        (h, aux), kvs = jax.lax.scan(_maybe_remat(group_body, cfg),
                                     (h, jnp.zeros((), jnp.float32)),
                                     grouped)
    else:
        (h, aux), kvs = jax.lax.scan(_maybe_remat(body, cfg),
                                     (h, jnp.zeros((), jnp.float32)),
                                     params["blocks"])
    if mode == "hidden":
        return apply_norm(h, params["ln_f"], cfg), aux, None
    if mode == "prefill":
        # prefill logits exist only to SAMPLE the next token after the
        # prompt: unembed just the last position, at f32 (shape (B, 1, V)
        # so callers' logits[:, -1] keeps working)
        return (_logits_exact(params, cfg, h[:, -1:]), aux,
                {"k": kvs[0], "v": kvs[1]})          # (L, B, S, KV, hd)
    return _logits(params, cfg, h), aux, None


def lm_decode(params, cfg: ArchConfig, tokens, cache):
    """tokens (B, 1); cache per family (see init_cache).

    ``pos`` is both the cache ROW the new token is written to and its rope
    position (whole-batch generation never rebases rows; the paged path
    has per-slot positions instead)."""
    B = tokens.shape[0] if cfg.embed_inputs else tokens.shape[0]
    pos = cache["pos"]
    positions = jnp.full((B, 1), pos, jnp.int32)
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions, (3, B, 1))
    rope = _rope(cfg, positions)
    h = _embed_in(params, cfg, tokens)

    # Caches are carried through the layer scan as FULL stacked buffers and
    # updated in place (dynamic_update_index_in_dim on the layer axis):
    # emitting per-layer caches through scan ys materializes a second copy
    # of every cache (measured +2x cache bytes of decode temps).
    if cfg.family == "ssm":
        def body(carry, p):
            h, conv_all, ssm_all, li = carry
            state = ssm_mod.Mamba1State(
                conv=jax.lax.dynamic_index_in_dim(conv_all, li, 0, False),
                ssm=jax.lax.dynamic_index_in_dim(ssm_all, li, 0, False))
            h, new = mamba_block_apply(h, p, cfg, state)
            conv_all = jax.lax.dynamic_update_index_in_dim(
                conv_all, new.conv.astype(conv_all.dtype), li, 0)
            ssm_all = jax.lax.dynamic_update_index_in_dim(
                ssm_all, new.ssm, li, 0)
            return (h, conv_all, ssm_all, li + 1), None
        (h, conv, ssm_s, _), _ = jax.lax.scan(
            body, (h, cache["conv"], cache["ssm"], jnp.int32(0)),
            params["blocks"])
        new_cache = dict(cache, conv=conv, ssm=ssm_s, pos=pos + 1)
        return _logits_exact(params, cfg, h)[:, 0], new_cache

    if cfg.family == "hybrid":
        return _hybrid_decode(params, cfg, h, rope, cache)

    start = cache.get("start")

    def body(carry, p):
        h, k_all, v_all, li = carry
        k_c = jax.lax.dynamic_index_in_dim(k_all, li, 0, False)
        v_c = jax.lax.dynamic_index_in_dim(v_all, li, 0, False)
        out, k_c, v_c = attn_decode(h, p, cfg, rope, k_c, v_c, pos, start)
        h = h + out
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, k_c, li, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, v_c, li, 0)
        k_all = constrain(k_all, None, "batch", "cache_seq", None, None)
        v_all = constrain(v_all, None, "batch", "cache_seq", None, None)
        h = decode_ffn(h, p, cfg)
        return (h, k_all, v_all, li + 1), None

    (h, k, v, _), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"], jnp.int32(0)), params["blocks"])
    new_cache = dict(cache, k=k, v=v, pos=pos + 1)
    return _logits_exact(params, cfg, h)[:, 0], new_cache


def lm_decode_paged(params, cfg: ArchConfig, tokens, cache, active):
    """tokens (B, 1); cache {"k"/"v" (L, num_pages, page, KV, hd) pools,
    "table" (B, max_blocks) int32, "length" (B,) int32}; active (B,) bool.

    The NON-LOCKSTEP decode step: every slot advances at its own
    ``length`` — rope positions are per-slot (request-relative, starting at
    0 on the slot's own pages), appends go to the slot's own pages via the
    block table, and inactive slots write only the reserved null page 0
    without advancing.  Decoder-only attention LMs only."""
    if cfg.mamba_version or cfg.is_encoder_decoder:
        raise ValueError("paged decode requires a decoder-only attention LM")
    lengths = cache["length"]
    table = cache["table"]
    B = tokens.shape[0]
    positions = lengths[:, None]                       # per-slot positions
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions[None], (3, B, 1))
    rope = _rope(cfg, positions)
    h = _embed_in(params, cfg, tokens)

    def body(carry, p):
        h, k_all, v_all, ks_all, vs_all, li = carry
        out, k_all, v_all, ks_all, vs_all = attn_decode_paged(
            h, p, cfg, rope, k_all, v_all, li, table, lengths, active,
            k_scale=ks_all, v_scale=vs_all)
        h = h + out
        h = decode_ffn(h, p, cfg)
        return (h, k_all, v_all, ks_all, vs_all, li + 1), None

    # scale pools ride the carry only for quantized pools (None is an empty
    # pytree, so the bf16 path's carry structure is unchanged)
    (h, k, v, ks, vs, _), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"], cache.get("k_scale"),
               cache.get("v_scale"), jnp.int32(0)), params["blocks"])
    new_cache = dict(cache, k=k, v=v,
                     length=lengths + active.astype(jnp.int32))
    if ks is not None:
        new_cache.update(k_scale=ks, v_scale=vs)
    return _logits_exact(params, cfg, h)[:, 0], new_cache


def lm_prefill_paged(params, cfg: ArchConfig, tokens, cache, grants,
                     unembed_all: bool = False):
    """Ragged multi-token paged prefill: tokens (B, T) int32 — each slot's
    next chunk of prompt tokens (row i's first ``grants[i]`` entries are
    real; the rest are pad the masks ignore); cache as in
    ``lm_decode_paged``; grants (B,) int32 — prompt tokens granted to each
    slot this chunk (0 = slot idle: nothing appended, length frozen).

    Appends all granted rows in ONE (layer, page) scatter per layer and
    attends causally over history + in-flight chunk, so admitting a
    P-token prompt costs ceil(P / T) compiled steps instead of P decode
    steps.  Only the logits at each slot's LAST granted position are
    unembedded (the next token after any earlier position is a known
    prompt token) — the unembed cost stays chunk-size independent.

    Returns (logits (B, V) at position grants-1 per slot, new cache with
    length advanced by grants).  With ``unembed_all`` every chunk position
    is unembedded instead — logits (B, T, V) at f32, the shape a
    speculative verify consumes (each position decides a token there).
    Decoder-only attention LMs only."""
    if cfg.mamba_version or cfg.is_encoder_decoder:
        raise ValueError("paged prefill requires a decoder-only attention "
                         "LM")
    lengths = cache["length"]
    table = cache["table"]
    B, T = tokens.shape
    grants = jnp.asarray(grants, jnp.int32)
    new_len = lengths + grants
    positions = lengths[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(positions[None], (3, B, T))
    rope = _rope(cfg, positions)
    h = _embed_in(params, cfg, tokens)

    def body(carry, p):
        h, k_all, v_all, ks_all, vs_all, li = carry
        out, k_all, v_all, ks_all, vs_all = attn_prefill_paged(
            h, p, cfg, rope, k_all, v_all, li, table, lengths, new_len,
            k_scale=ks_all, v_scale=vs_all)
        h = h + out
        h = decode_ffn(h, p, cfg)
        return (h, k_all, v_all, ks_all, vs_all, li + 1), None

    (h, k, v, ks, vs, _), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"], cache.get("k_scale"),
               cache.get("v_scale"), jnp.int32(0)), params["blocks"])
    new_cache = dict(cache, k=k, v=v, length=new_len)
    if ks is not None:
        new_cache.update(k_scale=ks, v_scale=vs)
    if unembed_all:
        return _logits_exact(params, cfg, h), new_cache     # (B, T, V)
    # last granted position per slot (grants==0 -> clipped; caller ignores)
    last = jnp.maximum(grants - 1, 0)[:, None, None]
    h_last = jnp.take_along_axis(h, last, axis=1)           # (B, 1, d)
    return _logits_exact(params, cfg, h_last)[:, 0], new_cache


def lm_verify_paged(params, cfg: ArchConfig, tokens, cache, grants):
    """Speculative VERIFY step on the prefill lane: tokens (B, T) int32 —
    row i holds [feed, p_1 .. p_{g-1}, pad] where feed is the slot's next
    input token and p_1.. are draft proposals (``grants[i]`` = g rows are
    real; 0 = slot idle); cache/grants as in ``lm_prefill_paged``.

    Runs the SAME ragged chunk forward as ``lm_prefill_paged`` (one
    scatter + one causal kernel step per layer) but unembeds ALL T
    positions at f32 (PR-7 discipline: every position here DECIDES a
    token) and reduces accept lengths on device:

      * ``greedy[b, t]`` = argmax over position t's logits — the target's
        greedy successor of tokens[b, :t+1].
      * proposal p_{t+1} = tokens[b, t+1] is ACCEPTED iff every earlier
        proposal matched and ``greedy[b, t] == tokens[b, t+1]`` — i.e.
        ``accept[b]`` is the longest common prefix of the target's greedy
        continuations and the draft's proposals.
      * the tick then emits ``greedy[b, :accept[b] + 1]``: the accepted
        proposals ARE the target's greedy tokens, and position accept[b]
        contributes the BONUS token (the target's correction after the
        first mismatch, or the free extra token after an all-accept) —
        so the emitted stream is bit-identical to plain greedy decode by
        construction.

    Returns (greedy (B, T) int32, accept (B,) int32 in [0, g-1], new
    cache with length advanced by the FULL grant — the caller truncates
    rejected rows by rolling ``length`` back to base + accept + 1, which
    the paged cache already supports)."""
    T = tokens.shape[1]
    grants = jnp.asarray(grants, jnp.int32)
    logits, new_cache = lm_prefill_paged(params, cfg, tokens, cache,
                                         grants, unembed_all=True)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, T)
    # leading-run length of proposal matches, masked to the g-1 proposals
    prop_ok = (greedy[:, :T - 1] == tokens[:, 1:])
    in_grant = (jnp.arange(T - 1, dtype=jnp.int32)[None, :]
                < (grants - 1)[:, None])
    run = jnp.cumprod((prop_ok & in_grant).astype(jnp.int32), axis=1)
    accept = run.sum(axis=1)                                 # (B,)
    accept = jnp.minimum(accept, jnp.maximum(grants - 1, 0))
    return greedy, accept, new_cache


# ---------------------------------------------------------------------------
# zamba2 hybrid: grouped scan (attn_every mamba layers + shared attn block)
# ---------------------------------------------------------------------------

def _hybrid_split(cfg: ArchConfig, tree):
    """Split a stacked (L, ...) tree into ((G, k, ...) grouped, (R, ...)
    tail) with k = attn_every, G = L // k, R = L - G*k."""
    k = cfg.attn_every
    G = cfg.n_layers // k
    R = cfg.n_layers - G * k

    def split(x):
        head = x[: G * k].reshape((G, k) + x.shape[1:])
        tail = x[G * k:]
        return head, tail

    heads = jax.tree.map(lambda x: split(x)[0], tree)
    tails = jax.tree.map(lambda x: split(x)[1], tree)
    return heads, tails, G, R


def _hybrid_forward(params, cfg: ArchConfig, h, rope, mode):
    heads, tails, G, R = _hybrid_split(cfg, params["blocks"])
    shared = params["shared_attn"]

    def inner(h, p):
        h, _ = mamba_block_apply(h, p, cfg)
        return h, None

    def group(carry, gp):
        h = carry
        h, _ = jax.lax.scan(inner, h, gp)
        h, kv = shared_attn_block(h, shared, cfg, rope)
        return h, (kv if mode == "prefill" else None)

    h, kvs = jax.lax.scan(_maybe_remat(group, cfg), h, heads)
    if R:
        h, _ = jax.lax.scan(inner, h, tails)
    if mode == "hidden":
        return (apply_norm(h, params["ln_f"], cfg),
                jnp.zeros((), jnp.float32), None)
    if mode == "prefill":
        return (_logits_exact(params, cfg, h[:, -1:]),
                jnp.zeros((), jnp.float32),
                {"attn_k": kvs[0], "attn_v": kvs[1]})  # (G, B, S, KV, hd)
    return _logits(params, cfg, h), jnp.zeros((), jnp.float32), None


def _hybrid_decode(params, cfg: ArchConfig, h, rope, cache):
    heads, tails, G, R = _hybrid_split(cfg, params["blocks"])
    pos = cache["pos"]
    start = cache.get("start")
    shared = params["shared_attn"]

    def mamba_step(carry, p):
        h, conv_all, ssm_all, li = carry
        st = ssm_mod.Mamba2State(
            conv=jax.lax.dynamic_index_in_dim(conv_all, li, 0, False),
            ssm=jax.lax.dynamic_index_in_dim(ssm_all, li, 0, False))
        h, new = mamba_block_apply(h, p, cfg, st)
        conv_all = jax.lax.dynamic_update_index_in_dim(
            conv_all, new.conv.astype(conv_all.dtype), li, 0)
        ssm_all = jax.lax.dynamic_update_index_in_dim(ssm_all, new.ssm,
                                                      li, 0)
        return (h, conv_all, ssm_all, li + 1), None

    def group(carry, gp):
        h, conv_all, ssm_all, li, k_all, v_all, gi = carry
        (h, conv_all, ssm_all, li), _ = jax.lax.scan(
            mamba_step, (h, conv_all, ssm_all, li), gp)
        k_c = jax.lax.dynamic_index_in_dim(k_all, gi, 0, False)
        v_c = jax.lax.dynamic_index_in_dim(v_all, gi, 0, False)
        h, k_c, v_c = shared_attn_decode(h, shared, cfg, rope, k_c, v_c, pos,
                                         start)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, k_c, gi, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, v_c, gi, 0)
        return (h, conv_all, ssm_all, li, k_all, v_all, gi + 1), None

    carry0 = (h, cache["conv"], cache["ssm"], jnp.int32(0),
              cache["attn_k"], cache["attn_v"], jnp.int32(0))
    (h, conv_all, ssm_all, li, k_n, v_n, _), _ = jax.lax.scan(
        group, carry0, heads)
    if R:
        (h, conv_all, ssm_all, _), _ = jax.lax.scan(
            mamba_step, (h, conv_all, ssm_all, li), tails)
    new_cache = dict(cache, conv=conv_all, ssm=ssm_all, attn_k=k_n,
                     attn_v=v_n, pos=pos + 1)
    return _logits_exact(params, cfg, h)[:, 0], new_cache


# ---------------------------------------------------------------------------
# whisper encoder-decoder
# ---------------------------------------------------------------------------

def whisper_encode(params, cfg: ArchConfig, frames):
    """frames (B, S, d) — precomputed frame embeddings (frontend stub)."""
    B, S, _ = frames.shape
    pos = jnp.arange(S)[None]
    h = frames.astype(cfg.param_dtype) + sinusoidal_positions(
        pos, cfg.d_model).astype(cfg.param_dtype)
    h = constrain(h, "batch", None, None)

    def body(h, p):
        out, _ = attn_apply(h, p, cfg, rope=None, causal=False)
        h = h + out
        h = h + ffn_apply(h, p, cfg)
        return constrain(h, "batch", None, None), None

    h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, params["encoder"])
    return apply_norm(h, params["ln_enc"], cfg)


def _cross_kv(enc_out, p, cfg: ArchConfig):
    B, S, _ = enc_out.shape
    hd = cfg.head_dim
    k = dense(enc_out, p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(enc_out, p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    return k, v


def _cross_attend(h, p, cfg: ArchConfig, k, v):
    hn = apply_norm(h, p["ln3"], cfg)
    B, S, _ = hn.shape
    hd = cfg.head_dim
    q = dense(hn, p["cross"]["wq"]).reshape(B, S, cfg.n_heads, hd)
    out = attention(q, k, v, causal=False, chunk_q=cfg.attn_chunk_q,
                    chunk_kv=cfg.attn_chunk_kv)
    return dense(out.reshape(B, S, -1), p["cross"]["wo"])


def whisper_forward(params, cfg: ArchConfig, frames, tokens,
                    mode: str = "train"):
    """Returns (logits, aux, cache or None)."""
    enc = whisper_encode(params, cfg, frames)
    B, S = tokens.shape
    pos = jnp.arange(S)[None]
    h = embed(tokens, params["embed"]).astype(cfg.param_dtype)
    h = h + sinusoidal_positions(pos, cfg.d_model).astype(cfg.param_dtype)
    h = constrain(h, "batch", None, None)

    def body(h, p):
        out, kv = attn_apply(h, p, cfg, rope=None, causal=True)
        h = h + out
        ck, cv = _cross_kv(enc, p["cross"], cfg)
        h = h + _cross_attend(h, p, cfg, ck, cv)
        h = h + ffn_apply(h, p, cfg)
        return constrain(h, "batch", None, None), (
            (kv, (ck, cv)) if mode == "prefill" else None)

    h, ys = jax.lax.scan(_maybe_remat(body, cfg), h, params["decoder"])
    if mode == "hidden":
        return (apply_norm(h, params["ln_f"], cfg),
                jnp.zeros((), jnp.float32), None)
    if mode == "prefill":
        (k, v), (ck, cv) = ys
        return (_logits_exact(params, cfg, h[:, -1:]),
                jnp.zeros((), jnp.float32),
                {"k": k, "v": v, "cross_k": ck, "cross_v": cv})
    return _logits(params, cfg, h), jnp.zeros((), jnp.float32), None


def whisper_decode(params, cfg: ArchConfig, tokens, cache):
    pos = cache["pos"]
    start = cache.get("start")
    B = tokens.shape[0]
    h = embed(tokens, params["embed"]).astype(cfg.param_dtype)
    h = h + sinusoidal_positions(
        jnp.full((B, 1), pos, jnp.int32), cfg.d_model).astype(cfg.param_dtype)

    def body(carry, xs):
        h, k_all, v_all, li = carry
        p, ck, cv = xs                      # cross caches are read-only xs
        k_c = jax.lax.dynamic_index_in_dim(k_all, li, 0, False)
        v_c = jax.lax.dynamic_index_in_dim(v_all, li, 0, False)
        out, k_c, v_c = attn_decode(h, p, cfg, rope=None,
                                    k_cache=k_c, v_cache=v_c, pos=pos,
                                    start=start)
        h = h + out
        h = h + _cross_attend(h, p, cfg, ck, cv)
        h = h + ffn_apply(h, p, cfg)
        k_all = jax.lax.dynamic_update_index_in_dim(k_all, k_c, li, 0)
        v_all = jax.lax.dynamic_update_index_in_dim(v_all, v_c, li, 0)
        return (h, k_all, v_all, li + 1), None

    (h, k, v, _), _ = jax.lax.scan(
        body, (h, cache["k"], cache["v"], jnp.int32(0)),
        (params["decoder"], cache["cross_k"], cache["cross_v"]))
    new_cache = dict(cache, k=k, v=v, pos=pos + 1)
    return _logits_exact(params, cfg, h)[:, 0], new_cache


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def cache_decls(cfg: ArchConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    """Shapes/dtypes (as ParamDecl so the same schema machinery yields
    zeros / ShapeDtypeStructs / PartitionSpecs).

    KV caches are SEQUENCE-sharded over the 'cache_seq' axes
    (flash-decoding-style): a 32k MQA cache replicated across the model
    axis would not fit HBM, whereas seq sharding costs only tiny softmax
    partial all-reduces per layer."""
    hd, KV = cfg.head_dim, cfg.n_kv_heads
    kv_axes = (None, "batch", "cache_seq", None, None)
    d_in = cfg.d_model * cfg.ssm_expand
    K = cfg.ssm_conv
    f32 = jnp.float32
    bf = cfg.param_dtype
    decls: Dict[str, Any] = {
        "pos": ParamDecl((), (), "zeros", jnp.int32),
        # per-slot attention-window base: slot b attends cache positions
        # [start[b], pos].  0 for whole-batch generation; the decode
        # kernels keep the windowed path (serving uses the paged cache's
        # per-slot block tables instead).
        "start": ParamDecl((batch,), ("batch",), "zeros", jnp.int32)}
    if cfg.family == "ssm":
        decls["conv"] = ParamDecl((cfg.n_layers, batch, K - 1, d_in),
                                  (None, "batch", None, "model"), "zeros", bf)
        decls["ssm"] = ParamDecl((cfg.n_layers, batch, d_in, cfg.ssm_state),
                                 (None, "batch", "model", None), "zeros", f32)
        return decls
    if cfg.family == "hybrid":
        H = d_in // cfg.ssm_head_dim
        G = cfg.n_layers // cfg.attn_every
        decls["conv"] = ParamDecl((cfg.n_layers, batch, K - 1, d_in),
                                  (None, "batch", None, "model"), "zeros", bf)
        decls["ssm"] = ParamDecl(
            (cfg.n_layers, batch, H, cfg.ssm_head_dim, cfg.ssm_state),
            (None, "batch", "model", None, None), "zeros", f32)
        decls["attn_k"] = ParamDecl((G, batch, max_seq, KV, hd), kv_axes,
                                    "zeros", bf)
        decls["attn_v"] = ParamDecl((G, batch, max_seq, KV, hd), kv_axes,
                                    "zeros", bf)
        return decls
    L = cfg.n_layers
    decls["k"] = ParamDecl((L, batch, max_seq, KV, hd), kv_axes, "zeros", bf)
    decls["v"] = ParamDecl((L, batch, max_seq, KV, hd), kv_axes, "zeros", bf)
    if cfg.is_encoder_decoder:
        decls["cross_k"] = ParamDecl((L, batch, max_seq, KV, hd), kv_axes,
                                     "zeros", bf)
        decls["cross_v"] = ParamDecl((L, batch, max_seq, KV, hd), kv_axes,
                                     "zeros", bf)
    return decls


def paged_cache_decls(cfg: ArchConfig, batch: int, max_blocks: int,
                      page_size: int, num_pages: int) -> Dict[str, Any]:
    """Paged decode cache: a shared page pool (num_pages, page, KV, hd) per
    layer plus a per-slot block table and per-slot lengths — NO shared
    position, NO start window.  Page 0 is the reserved null page (never
    allocated; inactive slots' appends and unallocated table entries land
    there).  Several block tables may reference the SAME physical page
    (prefix sharing; see serve/cache.py for the refcount/COW discipline —
    the device arrays carry no refcounts, only the host manager does).
    The pool is sharded over its page axis ('cache_seq'), the
    flash-decoding seq-sharding of the dense cache carried over page-wise.

    With ``cfg.kv_dtype == "int8"`` the pools are int8 and the cache grows
    ``k_scale``/``v_scale`` — (L, num_pages, page, KV) f32 per-row-per-head
    scales that travel WITH their pages through every copy path (COW,
    defrag, retained-prefix adoption); see models/kv_quant.py."""
    if cfg.mamba_version or cfg.is_encoder_decoder:
        raise ValueError("paged KV cache requires a decoder-only attention "
                         "LM (per-slot page tables)")
    hd, KV, L = cfg.head_dim, cfg.n_kv_heads, cfg.n_layers
    pool_axes = (None, "cache_seq", None, None, None)
    scale_axes = (None, "cache_seq", None, None)
    bf = jnp.int8 if cfg.kv_quantized else cfg.param_dtype
    decls = {
        "k": ParamDecl((L, num_pages, page_size, KV, hd), pool_axes,
                       "zeros", bf),
        "v": ParamDecl((L, num_pages, page_size, KV, hd), pool_axes,
                       "zeros", bf),
        "table": ParamDecl((batch, max_blocks), ("batch", None), "zeros",
                           jnp.int32),
        "length": ParamDecl((batch,), ("batch",), "zeros", jnp.int32),
    }
    if cfg.kv_quantized:
        decls["k_scale"] = ParamDecl((L, num_pages, page_size, KV),
                                     scale_axes, "zeros", jnp.float32)
        decls["v_scale"] = ParamDecl((L, num_pages, page_size, KV),
                                     scale_axes, "zeros", jnp.float32)
    return decls
