"""Mixture-of-Experts FFN with explicit expert parallelism.

Design (DESIGN.md section 4): activations arrive replicated across the model
axis (post attention all-reduce), so dispatch needs NO all-to-all — each
model-axis member selects, from the full local token set, the tokens routed
to the expert block(s) it owns, runs its expert FFN shard, scatters weighted
partial outputs back, and a single psum over the model axis plays the role
of the dense-FFN tensor-parallel all-reduce.

Expert weights are stored in a mesh-friendly block layout
``(G, d, ffp)`` where ``G = cfg.ep_shards`` blocks partition the
``E x d_ff`` expert volume:  ``shards_per_expert = G // E`` and
``ffp = E * d_ff // G``.  Block g holds expert ``g // shards_per_expert``,
ff-slice ``g % shards_per_expert``.  Because the down-projection contracts
over ff, the per-block partial outputs *sum* to the full expert output —
the same psum that combines experts also completes the ff contraction
(works for llama4: E=16,G=16 and grok: E=8,G=16 alike).

Capacity: per data shard, ``C = capacity_factor * n_local * k / E`` tokens
per expert; overflow drops (Switch-style), underflow pads with zeros.

Without an active mesh (unit tests / 1-device smoke) the identical math runs
locally over all G blocks.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.dist.sharding import active_rules, current_mesh
from repro.models.layers import dense

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                          # jax < 0.6 compat
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, mesh, in_specs, out_specs, check_vma=False):
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)


def route(xf: jax.Array, router_w: jax.Array, cfg: ArchConfig
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """xf (n, d) -> (gates (n,k), experts (n,k), probs (n,E)).

    The router dot stays in the activation dtype (MXU accumulates fp32);
    only the softmax runs in fp32.  A pure ``xf.astype(f32)`` here makes
    XLA hoist an f32 copy of the whole remat-saved residual stack out of
    the backward loop (llama4: +8 GiB)."""
    logits = jnp.dot(xf, router_w.astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, experts, probs


def aux_loss(experts: jax.Array, probs: jax.Array, n_experts: int
             ) -> jax.Array:
    """Switch-style load-balancing loss: E * sum_e f_e * P_e."""
    one_hot = jax.nn.one_hot(experts[..., 0], n_experts)       # top-1 counts
    f = one_hot.mean(axis=0)
    p = probs.mean(axis=0)
    return n_experts * jnp.sum(f * p)


def _expert_block_compute(xf, gates, experts, w1g, w2g, w3g, e: int,
                          capacity: int, ffn_kind: str):
    """Tokens routed to expert ``e`` -> weighted partial FFN output scattered
    back to (n, d)."""
    n = xf.shape[0]
    w_tok = jnp.sum(jnp.where(experts == e, gates, 0.0), axis=-1)   # (n,)
    sel = w_tok > 0
    # stable gather of up-to-capacity selected tokens
    score = jnp.where(sel, jnp.arange(n), n + jnp.arange(n))
    order = jnp.argsort(score)[:capacity]
    valid = sel[order]
    toks = xf[order] * valid[:, None].astype(xf.dtype)              # (C, d)
    if ffn_kind == "swiglu":
        h = jax.nn.silu(dense(toks, w1g)) * dense(toks, w3g)
    else:
        h = jax.nn.gelu(dense(toks, w1g), approximate=True)
    y = dense(h, w2g)                                               # (C, d)
    y = y * (w_tok[order] * valid)[:, None].astype(y.dtype)
    out = jnp.zeros_like(xf)
    return out.at[order].add(y, mode="drop")


def _moe_blocks_local(xf, gates, experts, w1, w2, w3, cfg: ArchConfig,
                      blocks: range, capacity: int):
    shards_per_e = max(1, cfg.ep_shards // cfg.n_experts)
    out = jnp.zeros_like(xf)
    for bi, g in enumerate(blocks):
        e = g // shards_per_e
        w3g = w3[bi] if w3 is not None else None
        out = out + _expert_block_compute(
            xf, gates, experts, w1[bi], w2[bi], w3g, e, capacity,
            cfg.ffn_kind)
    return out


def moe_ffn(x: jax.Array, router_w: jax.Array, w1: jax.Array, w2: jax.Array,
            w3, cfg: ArchConfig) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).

    w1 (G, d, ffp); w2 (G, ffp, d); w3 (G, d, ffp) or None (gelu experts).
    """
    B, S, d = x.shape
    mesh = current_mesh()
    rules = active_rules()
    G = cfg.ep_shards

    xf_full = x.reshape(B * S, d)
    gates, experts, probs = route(xf_full, router_w, cfg)
    aux = aux_loss(experts, probs, cfg.n_experts)

    ep_axes = rules.ep_axes if rules is not None else ("model",)
    tp_ep = 1
    if mesh is not None and rules is not None:
        for a in ep_axes:
            if a in mesh.shape:
                tp_ep *= mesh.shape[a]

    if tp_ep == 1 or G % tp_ep != 0:
        n = B * S
        capacity = max(1, int(cfg.capacity_factor * n
                              * cfg.experts_per_token / cfg.n_experts))
        out = _moe_blocks_local(xf_full, gates, experts, w1, w2, w3, cfg,
                                range(G), capacity)
        return out.reshape(B, S, d), aux

    # --- expert-parallel island ---------------------------------------------
    # expert blocks are sharded over ep_axes (train: the model axis; big-MoE
    # serving: data x model — fully weight-stationary).  Any fsdp axes not
    # consumed by EP still shard the weights' d dim and are gathered here.
    batch_axes = rules.batch_axes
    fsdp_axes = (tuple(a for a in rules.fsdp_axes if a not in ep_axes)
                 if rules.use_fsdp else ())
    blocks_per_rank = G // tp_ep
    n_dp = 1
    for a in batch_axes:
        n_dp *= mesh.shape[a]
    n_local = max(1, (B // max(1, n_dp)) * S)
    capacity = max(1, int(cfg.capacity_factor * n_local
                          * cfg.experts_per_token / cfg.n_experts))
    shards_per_e = max(1, G // cfg.n_experts)

    def _axis_entry(axes):
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]

    ep_entry = _axis_entry(ep_axes)
    fsdp_entry = _axis_entry(fsdp_axes)
    w_specs = P(ep_entry, fsdp_entry, None)
    w2_spec = P(ep_entry, None, fsdp_entry)
    tok_spec = P(_axis_entry(batch_axes), None)

    def island(xf, g8, e8, w1l, w2l, w3l):
        # gather FSDP-sharded weight dims
        if fsdp_axes:
            w1l = jax.lax.all_gather(w1l, fsdp_axes, axis=1, tiled=True)
            w2l = jax.lax.all_gather(w2l, fsdp_axes, axis=2, tiled=True)
            if w3l is not None:
                w3l = jax.lax.all_gather(w3l, fsdp_axes, axis=1, tiled=True)
        r = jnp.int32(0)
        for a in ep_axes:                      # row-major combined EP rank
            r = r * mesh.shape[a] + jax.lax.axis_index(a)
        out = jnp.zeros_like(xf)
        for bi in range(blocks_per_rank):
            g = r * blocks_per_rank + bi
            e = g // shards_per_e
            w3g = w3l[bi] if w3l is not None else None
            # e is traced (depends on r) — _expert_block_compute only uses it
            # in comparisons, which is fine.
            out = out + _expert_block_compute(
                xf, g8, e8, w1l[bi], w2l[bi], w3g, e, capacity, cfg.ffn_kind)
        return jax.lax.psum(out, ep_axes)

    in_specs = (tok_spec, tok_spec, tok_spec, w_specs, w2_spec,
                (w_specs if w3 is not None else None))
    if w3 is None:
        island_fn = lambda xf, g8, e8, w1l, w2l: island(xf, g8, e8, w1l,
                                                        w2l, None)
        sm = _shard_map(island_fn, mesh=mesh,
                        in_specs=in_specs[:5], out_specs=tok_spec,
                        check_vma=False)
        out = sm(xf_full, gates, experts, w1, w2)
    else:
        sm = _shard_map(island, mesh=mesh, in_specs=in_specs,
                        out_specs=tok_spec, check_vma=False)
        out = sm(xf_full, gates, experts, w1, w2, w3)
    return out.reshape(B, S, d).astype(x.dtype), aux
