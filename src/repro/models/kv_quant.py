"""Symmetric int8 row quantization for the paged KV page pools.

The page pools store K/V rows as int8 with one f32 scale PER ROW PER KV
HEAD (pool shape (L, num_pages, page, KV, hd) -> scale shape
(L, num_pages, page, KV)).  Per-row scales are the smallest granularity
that keeps the serving invariants intact:

  * incremental append writes exactly its own row's scale — a page-level
    scale would force a lossy requantization of every already-resident
    row in the page on each append;
  * COW privatization, defrag and retained-prefix adoption copy int8
    rows + scale rows VERBATIM, so shared/retained content stays
    bit-exact (no requantization anywhere after the initial write);
  * storage overhead is 4/hd of the int8 bytes (3% at hd=128), far under
    the 2x the bf16 pools cost.

Quantize and dequantize are the SAME arithmetic everywhere — the write
paths in ``transformer.py``, both Pallas kernels' page sweeps, and the
jnp gather oracles in ``ref.py`` — so interpret-mode equivalence pins
the kernels and the oracles stay the ground truth.
"""
from __future__ import annotations

import jax.numpy as jnp

# symmetric int8: q = round(x / scale) in [-127, 127], scale = absmax / 127
QMAX = 127.0


def quantize_rows(x):
    """Quantize ``x`` (..., hd) -> (int8 rows (..., hd), f32 scales (...)).

    All-zero rows get scale 1.0 so dequantization is exact (zeros) and the
    null page stays all-zero in both pools.
    """
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(absmax > 0, absmax / QMAX, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -QMAX, QMAX)
    return q.astype(jnp.int8), scale


def dequantize_rows(q, scale):
    """Inverse of ``quantize_rows``: int8 rows (..., hd) + f32 scales (...)
    -> f32 rows.  Exact for the rows ``quantize_rows`` produced (round-trip
    error is bounded by scale/2 per element, zero for all-zero rows)."""
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
