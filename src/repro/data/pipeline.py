"""Deterministic, resumable, sharded synthetic-token data pipeline.

Production properties this models (and tests assert):
  * deterministic as a function of (seed, step) — restart-safe: the
    checkpoint stores only the step cursor;
  * host-sharded: each data-parallel host generates only its slice
    (``host_index`` / ``num_hosts``);
  * straggler re-assignment: ``reassign(host)`` lets the trainer hand a
    slow host's shard to a spare without replaying the stream (pure
    function of (seed, step, shard map));
  * background prefetch of the next batch (double buffering).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0
    emit_embeddings: bool = False     # stub-frontend archs (audio/vlm)
    d_model: int = 0
    emit_frames: bool = False         # enc-dec


class SyntheticTokenPipeline:
    """Zipf-ish synthetic LM tokens with next-token labels."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts
        self._shard_map: Dict[int, int] = {i: i for i in
                                           range(cfg.num_hosts)}
        self._queue: "queue.Queue" = queue.Queue(maxsize=2)
        self._prefetch_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- determinism ---------------------------------------------------------
    def _rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.Generator(np.random.Philox(
            key=self.cfg.seed, counter=[0, 0, step, shard]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The local batch for `step` — pure function of (seed, step,
        shard)."""
        cfg = self.cfg
        shard = self._shard_map[cfg.host_index]
        rng = self._rng(step, shard)
        # zipf-like marginal over the vocab, cheap to sample
        u = rng.random((self.local_batch, cfg.seq_len + 1))
        toks = ((cfg.vocab_size - 1) * u ** 3.0).astype(np.int32)
        batch: Dict[str, np.ndarray] = {
            "labels": toks[:, 1:].astype(np.int32)}
        if cfg.emit_embeddings:
            batch["embeds"] = rng.standard_normal(
                (self.local_batch, cfg.seq_len, cfg.d_model),
                dtype=np.float32)
        else:
            batch["tokens"] = toks[:, :-1]
        if cfg.emit_frames:
            batch["frames"] = rng.standard_normal(
                (self.local_batch, cfg.seq_len, cfg.d_model),
                dtype=np.float32)
            batch["tokens"] = toks[:, :-1]
        return batch

    # -- straggler mitigation hook -------------------------------------------
    def reassign(self, slow_host: int, spare_host: int) -> None:
        """Hand slow_host's shard to spare_host (no stream replay needed)."""
        self._shard_map[spare_host] = self._shard_map[slow_host]

    # -- prefetching iterator --------------------------------------------------
    def iterator(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        def worker():
            step = start_step
            while not self._stop.is_set():
                try:
                    self._queue.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        self._stop.clear()
        self._prefetch_thread = threading.Thread(target=worker, daemon=True)
        self._prefetch_thread.start()
        try:
            while True:
                yield self._queue.get()
        finally:
            self._stop.set()

    def close(self):
        self._stop.set()
