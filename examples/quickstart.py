"""Quickstart: the paper's workflow end-to-end in 60 lines.

1. Reproduce a row of the paper's Table 2 from raw rocProf counters.
2. Profile a jitted JAX function with the XLA instruction census (the
   "rocProf for XLA") and print its instruction roofline record.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import paper_data
from repro.core.hardware import TPU_V5E
from repro.core.hlo_counters import census_from_compiled
from repro.core.roofline import roofline_terms
from repro.core.tpu_model import profile_from_census

# --- 1. paper Table 2, MI100 row -------------------------------------------
m = paper_data.TWEAC_MI100
print("== Leinhauser et al. Table 2 (TWEAC ComputeCurrent, MI100) ==")
print(f"  peak GIPS (Eq.3):      {m.peak_gips():8.2f}   (published 180.24)")
print(f"  achieved GIPS (Eq.4):  {m.achieved_gips():8.3f}   (published 4.993)")
print(f"  intensity (Eq.2):      {m.intensity_performance():8.3f}"
      "   (published 0.408)")
print(f"  bound: {m.bound()}")

# --- 2. same methodology, applied to a compiled XLA step --------------------
def step(x, w1, w2):
    h = jax.nn.gelu(x @ w1)
    return (h @ w2).sum()

B, D, F = 256, 512, 2048
args = [jax.ShapeDtypeStruct(s, jnp.bfloat16)
        for s in [(B, D), (D, F), (F, D)]]
compiled = jax.jit(step).lower(*args).compile()

census = census_from_compiled(compiled)
terms = roofline_terms("quickstart_mlp", census, TPU_V5E, n_devices=1)
prof = profile_from_census("quickstart_mlp", census, TPU_V5E,
                           runtime_s=terms.modeled_time_s)

print("\n== instruction roofline of the compiled MLP step (TPU v5e model) ==")
print(f"  MXU flops: {census.mxu_flops/1e9:.2f} GFLOP   "
      f"issues: {census.mxu_issues:.0f} "
      f"(padding eff {prof.mxu_padding_efficiency*100:.0f}%)")
print(f"  VPU issues: {census.vpu_issues:.0f}   HBM bytes: "
      f"{census.hbm_bytes/1e6:.1f} MB")
print("  " + terms.summary())
print(f"  achieved MXU GIPS {prof.achieved_mxu_gips:.4f} "
      f"(peak {prof.peak_mxu_gips:.4f}) | intensity "
      f"{prof.mxu_intensity:.2e} inst/B")
