"""Profile any assigned architecture x shape into an instruction-roofline
report + IRM plot, without hardware (AOT dry-run on placeholder devices).

Run:  PYTHONPATH=src python examples/profile_model.py --arch granite-8b \
          --shape train_4k [--multi-pod] [--plot out.png]

NOTE: spawns the 512-device dry-run in-process; run it as your first jax
use in the process (it sets XLA_FLAGS before importing jax).
"""
import argparse
import importlib
import json
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--plot", default="")
    args = ap.parse_args()

    # dryrun sets XLA_FLAGS at import time — must come before any jax init
    from repro.launch import dryrun
    rec = dryrun.run_cell(args.arch, args.shape, args.multi_pod)
    print(json.dumps({k: v for k, v in rec.items()
                      if k in ("cell", "roofline", "irm", "memory",
                               "build_info", "skipped")},
                     indent=2, default=str))

    if args.plot and "irm" not in rec.get("skipped", "irm"):
        from repro.core.hardware import TPU_V5E
        from repro.core.irm import tpu_irm
        from repro.core.plotting import plot_irm
        from repro.core.tpu_model import TpuInstructionProfile
        irm = rec["irm"]
        prof = TpuInstructionProfile(
            name=rec["cell"], hw=TPU_V5E, runtime_s=irm["runtime_s"],
            runtime_is_modeled=True,
            mxu_issues=rec["census"]["mxu_issues"],
            vpu_issues=rec["census"]["vpu_issues"],
            scalar_ops=rec["census"]["scalar_ops"],
            hbm_bytes=rec["census"]["hbm_bytes"],
            mxu_flops=rec["census"]["mxu_flops"],
            vpu_flops=rec["census"]["vpu_flops"],
            mxu_flops_padded=rec["census"]["mxu_issues"] * 2 * 128 ** 3)
        plot_irm(tpu_irm([prof], title=rec["cell"]), args.plot)
        print(f"wrote {args.plot}")


if __name__ == "__main__":
    main()
