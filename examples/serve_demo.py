"""Serving demo on a reduced granite-8b: whole-batch fused decode, then the
paged continuous-batching engine (per-slot positions, refcounted page pool,
chunked prefill, prefix sharing + copy-on-write).

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import numpy as np

from repro.configs import get
from repro.models import get_model
from repro.serve.engine import PagedEngine, ServeConfig, ServingEngine


def main():
    cfg = get("granite-8b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params,
                           ServeConfig(max_batch=4, max_seq=96,
                                       max_new_tokens=12, temperature=0.8))

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 17, 5, 12)]
    t0 = time.time()
    outs = engine.generate_batch(prompts)
    dt = time.time() - t0
    total = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"[serve_demo] req{i} prompt_len={len(prompts[i])} -> {o}")
    print(f"[serve_demo] {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on this host)")

    stats = engine.benchmark_decode(batch=4, seq=64, steps=6)
    print(f"[serve_demo] fused decode step {stats['s_per_step']*1e3:.2f} ms "
          f"({stats['tokens_per_s']:.1f} tok/s), "
          f"x{stats['fused_speedup']:.1f} vs per-token loop")

    # paged continuous batching: 8 requests over 4 slots, mid-flight joins,
    # prompts chunk-prefilled through the one fused decode cell
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=4, max_seq=64, max_new_tokens=8,
                                 page_size=16, prefill_chunk=4))
    rids = [pe.submit(rng.randint(0, cfg.vocab_size, size=6)
                      .astype(np.int32)) for _ in range(8)]
    results = pe.run()
    util = pe.util_trace
    print(f"[serve_demo] paged: {len(results)} requests / {pe.joins} joins "
          f"on 4 slots, {sum(len(results[r]) for r in rids)} tokens in "
          f"{pe.steps_run} chunked ticks, page util "
          f"mean={np.mean(util):.2f} max={np.max(util):.2f}")

    # prefix sharing: a common system prompt across 8 requests — later
    # admissions reference the resident prefix pages instead of recomputing
    # them; the first append into a shared page copies it (copy-on-write)
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=4, max_seq=64, max_new_tokens=6,
                                 page_size=8, prefill_chunk=4))
    sys_prompt = rng.randint(0, cfg.vocab_size, size=18).astype(np.int32)
    # ragged tails AND budgets stagger the finishes: sharing matches LIVE
    # slots, so a later admission needs a donor still mid-flight (equal
    # lengths would finish whole waves in the same chunk-quantized tick)
    rids = [pe.submit(np.concatenate(
        [sys_prompt, rng.randint(0, cfg.vocab_size, size=rng.randint(2, 9))
         .astype(np.int32)]), max_new_tokens=int(rng.randint(3, 10)))
        for _ in range(8)]
    results = pe.run()
    print(f"[serve_demo] shared-prefix: {len(results)} requests, "
          f"{pe.shared_tokens} prompt tokens served by page reference, "
          f"{pe.kv.cow_copies} COW page copies, logical/physical tokens "
          f"x{pe.logical_physical_ratio:.2f}")


if __name__ == "__main__":
    main()
