"""Serving demo: batched prefill + lockstep decode with a shared KV cache
(continuous-batching style), on a reduced granite-8b.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import numpy as np

from repro.configs import get
from repro.models import get_model
from repro.serve.engine import ServeConfig, ServingEngine


def main():
    cfg = get("granite-8b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params,
                           ServeConfig(max_batch=4, max_seq=96,
                                       max_new_tokens=12, temperature=0.8))

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 17, 5, 12)]
    t0 = time.time()
    outs = engine.generate_batch(prompts)
    dt = time.time() - t0
    total = sum(len(o) for o in outs)
    for i, o in enumerate(outs):
        print(f"[serve_demo] req{i} prompt_len={len(prompts[i])} -> {o}")
    print(f"[serve_demo] {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on this host)")

    stats = engine.benchmark_decode(batch=4, seq=64, steps=6)
    print(f"[serve_demo] fused decode step {stats['s_per_step']*1e3:.2f} ms "
          f"({stats['tokens_per_s']:.1f} tok/s), "
          f"x{stats['fused_speedup']:.1f} vs per-token loop")

    # continuous batching: 8 requests over 4 slots, joins mid-flight
    from repro.serve.engine import ContinuousBatchingEngine
    cbe = ContinuousBatchingEngine(
        model, params, ServeConfig(max_batch=4, max_seq=256,
                                   max_new_tokens=8))
    rids = [cbe.submit(rng.randint(0, cfg.vocab_size, size=6)
                       .astype(np.int32)) for _ in range(8)]
    results = cbe.run()
    print(f"[serve_demo] continuous: {len(results)} requests / "
          f"{cbe.joins} joins on 4 slots, "
          f"{sum(len(results[r]) for r in rids)} tokens in "
          f"{cbe.steps_run} lockstep steps")

    # paged non-lockstep: same workload, per-slot positions + page pool,
    # prompts chunk-prefilled through the fused decode cell
    from repro.serve.engine import PagedEngine
    pe = PagedEngine(model, params,
                     ServeConfig(max_batch=4, max_seq=64, max_new_tokens=8,
                                 page_size=16, prefill_chunk=4))
    rids = [pe.submit(rng.randint(0, cfg.vocab_size, size=6)
                      .astype(np.int32)) for _ in range(8)]
    results = pe.run()
    print(f"[serve_demo] paged: {len(results)} requests / {pe.joins} joins "
          f"on 4 slots, {sum(len(results[r]) for r in rids)} tokens in "
          f"{pe.steps_run} chunked ticks, page util "
          f"mean={pe.util_sum / max(1, pe.steps_run):.2f} "
          f"max={pe.util_max:.2f}")


if __name__ == "__main__":
    main()
