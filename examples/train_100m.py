"""End-to-end training driver: a ~100M-parameter llama-family model for a
few hundred steps, with checkpoint/restart and loss logging.

Presets:
  --preset 100m        the real thing (~163M params, use on TPU or a beefy
                       host; a few hundred steps)
  --preset cpu-smoke   CPU-sized variant (~6M params, 120 steps) — what CI
                       and EXPERIMENTS.md run; same code path end to end.

Run:  PYTHONPATH=src python examples/train_100m.py --preset cpu-smoke
Restart behaviour: re-running with the same --ckpt dir resumes from the
newest committed checkpoint (kill it mid-run and re-run to see).
"""
import argparse
import dataclasses

from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig
from repro.models import get_model
from repro.optim.adamw import AdamWConfig
from repro.optim.schedule import cosine_with_warmup
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    "100m": dict(
        arch=ArchConfig(
            name="llama-100m", family="dense", n_layers=12, d_model=768,
            n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=32768,
            attn_chunk_q=512, attn_chunk_kv=512),
        seq_len=1024, global_batch=32, steps=300, lr=3e-4),
    "cpu-smoke": dict(
        arch=ArchConfig(
            name="llama-6m", family="dense", n_layers=4, d_model=256,
            n_heads=4, n_kv_heads=2, d_ff=1024, vocab_size=4096,
            attn_chunk_q=128, attn_chunk_kv=128),
        seq_len=128, global_batch=8, steps=120, lr=1e-3),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-smoke", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt", default="/tmp/repro_train100m_ckpt")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = p["arch"]
    model = get_model(cfg)
    n = cfg.n_params()
    print(f"[train_100m] {cfg.name}: ~{n/1e6:.1f}M params")

    steps = args.steps or p["steps"]
    trainer = Trainer(
        model,
        AdamWConfig(lr=cosine_with_warmup(p["lr"], 20, steps)),
        DataConfig(vocab_size=cfg.vocab_size, seq_len=p["seq_len"],
                   global_batch=p["global_batch"]),
        TrainerConfig(steps=steps, checkpoint_every=max(10, steps // 4),
                      checkpoint_dir=args.ckpt, log_every=10),
    )
    out = trainer.run()
    print(f"[train_100m] loss {out['first_loss']:.4f} -> "
          f"{out['last_loss']:.4f} over {len(out['losses'])} steps "
          f"({out['wall_s']:.1f}s)")
    assert out["last_loss"] < out["first_loss"], "loss did not improve"
    print("[train_100m] OK — loss improved")


if __name__ == "__main__":
    main()
