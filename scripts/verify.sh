#!/usr/bin/env bash
# Tier-1 verify gate: pytest suite + CPU smoke serve benchmark + tokens/s
# regression check against the COMMITTED BENCH_serve.json.
#
#   scripts/verify.sh            # full gate
#   TOL=0.5 scripts/verify.sh    # custom regression tolerance (default 0.4:
#                                # CPU smoke timings swing under container
#                                # contention; the gate catches collapses,
#                                # the recorded trajectory catches drift)
set -euo pipefail
cd "$(dirname "$0")/.."

TOL="${TOL:-0.4}"
# every step runs under a hard wall-clock cap: a wedged engine (the exact
# failure mode the overload harness guards) must FAIL the gate, not hang
# CI.  The in-process pytest watchdog (tests/conftest.py) fires first with
# per-test stacks; this is the outer belt-and-suspenders.
STEP_TIMEOUT="${STEP_TIMEOUT:-3600}"
run_capped() { timeout -k 30 "$STEP_TIMEOUT" "$@"; }

# per-step wall-clock accounting, summarized at the end: CI time is a
# budget and the summary shows which step is spending it
STEP_NAMES=()
STEP_SECS=()
STEP_T0=$SECONDS
step_done() {
  STEP_NAMES+=("$1")
  STEP_SECS+=($((SECONDS - STEP_T0)))
  STEP_T0=$SECONDS
}
print_timings() {
  echo "[verify] step timing summary:"
  local i
  for i in "${!STEP_NAMES[@]}"; do
    printf '  %4ds  %s\n' "${STEP_SECS[$i]}" "${STEP_NAMES[$i]}"
  done
  printf '  %4ds  total\n' "$SECONDS"
}

echo "[verify] tier-1 pytest (capped at ${STEP_TIMEOUT}s/step)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} run_capped python -m pytest -x -q
step_done "tier-1 pytest"

echo "[verify] committed BENCH_serve.json baseline"
git show HEAD:BENCH_serve.json > /tmp/bench_baseline.json
step_done "baseline checkout"

echo "[verify] CPU smoke serve_bench (all scenarios)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    run_capped python benchmarks/serve_bench.py --json --scenario all
step_done "serve_bench all"

echo "[verify] CPU smoke serve_bench (quantized KV pages)"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    run_capped python benchmarks/serve_bench.py --json --scenario ragged \
    --kv-dtype int8
step_done "serve_bench int8"

echo "[verify] HLO census throughput"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    run_capped python benchmarks/census_bench.py --json
step_done "census_bench"

echo "[verify] tokens/s regression check (tolerance ${TOL})"
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - "$TOL" <<'EOF'
import json
import sys

tol = float(sys.argv[1])
with open("/tmp/bench_baseline.json") as f:
    base = json.load(f)
with open("BENCH_serve.json") as f:
    new = json.load(f)


def get(rec, dotted):
    cur = rec
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


# throughputs must not collapse below (1 - tol) x committed; ratios and
# speedups are schedule-determined and get the same gate
GATED = [
    "tokens_per_s_fused",
    "continuous_tokens_per_s",
    "ragged.ragged_tokens_per_s_paged",
    "ragged.ragged_paged_speedup",
    "shared_prefix.shared_tokens_per_s",
    "shared_prefix.shared_logical_physical_ratio",
    "long_decode.long_decode_tokens_per_s",
    "long_prompt.long_prompt_tokens_per_s_lane",
    "overload.overload_goodput_tokens_per_s",
    "cold_prefix.cold_prefix_tokens_per_s",
    "ragged_int8.int8_tokens_per_s",
    "speculative.speculative_tokens_per_s",
    "census.lines_per_s",
]
# per-tick overheads must not climb above ceiling x committed — the
# tick_overhead section is a CEILING gate, not a floor.  Dispatch count
# and upload bytes are schedule-deterministic (tight ceiling); host ms is
# wall clock under container contention (wide ceiling: catches collapses)
GATED_CEIL = [
    ("tick_overhead.tick_dispatches", 1.0 + tol),
    ("tick_overhead.tick_upload_bytes", 1.0 + tol),
    ("tick_overhead.tick_host_ms", 1.0 + 4 * tol),
    # restore latency is wall clock under container contention — wide
    # ceiling, same reasoning as tick_host_ms (catches collapses)
    ("restart.restart_restore_ms", 1.0 + 4 * tol),
    ("restart.restart_snapshot_write_ms", 1.0 + 4 * tol),
]
failed = []
for key in GATED:
    b, n = get(base, key), get(new, key)
    if b is None or n is None:
        print(f"  [skip] {key}: missing ({'baseline' if b is None else 'new'})")
        continue
    floor = (1.0 - tol) * b
    status = "ok" if n >= floor else "REGRESSION"
    print(f"  [{status}] {key}: {n:.2f} vs committed {b:.2f} "
          f"(floor {floor:.2f})")
    if n < floor:
        failed.append(key)
for key, factor in GATED_CEIL:
    b, n = get(base, key), get(new, key)
    if b is None or n is None:
        print(f"  [skip] {key}: missing ({'baseline' if b is None else 'new'})")
        continue
    ceil = factor * b
    status = "ok" if n <= ceil else "REGRESSION"
    print(f"  [{status}] {key}: {n:.2f} vs committed {b:.2f} "
          f"(ceiling {ceil:.2f})")
    if n > ceil:
        failed.append(key)

# hard floors independent of the committed record (acceptance criteria)
ratio = get(new, "shared_prefix.shared_logical_physical_ratio")
if ratio is not None and ratio < 1.5:
    print(f"  [REGRESSION] shared-prefix logical/physical ratio {ratio:.2f} "
          f"< 1.5")
    failed.append("shared_prefix_ratio_floor")
spd = get(new, "shared_prefix.shared_speedup")
if spd is not None and spd <= 1.0:
    print(f"  [REGRESSION] shared-prefix speedup {spd:.2f} <= 1.0 "
          f"(sharing must beat unshared at equal pool)")
    failed.append("shared_prefix_speedup_floor")
# a healthy long-decode drive is mostly STEADY ticks (1 dispatch, only
# the B-int feed/grant upload — zero table bytes, zero forced bytes);
# reintroducing any per-tick upload would drop this fraction to 0
sf = get(new, "tick_overhead.tick_steady_frac")
if sf is not None and sf < 0.25:
    print(f"  [REGRESSION] steady-tick fraction {sf:.2f} < 0.25 "
          f"(long-decode ticks are paying per-tick uploads/dispatches)")
    failed.append("steady_tick_frac_floor")
# the ragged prefill lane must beat prefill-by-decode on prompt tokens/s
# (acceptance: >= 2x committed; 1.5x here catches collapses under
# container contention without flaking the gate — deliberately a HARD
# floor only, NOT in GATED: a ratio of two wall-clock runs swings too
# much under contention for a relative-to-committed floor)
ps = get(new, "long_prompt.long_prompt_speedup")
if ps is not None and ps < 1.5:
    print(f"  [REGRESSION] prefill-lane speedup {ps:.2f} < 1.5 "
          f"(the multi-token prefill lane lost to prefill-by-decode)")
    failed.append("long_prompt_speedup_floor")
# prompt traffic routed through the lane must never build the per-step
# (chunk, B) forced-token arrays — the upload the lane exists to retire
fb = get(new, "long_prompt.long_prompt_forced_upload_bytes")
if fb is not None and fb != 0:
    print(f"  [REGRESSION] prefill-lane forced_upload_bytes {fb:.0f} != 0 "
          f"(prompt traffic leaked back onto the forced decode path)")
    failed.append("long_prompt_forced_upload_zero")
# overload safety (acceptance criteria): a 4x-oversubscribed bursty
# workload must complete with ZERO crashed ticks (the pre-overload engine
# raised "page pool exhausted" here), at least one preemption (else the
# scenario is not actually exercising the preempt-and-recompute path),
# every request at a typed terminal status, and a bounded recompute tax
# (measured ~0.11 of all appended tokens; the 0.60 ceiling catches a
# thrashing victim policy without flaking on schedule jitter)
ct = get(new, "overload.overload_crashed_ticks")
if ct is not None and ct != 0:
    print(f"  [REGRESSION] overload crashed_ticks {ct:.0f} != 0 "
          f"(engine.step() raised under an admissible overload schedule)")
    failed.append("overload_crashed_ticks_zero")
pre = get(new, "overload.overload_preemptions")
if pre is not None and pre < 1:
    print(f"  [REGRESSION] overload preemptions {pre:.0f} < 1 "
          f"(the overload scenario never wedged the pool — not a test)")
    failed.append("overload_preemptions_floor")
at = get(new, "overload.overload_all_terminal")
if at is not None and at != 1:
    print(f"  [REGRESSION] overload all_terminal {at:.0f} != 1 "
          f"(a request leaked out of the lifecycle without a terminal "
          f"status)")
    failed.append("overload_all_terminal")
rf = get(new, "overload.overload_recompute_fraction")
if rf is not None and rf > 0.60:
    print(f"  [REGRESSION] overload recompute fraction {rf:.2f} > 0.60 "
          f"(preemption is thrashing: most appended K/V rows are "
          f"recomputed work)")
    failed.append("overload_recompute_ceiling")
gp = get(new, "overload.overload_goodput_tokens_per_s")
if gp is not None and gp < 250:
    print(f"  [REGRESSION] overload goodput {gp:.1f} tok/s < 250 "
          f"(completed-request throughput collapsed under overload)")
    failed.append("overload_goodput_floor")
# cross-lifetime retention (acceptance criteria): every follower repeating
# the dead donor's 256-token system prompt must adopt from the RETAINED
# pool (hit rate 1.0 — there is no live donor to share from), re-sharing a
# nonzero token count, and the warm engine must beat the retention-off
# baseline by >= 1.5x tokens/s (measured ~2.9x; a HARD floor, not in
# GATED: a ratio of two wall-clock runs swings under contention)
chr_ = get(new, "cold_prefix.cold_prefix_hit_rate")
if chr_ is not None and chr_ < 0.99:
    print(f"  [REGRESSION] cold-prefix retained hit rate {chr_:.2f} < 0.99 "
          f"(followers missed the dead donor's retained prefix)")
    failed.append("cold_prefix_hit_rate_floor")
crt = get(new, "cold_prefix.cold_prefix_retained_tokens")
if crt is not None and crt <= 0:
    print(f"  [REGRESSION] cold-prefix retained tokens {crt:.0f} <= 0 "
          f"(no tokens were ever re-shared from the retained pool)")
    failed.append("cold_prefix_retained_tokens_floor")
cs = get(new, "cold_prefix.cold_prefix_speedup")
if cs is not None and cs < 1.5:
    print(f"  [REGRESSION] cold-prefix speedup {cs:.2f} < 1.5 "
          f"(retention lost its win over the cold-prefill baseline)")
    failed.append("cold_prefix_speedup_floor")
cch = get(new, "cold_prefix.cold_prefix_cold_hit_rate")
if cch is not None and cch != 0:
    print(f"  [REGRESSION] retention-OFF engine reported retained hits "
          f"({cch:.2f}) — the baseline is not actually cold")
    failed.append("cold_prefix_cold_baseline_clean")
# quantized KV pages (acceptance criteria): pool-resident byte traffic per
# live token (irregular/gather slope of the decode-step census over block-
# table width) must be <= 0.6x the bf16 pool's (measured ~0.27 against the
# f32-compute measurement config; theoretical (hd+4)/(4*hd) at d_head=64),
# the SAME program must stay pool-size independent, tokens/s on the ragged
# workload must hold >= 0.9x the bf16 engine (a HARD floor, not in GATED
# as a ratio: two wall-clock runs under contention), and the two quantized
# WRITE paths (prefill lane vs prefill-by-decode) must emit token-
# identical streams — per-row scales make their appended rows bit-equal
pbr = get(new, "ragged_int8.int8_pool_bytes_ratio")
if pbr is not None and pbr > 0.6:
    print(f"  [REGRESSION] int8 pool-byte ratio {pbr:.2f} > 0.6 "
          f"(quantized pages stopped shrinking per-live-token traffic)")
    failed.append("int8_pool_bytes_ceiling")
pin = get(new, "ragged_int8.int8_pool_independent")
if pin is not None and pin != 1:
    print(f"  [REGRESSION] int8 census pool-independence flag {pin:.0f} "
          f"!= 1 (decode-step bytes moved with POOL size, not live tokens)")
    failed.append("int8_pool_independence")
tr = get(new, "ragged_int8.int8_bf16_tokens_ratio")
if tr is not None and tr < 0.9:
    print(f"  [REGRESSION] int8/bf16 tokens/s ratio {tr:.2f} < 0.9 "
          f"(quantized pools cost more than a tenth of throughput)")
    failed.append("int8_tokens_ratio_floor")
ti = get(new, "ragged_int8.int8_token_identity")
if ti is not None and ti != 1:
    print(f"  [REGRESSION] int8 write-path token identity {ti:.0f} != 1 "
          f"(prefill lane and prefill-by-decode quantized the same rows "
          f"differently)")
    failed.append("int8_write_path_identity")
cap = get(new, "ragged_int8.int8_capacity_ratio")
if cap is not None and cap < 1.5:
    print(f"  [REGRESSION] int8 resident-token capacity ratio {cap:.2f} "
          f"< 1.5 (page_bytes stopped reflecting the quantized pool)")
    failed.append("int8_capacity_floor")
# speculative decoding (acceptance criteria): greedy draft-and-verify is
# EXACT by construction, so the spec and plain engines must emit bit-
# identical token streams regardless of accept rate; the doctored bench
# target pins accept_rate at 1.0 (a drop means the verify/accept math
# broke, not the draft quality); no tick may raise; and the machinery
# must clear >= 1.3x tokens/s over the same engine speculating off
# (measured ~1.5-1.9x on the 6-layer doctored target; a HARD floor, not
# in GATED as a ratio: two wall-clock runs swing under contention)
ss = get(new, "speculative.speculative_speedup")
if ss is not None and ss < 1.3:
    print(f"  [REGRESSION] speculative speedup {ss:.2f} < 1.3 "
          f"(draft-and-verify lost its win over plain decode ticks)")
    failed.append("speculative_speedup_floor")
sti = get(new, "speculative.speculative_token_identity")
if sti is not None and sti != 1:
    print(f"  [REGRESSION] speculative token identity {sti:.0f} != 1 "
          f"(greedy speculation emitted a different stream than plain "
          f"decode — the accept/truncate/rollback math is broken)")
    failed.append("speculative_token_identity")
sct = get(new, "speculative.speculative_crashed_ticks")
if sct is not None and sct != 0:
    print(f"  [REGRESSION] speculative crashed_ticks {sct:.0f} != 0 "
          f"(a draft/verify tick raised)")
    failed.append("speculative_crashed_ticks_zero")
sar = get(new, "speculative.speculative_accept_rate")
if sar is not None and sar < 0.99:
    print(f"  [REGRESSION] speculative accept rate {sar:.2f} < 0.99 "
          f"(the doctored target must accept every proposal — the "
          f"verify window or draft rollback desynced)")
    failed.append("speculative_accept_rate_floor")
if get(new, "speculative.speculative_tokens_per_s") is not None and \
        sar is None:
    print("  [REGRESSION] speculative section missing accept_rate")
    failed.append("speculative_accept_rate_missing")
# crash-consistent restart (acceptance criteria): a kill-and-restore
# drill must finish with every request's output BIT-IDENTICAL to the
# uninterrupted oracle (greedy determinism + verbatim state restore),
# zero non-kill crashes, a recompute tax bounded by the snapshot cadence
# (only the snapshot->kill window replays; 0.60 matches the overload
# thrash ceiling), and a sane absolute restore latency (the relative
# ceiling rides GATED_CEIL; 5s absolute catches a restore that started
# re-running prefill instead of reloading pools)
rti = get(new, "restart.restart_token_identity")
if rti is not None and rti != 1:
    print(f"  [REGRESSION] restart token identity {rti:.0f} != 1 "
          f"(kill-and-restore emitted a different stream than the "
          f"uninterrupted oracle — the snapshot lost state)")
    failed.append("restart_token_identity")
rct = get(new, "restart.restart_crashed_ticks")
if rct is not None and rct != 0:
    print(f"  [REGRESSION] restart crashed_ticks {rct:.0f} != 0 "
          f"(a restored engine raised on a non-kill tick)")
    failed.append("restart_crashed_ticks_zero")
rrf = get(new, "restart.restart_recompute_fraction")
if rrf is not None and rrf > 0.60:
    print(f"  [REGRESSION] restart recompute fraction {rrf:.2f} > 0.60 "
          f"(the restore is replaying far more than the snapshot->kill "
          f"window)")
    failed.append("restart_recompute_ceiling")
rrm = get(new, "restart.restart_restore_ms")
if rrm is not None and rrm > 5000:
    print(f"  [REGRESSION] restart restore latency {rrm:.0f} ms > 5000 "
          f"(restore should reload pools, not recompute them)")
    failed.append("restart_restore_latency_ceiling")
rk = get(new, "restart.restart_kills")
if rk is not None and rk < 1:
    print(f"  [REGRESSION] restart kills {rk:.0f} < 1 "
          f"(the drill never killed the engine — not a test)")
    failed.append("restart_kills_floor")

if failed:
    print(f"[verify] FAILED: {failed}")
    sys.exit(1)
print("[verify] OK")
EOF
step_done "regression gate"

print_timings
echo "[verify] all gates passed"
